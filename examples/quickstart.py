"""Quickstart: the paper's Figure 2-2 — one neural column (1000 Izhikevich
neurons, 80% RS / 20% FS), 2000 ms of simulated activity with STDP.

Produces: an ASCII rastergram, per-window firing rates, two membrane-
potential traces, and a spike-events CSV.

  PYTHONPATH=src python examples/quickstart.py [--steps 2000]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (EngineConfig, GridConfig, build, engine,
                        observables)


def membrane_trace(spec, plan, state, neuron_ids, steps):
    """Re-run stepwise recording v(t) for a few neurons (paper Fig 2-2)."""
    step = jax.jit(engine.make_step_fn(spec, plan))
    vs = []
    for t in range(steps):
        state, _ = step(state, jnp.int32(t))
        vs.append(np.asarray(state.v[0, neuron_ids]))
    return np.stack(vs)


def ascii_raster(raster, width=100, height=20):
    """Downsample the [T, N] spike raster to an ASCII picture."""
    T, N = raster.shape
    img = raster.reshape(height, T // height * N // width, -1)
    r = raster[: T // width * width, : N // height * height]
    r = r.reshape(width, T // width, height, N // height)
    dots = r.sum(axis=(1, 3)).T > 0
    lines = ["".join("." if not d else "#" for d in row) for row in
             dots[::-1]]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--out", default="quickstart_out")
    args = ap.parse_args()

    cfg = GridConfig(grid_x=1, grid_y=1)       # 1000 neurons, 200K synapses
    print(f"building 1 column: {cfg.n_neurons} neurons, "
          f"{cfg.n_synapses} synapses ...")
    spec, plan, state = build(cfg, EngineConfig(n_shards=1))

    print(f"simulating {args.steps} ms ...")
    state2, raster, tm = jax.jit(
        lambda s: engine.run(spec, plan, s, 0, args.steps))(state)
    raster = np.asarray(raster)[:, 0]          # [T, N]

    rate = observables.mean_rate_hz(raster[:, None], cfg.n_neurons)
    print(f"\nmean firing rate: {rate:.1f} Hz "
          "(paper Table 1, single column: ~20 Hz)")
    win = observables.rate_per_window(raster[:, None], cfg.n_neurons, 100)
    print("rate per 100ms window (Hz):",
          " ".join(f"{x:.0f}" for x in win))

    print("\nrastergram (time ->, neuron id ^):")
    print(ascii_raster(raster))

    os.makedirs(args.out, exist_ok=True)
    csv = os.path.join(args.out, "spikes.csv")
    observables.dump_events_csv(csv, raster[:, None, :],
                                np.asarray(plan.gid))
    print(f"\nspike events written to {csv}")

    print("\nmembrane traces for neurons [0, 900] over 300 ms "
          "(paper Fig 2-2 bottom):")
    tr = membrane_trace(spec, plan, state, np.array([0, 900]), 300)
    for row in range(2):
        t_ = tr[:, row]
        lo, hi = -90.0, 35.0
        q = np.clip(((t_ - lo) / (hi - lo) * 8).astype(int), 0, 8)
        print(f"n{row}: " + "".join(" .:-=+*#%"[v] for v in q[:300]))
    print("\nweights: exc in [%.2f, %.2f] after STDP"
          % (float(np.asarray(state2.w)[np.asarray(plan.syn_plastic)].min()),
             float(np.asarray(state2.w)[np.asarray(plan.syn_plastic)].max())))


if __name__ == "__main__":
    main()
