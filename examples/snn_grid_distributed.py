"""Distributed end-to-end driver (the paper's kind of workload): a 2-D grid
of neural columns simulated across multiple shards with the two-phase AER
halo exchange, with a mid-run checkpoint and an ELASTIC restart on a
different shard count — the rasters must be identical (paper Table 1).

This script forces 4 host devices, so run it as-is (fresh interpreter):

  PYTHONPATH=src python examples/snn_grid_distributed.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", "")).strip()

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (EngineConfig, GridConfig, build, checkpoint,
                        observables, run)
from repro.core import distributed as D

STEPS1, STEPS2 = 150, 150


def main():
    # connectivity="gaussian:sigma=1.0" (or any core.profiles spec) swaps
    # the lateral kernel; halo depth, AER routes and the elastic-restart
    # identity below all follow the profile's reach automatically.
    cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=500,
                     synapses_per_neuron=100)
    eng = EngineConfig(n_shards=4, exchange="halo")
    print(f"grid {cfg.grid_x}x{cfg.grid_y}, {cfg.n_neurons} neurons, "
          f"{cfg.n_synapses} synapses over {eng.n_shards} shards (halo "
          "exchange)")

    spec, plan, state = build(cfg, eng)
    offs = D.halo_offsets(spec, plan)
    print(f"static halo schedule: {len(offs)} shard offsets "
          f"(vs {eng.n_shards}-way all-to-all)")

    mesh = D.make_mesh(4)
    state_d = D.shard_put(mesh, state)
    runner = D.make_sharded_run(spec, plan, mesh)

    print(f"phase 1: {STEPS1} ms on 4 shards ...")
    state_d, raster1, tm = runner(state_d, 0, STEPS1)
    rate = observables.mean_rate_hz(np.asarray(raster1), cfg.n_neurons)
    print(f"  rate {rate:.1f} Hz, spikes/step "
          f"{np.asarray(tm.spikes).sum(1).mean():.1f}")

    ck = "ckpt_demo/ckpt_%d.npz" % STEPS1
    checkpoint.save(ck, spec, plan, jax_tree_to_host(state_d), STEPS1)
    print(f"  checkpoint -> {ck}")

    # continue on 4 shards
    state_d, raster2a, _ = runner(state_d, STEPS1, STEPS2)
    sig_a = observables.raster_signature(np.asarray(raster2a),
                                         np.asarray(plan.gid))

    # ELASTIC restart: same checkpoint, 2 shards, scatter placement
    eng2 = EngineConfig(n_shards=2, placement="scatter")
    spec2, plan2, _ = build(cfg, eng2)
    state2, t0 = checkpoint.load(ck, spec2, plan2)
    _, raster2b, _ = run(spec2, plan2, state2, t0, STEPS2)
    sig_b = observables.raster_signature(np.asarray(raster2b),
                                         np.asarray(plan2.gid))

    assert sig_a == sig_b, "elastic restart changed the spike raster!"
    print("phase 2: identical rasters on 4-shard continue vs 2-shard "
          f"scatter restart  (sha256 {sig_a.hex()[:16]}...)  OK")


def jax_tree_to_host(tree):
    import jax
    return jax.tree.map(np.asarray, tree)


if __name__ == "__main__":
    main()
