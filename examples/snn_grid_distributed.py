"""Distributed end-to-end driver (the paper's kind of workload): a 2-D grid
of neural columns simulated across multiple shards with the two-phase AER
halo exchange, with a mid-run checkpoint and an ELASTIC restart on a
different shard count — the rasters must be identical (paper Table 1).

This script forces 4 host devices, so run it as-is (fresh interpreter):

  PYTHONPATH=src python examples/snn_grid_distributed.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", "")).strip()

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (EngineConfig, GridConfig, StepProgram, checkpoint,
                        observables)
from repro.core import distributed as D

STEPS1, STEPS2 = 150, 150


def main():
    # connectivity="gaussian:sigma=1.0" (or any core.profiles spec) swaps
    # the lateral kernel; halo depth, AER routes and the elastic-restart
    # identity below all follow the profile's reach automatically.
    cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=500,
                     synapses_per_neuron=100)
    eng = EngineConfig(n_shards=4, exchange="halo")
    print(f"grid {cfg.grid_x}x{cfg.grid_y}, {cfg.n_neurons} neurons, "
          f"{cfg.n_synapses} synapses over {eng.n_shards} shards (halo "
          "exchange)")

    sp = StepProgram(cfg, eng, mesh=D.make_mesh(4))
    spec, plan = sp.spec, sp.plan
    offs = D.halo_offsets(spec, plan)
    print(f"static halo schedule: {len(offs)} shard offsets "
          f"(vs {eng.n_shards}-way all-to-all)")

    state_d = sp.place(sp.init_state())

    print(f"phase 1: {STEPS1} ms on 4 shards ...")
    state_d, raster1, tm = sp.run(state_d, 0, STEPS1)
    rate = observables.mean_rate_hz(np.asarray(raster1), cfg.n_neurons)
    print(f"  rate {rate:.1f} Hz, spikes/step "
          f"{np.asarray(tm.spikes).sum(1).mean():.1f}")

    ck = "ckpt_demo/ckpt_%d.npz" % STEPS1
    checkpoint.save(ck, spec, plan, jax_tree_to_host(state_d), STEPS1)
    print(f"  checkpoint -> {ck}")

    # continue on 4 shards
    state_d, raster2a, _ = sp.run(state_d, STEPS1, STEPS2)
    sig_a = observables.raster_signature(np.asarray(raster2a),
                                         np.asarray(plan.gid))

    # ELASTIC restart: same checkpoint, 2 shards, scatter placement
    sp2 = StepProgram(cfg, EngineConfig(n_shards=2, placement="scatter"))
    state2, t0 = sp2.load(ck)
    _, raster2b, _ = sp2.run(state2, t0, STEPS2)
    sig_b = observables.raster_signature(np.asarray(raster2b),
                                         np.asarray(sp2.plan.gid))

    assert sig_a == sig_b, "elastic restart changed the spike raster!"
    print("phase 2: identical rasters on 4-shard continue vs 2-shard "
          f"scatter restart  (sha256 {sig_a.hex()[:16]}...)  OK")


def jax_tree_to_host(tree):
    import jax
    return jax.tree.map(np.asarray, tree)


if __name__ == "__main__":
    main()
