"""Train a reduced LM config end-to-end on the synthetic pipeline with the
full substrate: WSD schedule, grad clipping, fault-tolerant trainer with
checkpoints (kill it mid-run and re-run: it resumes).

  PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b --steps 120
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_smoke_config
from repro.data import pipeline
from repro.models import lm
from repro.optim import schedules
from repro.train import step as step_mod
from repro.train.train_state import create
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="ckpt_lm_demo")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"arch {args.arch} (reduced): d={cfg.d_model} L={cfg.n_layers} "
          f"V={cfg.vocab_size}")
    params = lm.init_params(cfg, jax.random.key(0))
    print(f"params: {lm.param_count(params)/1e6:.1f}M")

    state = create(params)
    step = step_mod.make_train_step(
        cfg, lr_schedule=schedules.wsd(3e-4, warmup=20, stable=60,
                                       decay=40),
        grad_clip=1.0)
    tr = Trainer(step, state, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                 log_every=10)
    start = tr.maybe_resume()

    data = iter(pipeline.prefetch(iter(pipeline.Batcher(
        cfg, args.batch, args.seq, seed=1, start_index=start))))
    out = tr.run(data, args.steps - start)
    print("done:", out)
    h = tr.history
    if len(h) > 20:
        print(f"loss first5 {sum(h[:5])/5:.3f} -> last5 "
              f"{sum(h[-5:])/5:.3f} (must decrease)")


if __name__ == "__main__":
    main()
