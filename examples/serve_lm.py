"""Serve a small model with batched requests through the serving engine
(prefill + KV-cache decode, static-shape batching with refill rounds).

  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch=args.batch, s_max=64)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 16)).astype(
                        np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]

    t0 = time.time()
    done = eng.run(reqs)
    wall = time.time() - t0
    n_tok = sum(r.out.shape[0] for r in done)
    print(f"served {len(done)} requests, {n_tok} new tokens in "
          f"{wall:.2f}s ({n_tok/wall:.1f} tok/s)")
    for i, r in enumerate(done[:3]):
        print(f"req{i}: prompt[:6]={r.prompt[:6].tolist()} -> "
              f"out={r.out.tolist()}")


if __name__ == "__main__":
    main()
