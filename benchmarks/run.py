"""Benchmark orchestrator (back-compat entry): delegates to the
`repro.bench` CLI.

  python -m benchmarks.run            # full (CPU-sized) suite
  python -m benchmarks.run --quick    # CI-sized

Prefer `python -m repro.bench run|compare|list` directly — it also writes
machine-readable BENCH_<name>.json reports and gates against the
committed baselines under benchmarks/baselines/.
"""
from __future__ import annotations

import argparse
import sys

from repro.bench import cli, registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-scaling", action="store_true",
                    help="skip the subprocess scaling points")
    ap.add_argument("--out", default=cli.DEFAULT_OUT)
    args = ap.parse_args()

    names = registry.default_names(include_slow=not args.skip_scaling)
    argv = ["run", "--out", args.out] + (["--quick"] if args.quick else []) \
        + names
    sys.exit(cli.main(argv))


if __name__ == "__main__":
    main()
