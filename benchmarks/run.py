"""Benchmark orchestrator: one benchmark per paper table/figure + the
framework-side LM micro-benchmarks + the roofline report (if dry-run
results exist).

  python -m benchmarks.run            # full (CPU-sized) suite
  python -m benchmarks.run --quick    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-scaling", action="store_true",
                    help="skip the subprocess scaling points")
    args = ap.parse_args()

    results = {}
    failures = []

    def section(name, fn):
        print(f"\n===== {name} =====", flush=True)
        try:
            results[name] = fn()
        except Exception as e:
            failures.append(name)
            print(f"[run] {name} FAILED: {e}", flush=True)
            traceback.print_exc()

    from . import (event_vs_dense, lm_throughput, roofline, scaling,
                   table1, table2)

    section("table1_sizes_and_rates",
            lambda: table1.bench(quick=args.quick))
    section("table2_phase_breakdown",
            lambda: table2.bench(quick=args.quick))
    section("event_vs_dense_delivery",
            lambda: event_vs_dense.bench(quick=args.quick))
    if not args.skip_scaling:
        section("fig3_1_strong_scaling",
                lambda: scaling.strong_scaling(quick=args.quick))
        section("fig3_2_weak_scaling",
                lambda: scaling.weak_scaling(quick=args.quick))
    section("lm_throughput", lambda: lm_throughput.bench(quick=args.quick))
    section("roofline_report", lambda: roofline.report())

    print("\n===== summary =====")
    print(json.dumps({k: ("ok" if k in results else "fail")
                      for k in results}, indent=1))
    if failures:
        print(f"FAILURES: {failures}")
        sys.exit(1)
    print("all benchmarks completed")


if __name__ == "__main__":
    main()
