"""Thin entry for the scaling suite (paper Figures 3-1 / 3-2); the
implementation lives in `repro.bench.suites.scaling`.

  python -m benchmarks.scaling --quick [--strong-only|--weak-only]
"""
from __future__ import annotations

from repro.bench.suites.scaling import (run_suite, strong_scaling,
                                        weak_scaling)

__all__ = ["run_suite", "strong_scaling", "weak_scaling"]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid/steps/H for the CI smoke check")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--strong-only", action="store_true")
    mode.add_argument("--weak-only", action="store_true")
    args = ap.parse_args()
    if not args.weak_only:
        strong_scaling(quick=args.quick)
    if not args.strong_only:
        weak_scaling(quick=args.quick)
