"""LM-side micro-benchmarks: train tokens/s and decode tokens/s on CPU for
a reduced config (the framework half of the system; TPU projections come
from the roofline, not from CPU wall-time)."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import pipeline
from repro.models import lm
from repro.optim import schedules
from repro.train import step as step_mod
from repro.train.train_state import create


def bench(arch: str = "qwen3-0.6b", steps: int = 10, batch: int = 8,
          seq: int = 128, quick: bool = False):
    if quick:
        steps, batch, seq = 5, 4, 64
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    state = create(params)
    step = jax.jit(step_mod.make_train_step(
        cfg, lr_schedule=schedules.cosine(3e-4, 10, 1000)))
    data = iter(pipeline.Batcher(cfg, batch, seq, seed=1))

    b = next(data)
    state, m = step(state, b)                   # compile
    jax.block_until_ready(m["loss"])
    t0 = time.time()
    for _ in range(steps):
        state, m = step(state, next(data))
    jax.block_until_ready(m["loss"])
    wall = time.time() - t0
    row = dict(kind="train", arch=arch, steps=steps,
               tokens_per_s=int(steps * batch * seq / wall),
               wall_s=round(wall, 2), final_loss=round(float(m["loss"]), 3))
    print("[lm]", json.dumps(row), flush=True)

    # decode throughput
    cache = lm.init_cache(cfg, batch, 64)
    dstep = jax.jit(lambda c, t: lm.decode_step(cfg, params, c, t))
    tok = jnp.ones((batch, 1), jnp.int32)
    _, cache = dstep(cache, tok)               # compile
    t0 = time.time()
    n = 20 if quick else 50
    for _ in range(n):
        lg, cache = dstep(cache, tok)
        tok = lg.argmax(-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    wall = time.time() - t0
    row2 = dict(kind="decode", arch=arch,
                tokens_per_s=int(n * batch / wall), wall_s=round(wall, 2))
    print("[lm]", json.dumps(row2), flush=True)
    return [row, row2]


if __name__ == "__main__":
    bench()
