"""Thin entry for the LM train/decode micro-benchmarks; the implementation
lives in `repro.bench.suites.lm_throughput`."""
from repro.bench.suites.lm_throughput import bench, run_suite

__all__ = ["bench", "run_suite"]

if __name__ == "__main__":
    bench()
