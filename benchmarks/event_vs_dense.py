"""Beyond-paper ablation: dense O(E) masked delivery vs event-driven
O(spikes x fan) delivery, across activity regimes.

The paper's model is event-driven (on a CPU cluster that is the only
sensible choice); the dense formulation is the TPU-idiomatic one.  This
benchmark measures the CPU wall-clock crossover by varying the thalamic
drive (lower stim -> sparser activity -> event backend advantage grows).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import EngineConfig, GridConfig, observables
from repro.core import engine as E
from repro.core import event_engine as EV


def bench(quick: bool = False):
    npc = 250 if quick else 500
    steps = 100 if quick else 200
    rows = []
    for stim in (1, 0):          # events/ms/column: normal vs silent-ish
        cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=npc,
                         synapses_per_neuron=50, seed=5,
                         stim_events_per_ms_per_column=stim)
        eng = EngineConfig(n_shards=1)

        spec, plan, dstate = E.build(cfg, eng)
        run_d = jax.jit(lambda s: E.run(spec, plan, s, 0, steps))
        _, raster_d, _ = run_d(dstate)
        jax.block_until_ready(raster_d)
        t0 = time.time()
        _, raster_d, _ = run_d(dstate)
        jax.block_until_ready(raster_d)
        dense_s = time.time() - t0

        spec2, plan2, eplan, estate = EV.build(cfg, eng)
        run_e = jax.jit(lambda s: EV.run(spec2, plan2, eplan, s, 0, steps))
        _, raster_e = run_e(estate)
        jax.block_until_ready(raster_e)
        t0 = time.time()
        st2, raster_e = run_e(estate)
        jax.block_until_ready(raster_e)
        event_s = time.time() - t0

        sig_d = observables.raster_signature(np.asarray(raster_d),
                                             np.asarray(plan.gid))
        sig_e = observables.raster_signature(np.asarray(raster_e),
                                             np.asarray(plan2.gid))
        rate = observables.mean_rate_hz(np.asarray(raster_d),
                                        cfg.n_neurons)
        row = dict(stim_per_ms=stim, rate_hz=round(rate, 1),
                   dense_s=round(dense_s, 3), event_s=round(event_s, 3),
                   speedup=round(dense_s / max(event_s, 1e-9), 2),
                   identical_rasters=bool(sig_d == sig_e),
                   saturated=int(np.asarray(st2.sat).sum()))
        rows.append(row)
        print("[event_vs_dense]", json.dumps(row), flush=True)
    return rows


if __name__ == "__main__":
    bench()
