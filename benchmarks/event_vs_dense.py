"""Thin entry for the dense-vs-event delivery ablation; the implementation
lives in `repro.bench.suites.event_vs_dense`."""
from repro.bench.suites.event_vs_dense import bench, run_suite

__all__ = ["bench", "run_suite"]

if __name__ == "__main__":
    bench()
