"""Back-compat shim: the benchmark utilities live in the installable
package now (`repro.bench.subproc` / `repro.bench.timing`).  The old
sys.path bootstrap is gone — install with `pip install -e .`, or run
uninstalled with `PYTHONPATH=src` (pytest alone bootstraps sys.path via
tests/conftest.py)."""
from repro.bench.subproc import SRC, run_subprocess
from repro.bench.timing import Timer

__all__ = ["SRC", "run_subprocess", "Timer"]
