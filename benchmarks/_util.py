import os
import subprocess
import sys
import time

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro._flags import subprocess_env


def run_subprocess(code: str, n_devices: int = 1, timeout: int = 1800,
                   extra_env=None) -> str:
    """Run `code` in a fresh interpreter with n host devices (jax locks the
    device count at first init, so scaling points need fresh processes —
    this is also what makes the measurement honest: each point pays full
    startup, like an MPI job)."""
    env = subprocess_env(n_devices, SRC)
    env.update(extra_env or {})
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{out.stdout}\n"
                           f"{out.stderr}")
    return out.stdout


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
