"""Thin entry for the roofline report; the implementation lives in
`repro.bench.suites.roofline`."""
from repro.bench.suites.roofline import (load_records, model_flops,
                                         model_params, report, run_suite)

__all__ = ["load_records", "model_flops", "model_params", "report",
           "run_suite"]

if __name__ == "__main__":
    report()
