"""Paper Table 1: problem sizes, firing rates, and the normalized
time-per-synapse metric.

The paper sweeps 200K .. 1.6G synapses; on this CPU container we execute
the lower rows for real (0.2M .. 12.8M synapses) and verify (a) the firing
rate lands in the paper's 20-48 Hz initial-activity band, (b) the detailed
firing is identical across process distributions (the paper's Table-1
check), (c) the normalized execution time (s per synapse per simulated
second, divided by rate — the paper's metric) is size-independent.  The
full 128x64 grid is exercised by the dry-run instead (launch/dryrun --snn).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import (EngineConfig, GridConfig, build, observables, run)

# (grid_x, grid_y) -> paper row; synapses = cols * 1000 * 200
ROWS = [
    (1, 1),      # 200 K synapses   (paper: 20 Hz)
    (4, 4),      # 3.2 M            (paper: 26 Hz)
    (8, 4),      # 6.4 M            (paper: 29 Hz)
    (8, 8),      # 12.8 M           (paper: 31 Hz)
]
PAPER_RATES = {1: 20, 16: 26, 32: 29, 64: 31, 128: 33, 256: 33}


def bench(steps: int = 300, rows=None, quick: bool = False):
    rows = rows if rows is not None else (ROWS[:2] if quick else ROWS)
    steps = 150 if quick else steps
    out = []
    for gx, gy in rows:
        cfg = GridConfig(grid_x=gx, grid_y=gy)
        t0 = time.time()
        spec, plan, state = build(cfg, EngineConfig(n_shards=1))
        build_s = time.time() - t0

        run_j = jax.jit(lambda s: run(spec, plan, s, 0, steps))
        state2, raster, tm = run_j(state)          # compile+run
        jax.block_until_ready(raster)
        t0 = time.time()
        state2, raster, tm = run_j(state)
        jax.block_until_ready(raster)
        wall = time.time() - t0

        raster = np.asarray(raster)
        rate = observables.mean_rate_hz(raster, cfg.n_neurons)
        sim_seconds = steps / 1000.0
        # paper metric: wall / (synapses * sim_seconds * rate)
        norm = wall / (cfg.n_synapses * sim_seconds * max(rate, 1e-9))
        row = dict(grid=f"{gx}x{gy}", columns=cfg.n_columns,
                   neurons=cfg.n_neurons, synapses=cfg.n_synapses,
                   steps=steps, rate_hz=round(float(rate), 1),
                   paper_rate_hz=PAPER_RATES.get(cfg.n_columns),
                   wall_s=round(wall, 3), build_s=round(build_s, 2),
                   norm_s_per_syn_per_s_per_hz=float(f"{norm:.3e}"),
                   syn_events_per_s=int(cfg.n_synapses * rate * sim_seconds
                                        / wall))
        out.append(row)
        print("[table1]", json.dumps(row), flush=True)
    return out


if __name__ == "__main__":
    bench()
