"""Thin entry for the paper-Table-1 suite; the implementation lives in
`repro.bench.suites.table1`."""
from repro.bench.suites.table1 import PAPER_RATES, ROWS, bench, run_suite

__all__ = ["PAPER_RATES", "ROWS", "bench", "run_suite"]

if __name__ == "__main__":
    bench()
