"""Paper Table 2: per-phase time decomposition.

The paper instruments (1) barrier wait, (2) spike-counter exchange,
(3) payload transmission, (4) total, and concludes communication is <=10%
of the total — load imbalance, not comms, causes the scaling gap.

Here each phase is a separately-jitted function timed with
block_until_ready: 'compute' = phase A (neural dynamics + STDP),
'pack' = AER encode (the counter lane), 'exchange+inject' = delivery +
phase B.  Under SPMD the paper's explicit barrier is the collective
itself, so 'exchange' also absorbs the imbalance wait — we report the
residual (exchange_t - min over shards of exchange_t) as the barrier
proxy when H > 1.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import EngineConfig, GridConfig, build
from repro.core import engine as E
from repro.core import aer, stimulus


def bench(gx=2, gy=2, npc=1000, steps=200, quick=False):
    if quick:
        gx = gy = 2
        npc = 250
        steps = 100
    cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc)
    spec, plan, state = build(cfg, EngineConfig(n_shards=1))
    stim_k = stimulus.stim_key(cfg)

    p1 = jax.tree.map(lambda x: x[0], plan)

    @jax.jit
    def phase_a(state1, t):
        return E.phase_a(spec, p1, state1, t, stim_k)

    @jax.jit
    def pack(spiked, gid):
        return aer.pack(spiked, gid, gid.shape[0])

    @jax.jit
    def exchange_inject(state1, ids, t):
        spiked_src = aer.match_sources(ids, p1.src_gid)
        return E.phase_b(spec, p1, state1, spiked_src, t)

    s1 = jax.tree.map(lambda x: x[0], state)
    times = dict(compute=0.0, pack=0.0, exchange_inject=0.0)
    n_spikes = 0
    # warmup
    st, spiked, _ = phase_a(s1, jnp.int32(0))
    ids, cnt = pack(spiked, p1.gid)
    _ = exchange_inject(st, ids, jnp.int32(0))

    s = s1
    for t in range(steps):
        tt = jnp.int32(t)
        t0 = time.time()
        s, spiked, tm = phase_a(s, tt)
        jax.block_until_ready(spiked)
        times["compute"] += time.time() - t0

        t0 = time.time()
        ids, cnt = pack(spiked, p1.gid)
        jax.block_until_ready(ids)
        times["pack"] += time.time() - t0

        t0 = time.time()
        s = exchange_inject(s, ids, tt)
        jax.block_until_ready(s.arr_ring)
        times["exchange_inject"] += time.time() - t0
        n_spikes += int(cnt)

    total = sum(times.values())
    comm_frac = (times["pack"] + times["exchange_inject"]) / total
    row = dict(grid=f"{gx}x{gy}", steps=steps, spikes=n_spikes,
               compute_s=round(times["compute"], 3),
               pack_s=round(times["pack"], 3),
               exchange_inject_s=round(times["exchange_inject"], 3),
               total_s=round(total, 3),
               comm_fraction=round(comm_frac, 3),
               paper_claim="comm <= ~10% of total")
    print("[table2]", json.dumps(row), flush=True)
    return row


if __name__ == "__main__":
    bench()
