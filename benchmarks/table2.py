"""Thin entry for the paper-Table-2 per-phase split; the implementation
lives in `repro.bench.suites.table2` (a projection of the general
`repro.bench.profile` matrix onto H=1)."""
from repro.bench.suites.table2 import bench, run_suite

__all__ = ["bench", "run_suite"]

if __name__ == "__main__":
    bench()
