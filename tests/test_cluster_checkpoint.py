"""Checkpoint elasticity across the process axis.

The checkpoint format is layout-free (neuron state keyed by gid, synapses
by the canonical (tgt, src, j) triple), so a state saved by the
single-process engine must restore into a 2-process x 2-shard cluster job
and continue with a bit-identical raster."""
import numpy as np
import pytest

from _cluster_helpers import require_cluster
from repro.cluster import cli
from repro.core import (EngineConfig, GridConfig, build, checkpoint,
                        observables, run)

pytestmark = pytest.mark.slow

CFG = dict(grid_x=2, grid_y=2, neurons_per_column=50,
           synapses_per_neuron=20, seed=11)
T_SAVE, T_CONT = 40, 40


def test_checkpoint_restores_across_processes(tmp_path):
    require_cluster()
    cfg = GridConfig(**CFG)
    spec, plan, state = build(cfg, EngineConfig(n_shards=4))

    # single-process: run, save at t=T_SAVE, continue for the reference
    state, _, _ = run(spec, plan, state, 0, T_SAVE)
    ckpt = str(tmp_path / f"ckpt_{T_SAVE}.npz")
    checkpoint.save(ckpt, spec, plan, state, T_SAVE)
    _, raster_cont, _ = run(spec, plan, state, T_SAVE, T_CONT)
    ref_sig = observables.raster_signature(
        np.asarray(raster_cont), np.asarray(plan.gid)).hex()

    # cluster: restore the same checkpoint at 2 processes x 2 shards
    args = cli.workload_namespace(
        grid="2x2", neurons_per_column=CFG["neurons_per_column"],
        synapses=CFG["synapses_per_neuron"], seed=CFG["seed"],
        steps=T_CONT, shards=4, ckpt=ckpt)
    row = cli.run_point(args, nprocs=2, timeout=600)

    assert row["t0"] == T_SAVE, "worker must resume at the saved t"
    assert row["raster_sig"] == ref_sig, \
        "continuation raster differs after cross-process restore"
