"""SNN dry-run path: repro.launch.dryrun's abstract lower->compile
pipeline (including the shard build in `_snn_abstract`, the bug-fixed
one-shard shape probe) on a small 8-device mesh.

Importing repro.launch.dryrun must NOT force 512 host devices — that only
happens under `python -m repro.launch.dryrun` — so this test both covers
the SNN cell and pins the import-side-effect contract."""
import pytest

from _mp_helpers import run_with_devices

_CODE = """
import jax
assert len(jax.devices()) == 8, jax.devices()

from repro.core import EngineConfig, GridConfig
from repro.dist.compat import make_mesh
from repro.launch import dryrun, hlo_cost

# importing dryrun must not have re-forced the device count
assert len(jax.devices()) == 8, 'dryrun import changed jax device state'

cfg = GridConfig(grid_x=4, grid_y=2, neurons_per_column=60,
                 synapses_per_neuron=20)
eng = EngineConfig(n_shards=8, exchange='halo')
spec, plan, state = dryrun._snn_abstract(cfg, eng)
mesh = make_mesh((8,), ('cells',))
_, lowered = dryrun._snn_lower(spec, mesh, plan, state)
compiled = lowered.compile()
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0
parsed = hlo_cost.analyze(compiled.as_text())
# the SNN step is elementwise+gather (no dots), so no FLOP assertion;
# the halo exchange must move collective-permute bytes every step
assert parsed['bytes'] > 0
assert parsed['collectives']['total'] > 0, parsed['collectives']
print('DRYRUN_SNN OK', parsed['collectives']['total'])
"""


@pytest.mark.slow
def test_snn_dryrun_small_mesh():
    out = run_with_devices(_CODE, 8, timeout=900)
    assert "DRYRUN_SNN OK" in out
