"""Helpers for tests that need multiple (host-platform) devices.

jax locks the device count at first init, so multi-device checks run in a
subprocess with XLA_FLAGS set; the parent process keeps its single device.
"""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(code: str, n_devices: int, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"subprocess failed:\nSTDOUT:\n{out.stdout}\n"
                           f"STDERR:\n{out.stderr}")
    return out.stdout
