"""Helpers for tests that need multiple (host-platform) devices.

jax locks the device count at first init, so multi-device checks run in a
subprocess with XLA_FLAGS set; the parent process keeps its single device.
"""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro._flags import subprocess_env


def run_with_devices(code: str, n_devices: int, timeout: int = 600) -> str:
    env = subprocess_env(n_devices, SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"subprocess failed:\nSTDOUT:\n{out.stdout}\n"
                           f"STDERR:\n{out.stderr}")
    return out.stdout
