"""Helpers for tests that need multiple (host-platform) devices.

jax locks the device count at first init, so multi-device checks run in a
subprocess with XLA_FLAGS set; the parent process keeps its single device.
Env construction and execution are delegated to `repro.bench.subproc` so
tests, benchmarks and the cluster launcher share one implementation
(coordinator vars + last-flag-wins XLA_FLAGS appending cannot drift).
"""
import os
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.bench.subproc import SubprocessError, run_subprocess  # noqa: F401


def run_with_devices(code: str, n_devices: int, timeout: float = 600) -> str:
    return run_subprocess(code, n_devices, timeout=timeout)
