"""`core.StepProgram` facade: the pipelined exchange schedule must be
bit-identical to sync (rasters AND weights) for every delivery backend,
exchange wire and shard count; the hierarchical exchange must reproduce
allgather; and the deprecated quartet entry points must warn and
delegate to the same machinery."""
import warnings

import numpy as np
import pytest

import repro.core as core
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D

from _mp_helpers import run_with_devices

CFG = GridConfig(grid_x=2, grid_y=2, neurons_per_column=60,
                 synapses_per_neuron=24, seed=9)


# ---------------------------------------------------------------------------
# sync vs pipelined bit-identity, real shard_map at H in {1, 2, 4}
# ---------------------------------------------------------------------------

_SCHED_CODE = """
import numpy as np
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D

cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=60,
                 synapses_per_neuron=24, seed=9)
STEPS = 60
for exchange in ("halo", "allgather"):
    for H in (1, 2, 4):
        outs = {{}}
        for sched in ("sync", "pipelined"):
            eng = EngineConfig(n_shards=H, exchange=exchange,
                               delivery={delivery!r},
                               exchange_schedule=sched)
            sp = StepProgram(cfg, eng, mesh=D.make_mesh(H))
            state = sp.place(sp.init_state())
            state, raster, _ = sp.run(state, 0, STEPS)
            w = state.w if {delivery!r} == "dense" else state.base.w
            outs[sched] = (
                observables.raster_signature(np.asarray(raster),
                                             np.asarray(sp.plan.gid)),
                np.asarray(w))
        sig_s, w_s = outs["sync"]
        sig_p, w_p = outs["pipelined"]
        assert sig_s == sig_p, \\
            f"raster differs: {delivery!r} {{exchange}} H={{H}}"
        assert np.array_equal(w_s, w_p), \\
            f"weights differ: {delivery!r} {{exchange}} H={{H}}"
print("SCHED OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("delivery", ["dense", "event"])
def test_pipelined_bit_identical_to_sync(delivery):
    """Rasters AND weights must bit-match between schedules over
    H in {1,2,4} x {halo,allgather} — a schedule is an execution layout,
    never physics (the ISSUE's headline acceptance gate)."""
    out = run_with_devices(_SCHED_CODE.format(delivery=delivery), 4,
                           timeout=900)
    assert "SCHED OK" in out


# ---------------------------------------------------------------------------
# hierarchical exchange == allgather, and under both schedules
# ---------------------------------------------------------------------------

_HIER_CODE = """
import numpy as np
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D

cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=60,
                 synapses_per_neuron=24, seed=9)
sigs = {}
for exchange, sched in (("allgather", "sync"), ("hier", "sync"),
                        ("hier", "pipelined")):
    eng = EngineConfig(n_shards=4, exchange=exchange,
                       exchange_schedule=sched)
    sp = StepProgram(cfg, eng, mesh=D.make_mesh(4),
                     hier_groups=2 if exchange == "hier" else None)
    state = sp.place(sp.init_state())
    _, raster, _ = sp.run(state, 0, 60)
    sigs[(exchange, sched)] = observables.raster_signature(
        np.asarray(raster), np.asarray(sp.plan.gid))
assert len(set(sigs.values())) == 1, sigs
print("HIER OK")
"""


@pytest.mark.slow
def test_hier_exchange_matches_allgather():
    """The two-level exchange (intra-group gather + inter-group
    neighbourhood ppermute, emulated via hier_groups=2 in one process)
    must reproduce the flat allgather raster, under both schedules."""
    out = run_with_devices(_HIER_CODE, 4, timeout=900)
    assert "HIER OK" in out


# ---------------------------------------------------------------------------
# single-device (vmap) schedule identity — runs in the tier-1 parent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("exchange", ["allgather", "halo"])
def test_time_phases_schedule_identity_vmap(exchange):
    """`time_phases` must produce identical rasters/counters (and final
    weights) under both schedules on the logical-shard path too."""
    outs = {}
    for sched in ("sync", "pipelined"):
        eng = EngineConfig(n_shards=2, exchange=exchange,
                           exchange_schedule=sched)
        sp = StepProgram(CFG, eng)
        s, times, rasters, counts = sp.time_phases(
            sp.init_state(), 0, 40, collect_rasters=True)
        assert set(times) == {"phase_a_s", "exchange_s", "phase_b_s"}
        outs[sched] = (np.stack(rasters), counts, np.asarray(s.w))
    r_s, c_s, w_s = outs["sync"]
    r_p, c_p, w_p = outs["pipelined"]
    assert np.array_equal(r_s, r_p)
    assert c_s == c_p
    assert np.array_equal(w_s, w_p)


def test_unknown_schedule_rejected():
    eng = EngineConfig(n_shards=2, exchange_schedule="bogus")
    sp = StepProgram(CFG, eng, mesh=None)
    with pytest.raises(ValueError, match="exchange_schedule"):
        D.make_run_program(sp.spec, sp.plan, D.make_mesh(1))


# ---------------------------------------------------------------------------
# deprecation shims: warn AND delegate
# ---------------------------------------------------------------------------

class TestDeprecatedShims:
    def test_build_delivery_warns_and_delegates(self):
        eng = EngineConfig(n_shards=2)
        with pytest.warns(DeprecationWarning, match="StepProgram"):
            spec, plan, eplan, state, cap_ev = core.build_delivery(CFG, eng)
        assert eplan is None and cap_ev is None
        sp = StepProgram(CFG, eng)
        assert np.array_equal(np.asarray(plan.gid), np.asarray(sp.plan.gid))
        assert np.array_equal(np.asarray(state.w),
                              np.asarray(sp.init_state().w))

    def test_run_delivery_warns_and_matches_step_program(self):
        eng = EngineConfig(n_shards=2)
        sp = StepProgram(CFG, eng)
        _, raster_new, _ = sp.run(sp.init_state(), 0, 30)
        with pytest.warns(DeprecationWarning, match="StepProgram"):
            _, raster_old, _ = core.run_delivery(
                sp.spec, sp.plan, None, sp.init_state(), 0, 30)
        assert np.array_equal(np.asarray(raster_old),
                              np.asarray(raster_new))

    def test_event_build_delivery_roundtrip(self):
        eng = EngineConfig(n_shards=2, delivery="event")
        with pytest.warns(DeprecationWarning):
            spec, plan, eplan, state, cap_ev = core.build_delivery(CFG, eng)
        assert eplan is not None and cap_ev == state.ev_ring.shape[-1]
        with pytest.warns(DeprecationWarning):
            _, raster_old, _ = core.run_delivery(spec, plan, eplan, state,
                                                 0, 30)
        _, raster_new, _ = StepProgram.from_parts(
            spec, plan, eplan).run(state, 0, 30)
        assert np.array_equal(np.asarray(raster_old),
                              np.asarray(raster_new))

    def test_make_sharded_run_warns_and_delegates(self):
        eng = EngineConfig(n_shards=1)
        sp = StepProgram(CFG, eng)
        mesh = D.make_mesh(1)
        with pytest.warns(DeprecationWarning, match="StepProgram"):
            runner = D.make_sharded_run(sp.spec, sp.plan, mesh)
        _, raster_old, _ = runner(sp.init_state(), 0, 30)
        _, raster_new, _ = StepProgram.from_parts(
            sp.spec, sp.plan, mesh=mesh).run(sp.init_state(), 0, 30)
        assert np.array_equal(np.asarray(raster_old),
                              np.asarray(raster_new))

    def test_make_phase_fns_warns_and_returns_triple(self):
        eng = EngineConfig(n_shards=1)
        sp = StepProgram(CFG, eng)
        mesh = D.make_mesh(1)
        with pytest.warns(DeprecationWarning, match="StepProgram"):
            pa, ex, pb = D.make_phase_fns(sp.spec, sp.plan, mesh)
        state = sp.init_state()
        s2, spiked, _ = pa(state, 0)
        s3 = pb(s2, ex(spiked), 0)
        assert np.asarray(s3.v).shape == np.asarray(state.v).shape

    def test_no_warning_on_step_program_itself(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            sp = StepProgram(CFG, EngineConfig(n_shards=2))
            sp.run(sp.init_state(), 0, 5)
