"""Supervised recovery across real processes: every injected fault class
recovers within its restart budget and the recovered run's final raster
AND weight signatures are bit-identical to the fault-free single-process
reference — the paper's Table 1 invariant extended along the failure
axis.

Probe-gated like every cluster test (tests/_cluster_helpers): skipped on
platforms that cannot run a live 2-process jax job.  Faults are injected
deterministically via the `repro.cluster.faults` grammar on the FIRST
attempt only, so each scenario is a reproducible test case:

  crash         worker 1 hard-exits at the step-20 chunk boundary; the
                gang is reaped and relaunched; the workers self-resume
                from the epoch at t=20 (nothing replayed).
  hang          worker 1 blocks forever; no process exits, so only the
                beacon stall detector can catch it (a short stall budget
                keeps the test fast).
  slow          worker 1 straggles 400 ms once; the supervisor must NOT
                restart — stragglers are not failures.
  corrupt_ckpt  the epoch at t=20 is truncated on disk after writing;
                recovery must detect the bad sha256 and fall back to the
                t=10 epoch (one period of replay, zero bit drift).
  drop_result   the run completes but worker 0 never reports; the retry
                resumes from the final epoch and replays nothing.
"""
import pytest

from _cluster_helpers import require_cluster

from repro.cluster import cli, local

WORKLOAD = dict(grid="2x2", neurons_per_column=20, synapses=10, seed=7,
                steps=40, shards=2, phase_steps=0)
CKPT_EVERY = 10


@pytest.fixture(scope="module")
def reference():
    """Fault-free single-process (raster, weights) ground truth."""
    return cli.reference_signatures(cli.workload_namespace(**WORKLOAD))


def _supervised(tmp_path, fault, stall_timeout=90.0, max_restarts=2):
    args = cli.workload_namespace(
        **WORKLOAD, ckpt_dir=str(tmp_path / "epochs"),
        ckpt_every=CKPT_EVERY, supervise=True, fault=fault,
        max_restarts=max_restarts, stall_timeout=stall_timeout)
    return cli.run_point(args, nprocs=2, timeout=600)


@pytest.mark.parametrize("fault,min_restarts,restored_t", [
    ("crash@step=20:rank=1", 1, 20),
    ("slow@step=20:ms=400", 0, None),
    ("corrupt_ckpt@step=20", 1, 10),     # bad epoch 20 -> fall back to 10
    ("drop_result@rank=0", 1, 40),       # resume at t_end, replay nothing
], ids=["crash", "slow", "corrupt_ckpt", "drop_result"])
def test_fault_recovers_bit_identical(tmp_path, reference, fault,
                                      min_restarts, restored_t):
    require_cluster()
    row = _supervised(tmp_path, fault)
    ref_raster, ref_weights = reference
    assert row["raster_sig"] == ref_raster
    assert row["weights_sig"] == ref_weights
    rec = row["recovery"]
    assert rec["restarts"] >= min_restarts
    if min_restarts == 0:
        assert rec["restarts"] == 0 and not rec["restored"]
    else:
        assert rec["restored"] and rec["restored_t"] == restored_t
        assert rec["recovered_steps"] == restored_t
        assert rec["attempt"] == rec["restarts"]
        assert len(rec["attempts"]) == rec["restarts"]


def test_hang_caught_by_stall_detector_not_deadline(tmp_path, reference):
    """The blunt launch deadline stays huge; only beacon-progress stall
    detection can catch a hung worker in time."""
    require_cluster()
    row = _supervised(tmp_path, "hang@step=20:rank=1", stall_timeout=30.0)
    ref_raster, ref_weights = reference
    assert row["raster_sig"] == ref_raster
    assert row["weights_sig"] == ref_weights
    rec = row["recovery"]
    assert rec["restarts"] >= 1 and rec["restored_t"] == 20
    assert any("stalled" in a["reason"] for a in rec["attempts"])


def test_unsupervised_run_unchanged_by_ckpt_machinery(tmp_path, reference):
    """Periodic checkpointing alone (no supervision, no faults) must not
    change a single output bit — chunked == unchunked."""
    require_cluster()
    args = cli.workload_namespace(
        **WORKLOAD, ckpt_dir=str(tmp_path / "epochs"),
        ckpt_every=CKPT_EVERY)
    row = cli.run_point(args, nprocs=2, timeout=600)
    ref_raster, ref_weights = reference
    assert row["raster_sig"] == ref_raster
    assert row["weights_sig"] == ref_weights
    assert row["n_ckpts"] == WORKLOAD["steps"] // CKPT_EVERY
    assert row["recovery"]["restarts"] == 0


def test_budget_exhaustion_with_real_workers(tmp_path):
    """A crash re-armed on EVERY attempt (ambient env, no supervisor
    disarm possible -> simulate by crashing at step 0 with ckpt off, so
    every retry re-dies) exhausts the budget with full history."""
    require_cluster()
    args = cli.workload_namespace(**WORKLOAD)
    cmd = ["-m", "repro.cluster.worker",
           *__import__("repro.cluster.worker", fromlist=["workload_argv"]
                       ).workload_argv(args)]
    with pytest.raises(local.LaunchError) as ei:
        # fault fires at step 0 before any epoch exists; with
        # max_restarts=0 the very first death exhausts the budget
        local.supervised_launch(cmd, nprocs=2, devices_per_proc=1,
                                timeout=600, stall_timeout=90.0,
                                max_restarts=0, backoff_s=0.01,
                                fault="crash@step=0:rank=0")
    err = ei.value
    assert "restart budget exhausted" in str(err)
    assert len(err.attempts) == 1
    assert 41 in err.attempts[0]["returncodes"]   # EXIT_CRASH
