"""Checkpoint/restart and ELASTIC resharding: a checkpoint saved at H shards
must restore at H' shards / another placement and continue bit-identically."""
import os

import numpy as np
import pytest

from repro.core import (EngineConfig, GridConfig, build, checkpoint,
                        observables, run)

CFG = GridConfig(grid_x=2, grid_y=2, neurons_per_column=80,
                 synapses_per_neuron=30, seed=13)


def _run_and_ckpt(tmp_path, eng, steps1):
    spec, plan, state = build(CFG, eng)
    state, _, _ = run(spec, plan, state, 0, steps1)
    path = os.path.join(str(tmp_path), f"ckpt_{steps1}.npz")
    checkpoint.save(path, spec, plan, state, steps1)
    return path


def _continue_from(path, eng, t0, steps2):
    spec, plan, _ = build(CFG, eng)
    state, t = checkpoint.load(path, spec, plan)
    assert t == t0
    _, raster, _ = run(spec, plan, state, t, steps2)
    return observables.raster_signature(np.asarray(raster),
                                        np.asarray(plan.gid))


def test_restart_bit_identical(tmp_path):
    """run(0..60) == run(0..30) + restart(30..60) on the same layout."""
    eng = EngineConfig(n_shards=2)
    spec, plan, state = build(CFG, eng)
    _, raster_full, _ = run(spec, plan, state, 0, 60)
    sig_tail = observables.raster_signature(
        np.asarray(raster_full)[30:], np.asarray(plan.gid))

    path = _run_and_ckpt(tmp_path, eng, 30)
    assert _continue_from(path, eng, 30, 30) == sig_tail


@pytest.mark.parametrize("eng2", [
    EngineConfig(n_shards=1),
    EngineConfig(n_shards=4),
    EngineConfig(n_shards=3),
    EngineConfig(n_shards=4, placement="scatter"),
])
def test_elastic_reshard(tmp_path, eng2):
    """checkpoint at H=2/block, restore at a different layout: same spikes."""
    eng1 = EngineConfig(n_shards=2)
    spec, plan, state = build(CFG, eng1)
    _, raster_full, _ = run(spec, plan, state, 0, 60)
    sig_tail = observables.raster_signature(
        np.asarray(raster_full)[30:], np.asarray(plan.gid))

    path = _run_and_ckpt(tmp_path, eng1, 30)
    assert _continue_from(path, eng2, 30, 30) == sig_tail


def test_latest_discovery(tmp_path):
    eng = EngineConfig(n_shards=1)
    assert checkpoint.latest(str(tmp_path)) is None
    _run_and_ckpt(tmp_path, eng, 5)
    _run_and_ckpt(tmp_path, eng, 10)
    assert checkpoint.latest(str(tmp_path)).endswith("ckpt_10.npz")
