"""Checkpoint/restart and ELASTIC resharding: a checkpoint saved at H shards
must restore at H' shards / another placement and continue bit-identically —
for BOTH delivery backends (the event ring is persisted as canonical
per-slot flags, so it reshards exactly like the dense arrival ring)."""
import os

import jax
import numpy as np
import pytest

from repro.core import (EngineConfig, GridConfig, build, checkpoint,
                        observables, run)
from repro.core import event_engine as EV

CFG = GridConfig(grid_x=2, grid_y=2, neurons_per_column=80,
                 synapses_per_neuron=30, seed=13)


def _run_and_ckpt(tmp_path, eng, steps1):
    spec, plan, state = build(CFG, eng)
    state, _, _ = run(spec, plan, state, 0, steps1)
    path = os.path.join(str(tmp_path), f"ckpt_{steps1}.npz")
    checkpoint.save(path, spec, plan, state, steps1)
    return path


def _continue_from(path, eng, t0, steps2):
    spec, plan, _ = build(CFG, eng)
    state, t = checkpoint.load(path, spec, plan)
    assert t == t0
    _, raster, _ = run(spec, plan, state, t, steps2)
    return observables.raster_signature(np.asarray(raster),
                                        np.asarray(plan.gid))


def test_restart_bit_identical(tmp_path):
    """run(0..60) == run(0..30) + restart(30..60) on the same layout."""
    eng = EngineConfig(n_shards=2)
    spec, plan, state = build(CFG, eng)
    _, raster_full, _ = run(spec, plan, state, 0, 60)
    sig_tail = observables.raster_signature(
        np.asarray(raster_full)[30:], np.asarray(plan.gid))

    path = _run_and_ckpt(tmp_path, eng, 30)
    assert _continue_from(path, eng, 30, 30) == sig_tail


@pytest.mark.parametrize("eng2", [
    EngineConfig(n_shards=1),
    EngineConfig(n_shards=4),
    EngineConfig(n_shards=3),
    EngineConfig(n_shards=4, placement="scatter"),
])
def test_elastic_reshard(tmp_path, eng2):
    """checkpoint at H=2/block, restore at a different layout: same spikes."""
    eng1 = EngineConfig(n_shards=2)
    spec, plan, state = build(CFG, eng1)
    _, raster_full, _ = run(spec, plan, state, 0, 60)
    sig_tail = observables.raster_signature(
        np.asarray(raster_full)[30:], np.asarray(plan.gid))

    path = _run_and_ckpt(tmp_path, eng1, 30)
    assert _continue_from(path, eng2, 30, 30) == sig_tail


def test_latest_discovery(tmp_path):
    eng = EngineConfig(n_shards=1)
    assert checkpoint.latest(str(tmp_path)) is None
    _run_and_ckpt(tmp_path, eng, 5)
    _run_and_ckpt(tmp_path, eng, 10)
    assert checkpoint.latest(str(tmp_path)).endswith("ckpt_10.npz")


# ---------------------------------------------------------------------------
# event backend: same layout-free format, same elasticity
# ---------------------------------------------------------------------------


def _event_run(built, state, t0, steps):
    spec, plan, eplan, _ = built
    return jax.jit(lambda s: EV.run(spec, plan, eplan, s, t0, steps))(state)


def _event_built(n_shards):
    eng = EngineConfig(n_shards=n_shards, delivery="event")
    return EV.build(CFG, eng)


def test_event_restart_bit_identical(tmp_path):
    """event run(0..60) == run(0..30) + restart(30..60), same layout."""
    built = _event_built(2)
    spec, plan, eplan, state = built
    _, raster_full, _ = _event_run(built, state, 0, 60)
    sig_tail = observables.raster_signature(
        np.asarray(raster_full)[30:], np.asarray(plan.gid))

    st30, _, _ = _event_run(built, state, 0, 30)
    path = os.path.join(str(tmp_path), "ckpt_30.npz")
    checkpoint.save(path, spec, plan, st30, 30)
    st_r, t = checkpoint.load(path, spec, plan,
                              cap_ev=state.ev_ring.shape[-1])
    assert t == 30
    assert isinstance(st_r, EV.EventState)
    _, raster_cont, _ = _event_run(built, st_r, 30, 30)
    sig = observables.raster_signature(np.asarray(raster_cont),
                                       np.asarray(plan.gid))
    assert sig == sig_tail


def test_event_ring_order_round_trips_exactly(tmp_path):
    """Same-layout restore must rebuild the ring lists in the EXACT live
    order, not a canonicalized one: phase_a's fp32 scatter-add
    accumulates in list order, so reordering would fork the trajectory
    bitwise in any workload with >= 3 same-slot arrivals per target.
    A dense high-stim workload makes the slot lists long and interleaved
    across emission steps — the regime where order loss shows."""
    cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=120,
                     synapses_per_neuron=80, seed=13,
                     stim_events_per_ms_per_column=3)
    eng = EngineConfig(n_shards=2, delivery="event")
    spec, plan, eplan, state = EV.build(cfg, eng)
    cap_ev = state.ev_ring.shape[-1]
    built = (spec, plan, eplan, state)
    st30, _, _ = _event_run(built, state, 0, 30)
    assert int(np.asarray(st30.ev_count).sum()) > 0, "need pending events"

    path = os.path.join(str(tmp_path), "ckpt_30.npz")
    checkpoint.save(path, spec, plan, st30, 30)
    st_r, _ = checkpoint.load(path, spec, plan, cap_ev=cap_ev)
    # the whole ring — ids AND order — must round-trip bit-exactly
    assert np.array_equal(np.asarray(st_r.ev_ring), np.asarray(st30.ev_ring))
    assert np.array_equal(np.asarray(st_r.ev_count),
                          np.asarray(st30.ev_count))
    # and the continuation must be bitwise the uninterrupted run
    _, r_cont, _ = _event_run(built, st30, 30, 30)
    _, r_rest, _ = _event_run(built, st_r, 30, 30)
    assert np.array_equal(np.asarray(r_rest), np.asarray(r_cont))


@pytest.mark.parametrize("h2", [1, 4])
def test_event_elastic_reshard(tmp_path, h2):
    """event checkpoint at H=2, restore at H'=1/4: same spikes — pending
    ring events re-key by canonical synapse id like weights do."""
    built = _event_built(2)
    spec, plan, eplan, state = built
    _, raster_full, _ = _event_run(built, state, 0, 60)
    sig_tail = observables.raster_signature(
        np.asarray(raster_full)[30:], np.asarray(plan.gid))

    st30, _, _ = _event_run(built, state, 0, 30)
    path = os.path.join(str(tmp_path), "ckpt_30.npz")
    checkpoint.save(path, spec, plan, st30, 30)

    built2 = _event_built(h2)
    spec2, plan2, eplan2, state2 = built2
    st_r, t = checkpoint.load(path, spec2, plan2,
                              cap_ev=state2.ev_ring.shape[-1])
    assert t == 30
    _, raster_cont, _ = _event_run(built2, st_r, 30, 30)
    sig = observables.raster_signature(np.asarray(raster_cont),
                                       np.asarray(plan2.gid))
    assert sig == sig_tail


def test_delivery_mode_guard(tmp_path):
    """A dense checkpoint must refuse to load into an event config and
    vice versa — the backends' fp32 summation orders differ, so a silent
    cross-mode restore would fork the trajectory."""
    eng_d = EngineConfig(n_shards=2)
    spec_d, plan_d, state_d = build(CFG, eng_d)
    state_d, _, _ = run(spec_d, plan_d, state_d, 0, 10)
    p_dense = os.path.join(str(tmp_path), "ckpt_dense.npz")
    checkpoint.save(p_dense, spec_d, plan_d, state_d, 10)

    built = _event_built(2)
    spec_e, plan_e, eplan_e, state_e = built
    st10, _, _ = _event_run(built, state_e, 0, 10)
    p_event = os.path.join(str(tmp_path), "ckpt_event.npz")
    checkpoint.save(p_event, spec_e, plan_e, st10, 10)

    with pytest.raises(AssertionError, match="delivery mode mismatch"):
        checkpoint.load(p_event, spec_d, plan_d)
    with pytest.raises(AssertionError, match="delivery mode mismatch"):
        checkpoint.load(p_dense, spec_e, plan_e,
                        cap_ev=state_e.ev_ring.shape[-1])
