"""End-to-end behaviour tests for the paper's system: a full simulate ->
checkpoint -> elastic-restart -> observe cycle through the public API, plus
the LM train-then-serve round trip."""
import numpy as np

from repro.core import (EngineConfig, GridConfig, build, checkpoint,
                        observables, run)


def test_snn_end_to_end(tmp_path):
    """Build a 2x2 grid, simulate, checkpoint, restart elsewhere, compare."""
    cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=100,
                     synapses_per_neuron=40, seed=42)
    spec, plan, state = build(cfg, EngineConfig(n_shards=2))
    state, raster1, tm = run(spec, plan, state, 0, 100)
    rate = observables.mean_rate_hz(np.asarray(raster1), cfg.n_neurons)
    assert 1.0 < rate < 200.0
    # spikes happened and were delivered (arrivals follow spikes)
    assert int(np.asarray(tm.spikes).sum()) > 0
    assert int(np.asarray(tm.arrivals).sum()) > 0

    path = checkpoint.save(str(tmp_path / "ckpt_100.npz"), spec, plan,
                           state, 100)
    # elastic: restart on 4 shards, simulate the same window twice
    spec2, plan2, _ = build(cfg, EngineConfig(n_shards=4))
    state2, t0 = checkpoint.load(path, spec2, plan2)
    _, raster_a, _ = run(spec2, plan2, state2, t0, 50)
    state3, _ = checkpoint.load(path, spec2, plan2)[0], 100
    _, raster_b, _ = run(spec2, plan2, state3, 100, 50)
    assert (observables.raster_signature(np.asarray(raster_a),
                                         np.asarray(plan2.gid))
            == observables.raster_signature(np.asarray(raster_b),
                                            np.asarray(plan2.gid)))


def test_lm_train_then_serve(tmp_path):
    """Train a few steps, checkpoint, reload, serve deterministically."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data import pipeline
    from repro.models import lm
    from repro.optim import schedules
    from repro.serve.engine import Request, ServeEngine
    from repro.train import step as step_mod
    from repro.train import train_state as ts_mod
    from repro.train.train_state import create

    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.key(0))
    state = create(params)
    step = jax.jit(step_mod.make_train_step(
        cfg, lr_schedule=schedules.constant(1e-3)))
    data = iter(pipeline.Batcher(cfg, 2, 32, seed=3))
    losses = []
    for _ in range(8):
        state, m = step(state, next(data))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]           # learning on synthetic data

    p = ts_mod.save(str(tmp_path / "lm_8.npz"), state)
    state2 = ts_mod.load(p, state)

    eng = ServeEngine(cfg, state2.params, batch=2, s_max=48)
    reqs = [Request(prompt=np.arange(5, dtype=np.int32), max_new=4)
            for _ in range(2)]
    done = eng.run(reqs)
    assert np.array_equal(done[0].out, done[1].out)  # same prompt => same
