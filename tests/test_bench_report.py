"""Bench report schema + baseline comparator (repro.bench.report).

The comparator is the CI regression gate: deterministic drift must be a
hard failure, wall-clock drift only a warning, and hlo_* drift must
downgrade to a warning when the baseline was produced under another jax
version.
"""
import copy

from repro.bench import report as R


def _report(name="unit", **over):
    rep = R.make_report(
        name,
        config=dict(grid="2x2", steps=10, quick=True),
        deterministic=dict(spikes=123, raster_sig="abcd", flag=True,
                           hlo_bytes_x=456789),
        wall=dict(wall_s=1.25, steps_per_s=8.0),
        extra=dict(rows=[{"grid": "2x2"}]))
    rep.update(over)
    return rep


class TestSchema:
    def test_valid_report_has_no_errors(self):
        assert R.validate(_report()) == []

    def test_missing_section_flagged(self):
        rep = _report()
        del rep["deterministic"]
        assert any("deterministic" in e for e in R.validate(rep))

    def test_float_deterministic_rejected(self):
        rep = _report()
        rep["deterministic"]["rate"] = 27.5
        assert any("rate" in e for e in R.validate(rep))

    def test_non_numeric_wall_rejected(self):
        rep = _report()
        rep["wall"]["wall_s"] = "fast"
        assert any("wall_s" in e for e in R.validate(rep))

    def test_schema_version_mismatch_flagged(self):
        rep = _report(schema_version=R.SCHEMA_VERSION + 1)
        assert any("schema_version" in e for e in R.validate(rep))

    def test_save_load_round_trip(self, tmp_path):
        rep = _report()
        path = R.save(rep, str(tmp_path))
        assert path.endswith("BENCH_unit.json")
        assert R.load(path) == rep
        assert R.load_dir(str(tmp_path)) == {"unit": rep}

    def test_save_refuses_invalid(self, tmp_path):
        rep = _report()
        rep["deterministic"]["bad"] = 1.5
        try:
            R.save(rep, str(tmp_path))
        except ValueError:
            return
        raise AssertionError("save() accepted an invalid report")


class TestCompare:
    def test_identical_reports_pass(self):
        rep = _report()
        res = R.compare(copy.deepcopy(rep), rep)
        assert res.ok and not res.warnings

    def test_deterministic_drift_fails(self):
        base = _report()
        cur = copy.deepcopy(base)
        cur["deterministic"]["spikes"] = 124
        res = R.compare(cur, base)
        assert not res.ok
        assert any("spikes" in f for f in res.failures)

    def test_raster_sig_drift_fails(self):
        base = _report()
        cur = copy.deepcopy(base)
        cur["deterministic"]["raster_sig"] = "beef"
        assert not R.compare(cur, base).ok

    def test_missing_deterministic_metric_fails(self):
        base = _report()
        cur = copy.deepcopy(base)
        del cur["deterministic"]["spikes"]
        assert not R.compare(cur, base).ok

    def test_wall_drift_warns_but_passes(self):
        base = _report()
        cur = copy.deepcopy(base)
        cur["wall"]["wall_s"] = base["wall"]["wall_s"] * 3
        res = R.compare(cur, base, wall_tol=0.5)
        assert res.ok
        assert any("wall_s" in w for w in res.warnings)

    def test_wall_within_tolerance_is_silent(self):
        base = _report()
        cur = copy.deepcopy(base)
        cur["wall"]["wall_s"] = base["wall"]["wall_s"] * 1.2
        res = R.compare(cur, base, wall_tol=0.5)
        assert res.ok and not res.warnings

    def test_config_mismatch_fails(self):
        base = _report()
        cur = copy.deepcopy(base)
        cur["config"]["steps"] = 999
        res = R.compare(cur, base)
        assert not res.ok
        assert any("config" in f for f in res.failures)

    def test_config_mismatch_with_list_values_reports_not_crashes(self):
        # full-size vs quick reports carry list-valued config entries
        # (table1 'grids', scaling '*_shards') — must not TypeError
        base = _report()
        base["config"]["grids"] = ["1x1", "4x4"]
        cur = copy.deepcopy(_report())
        cur["config"]["grids"] = ["1x1", "4x4", "8x8"]
        res = R.compare(cur, base)
        assert not res.ok
        assert any("grids" in f for f in res.failures)

    def test_hlo_drift_under_other_jax_downgrades_to_warning(self):
        base = _report()
        base["env"]["jax"] = "0.0.0-baseline"
        cur = copy.deepcopy(_report())
        cur["deterministic"]["hlo_bytes_x"] = 1
        res = R.compare(cur, base)
        assert res.ok
        assert any("hlo_bytes_x" in w for w in res.warnings)

    def test_spike_drift_under_other_jax_still_fails(self):
        base = _report()
        base["env"]["jax"] = "0.0.0-baseline"
        cur = copy.deepcopy(_report())
        cur["deterministic"]["spikes"] = 1
        assert not R.compare(cur, base).ok


class TestCompareDirs:
    def test_dir_round_trip_and_missing_current(self, tmp_path):
        basedir = tmp_path / "base"
        curdir = tmp_path / "cur"
        R.save(_report("a"), str(basedir))
        R.save(_report("b"), str(basedir))
        R.save(_report("a"), str(curdir))
        res = R.compare_dirs(str(curdir), str(basedir))
        assert not res.ok                       # 'b' has no current report
        assert any("b" in f for f in res.failures)
        R.save(_report("b"), str(curdir))
        assert R.compare_dirs(str(curdir), str(basedir)).ok

    def test_empty_baseline_dir_fails(self, tmp_path):
        res = R.compare_dirs(str(tmp_path), str(tmp_path / "nothing"))
        assert not res.ok
