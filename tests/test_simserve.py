"""repro.simserve: multi-tenant simulation service.

The correctness spine under test: every tenant's streamed raster
signature is bit-identical to the same config run solo through
`StepProgram`, regardless of batch companions, slot-refill order, or
evict/resume cycles — including resumes into a different shard layout —
and the program cache traces each shape key exactly once no matter how
many tenants ride it.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import distributed, observables
from repro.core.params import EngineConfig, GridConfig
from repro.core.step_program import StepProgram
from repro.simserve import (DONE, FAILED, RUNNING, RasterStream, SimService,
                            TenantRequest, batcher)

CFG = GridConfig(grid_x=2, grid_y=2, neurons_per_column=20,
                 synapses_per_neuron=10)
DENSE = EngineConfig(n_shards=2, delivery="dense")
EVENT = EngineConfig(n_shards=2, delivery="event")


def _solo(cfg, eng, n_steps, caps=None, cap_ev=None):
    """(signature, sat_total) of the reference solo run."""
    spec, planT, state = batcher.build_parts(cfg, eng, caps, cap_ev)
    plan = distributed._base_plan(planT)
    eplan = planT[1] if eng.delivery == "event" else None
    prog = StepProgram.from_parts(spec, plan, eplan, state0=state,
                                  mesh=None, caps=batcher.caps_dict(caps),
                                  hier_groups=None)
    st, raster, _ = prog.run(state, 0, n_steps)
    sig = observables.raster_signature(np.asarray(raster),
                                       np.asarray(plan.gid))
    sat = int(np.asarray(st.sat).sum()) if hasattr(st, "sat") else 0
    return sig, sat


class TestStreaming:
    def test_chunked_signature_matches_full(self):
        rng = np.random.default_rng(0)
        raster = rng.random((30, 2, 8)) < 0.2
        gid = np.arange(16).reshape(2, 8)
        full = observables.raster_signature(raster, gid)
        stream = RasterStream()
        for t0 in range(0, 30, 7):          # uneven chunks
            stream.push(raster[t0:t0 + 7], gid, t0=t0)
        assert stream.signature() == full
        assert stream.n_events == int(raster.sum())

    def test_csv_append_equals_full_dump(self, tmp_path):
        rng = np.random.default_rng(1)
        raster = rng.random((20, 1, 6)) < 0.3
        gid = np.arange(6).reshape(1, 6)
        full, chunked = str(tmp_path / "a.csv"), str(tmp_path / "b.csv")
        observables.dump_events_csv(full, raster, gid)
        for t0 in range(0, 20, 6):
            observables.dump_events_csv(chunked, raster[t0:t0 + 6], gid,
                                        append=True, t0=t0)
        assert open(full).read() == open(chunked).read()


class TestShapeKeys:
    def test_seed_not_in_key(self):
        a = batcher.shape_key(dataclasses.replace(CFG, seed=1), DENSE)
        b = batcher.shape_key(dataclasses.replace(CFG, seed=999), DENSE)
        assert a == b and hash(a) == hash(b)

    def test_layout_and_caps_in_key(self):
        base = batcher.shape_key(CFG, DENSE)
        assert base != batcher.shape_key(CFG, EVENT)
        assert base != batcher.shape_key(
            CFG, dataclasses.replace(DENSE, n_shards=4))
        assert base != batcher.shape_key(CFG, DENSE, caps=(8, 8))

    def test_negotiate_headroom_and_monotone(self):
        r = batcher.GroupCaps(e_cap=100, s_cap=50, kf=7, ki=5, cap_ev=64)
        g = batcher.negotiate(r)
        assert g.fits(r) and g.e_cap > r.e_cap and g.kf % 4 == 0
        prior = batcher.GroupCaps(e_cap=999, s_cap=1, kf=99, ki=1,
                                  cap_ev=0)
        g2 = batcher.negotiate(r, prior=prior)
        assert g2.e_cap >= 999 and g2.kf >= 99 and g2.fits(g)


class TestServiceIdentity:
    def test_soak_mixed_fleet_with_resharded_resume(self):
        """The acceptance scenario: 6 tenants over 2 shape keys in 2
        slots (queueing + preemption), one tenant force-evicted mid-run
        and resumed into a DOUBLED shard count; every signature must
        equal the solo run and each shape key must trace exactly once."""
        reqs = []
        for i, seed in enumerate([2013, 7, 99, 5, 123456, 42]):
            reqs.append(TenantRequest(
                f"t{i}", dataclasses.replace(CFG, seed=seed),
                EVENT if i % 2 else DENSE, 60))
        svc = SimService(slots=2, round_steps=15)
        for r in reqs:
            svc.submit(r)
        svc.step_round()
        svc.step_round()
        victim = next(s for s in svc.sessions.values()
                      if s.status == RUNNING)
        svc.evict(victim.name)
        svc.step_round()
        svc.resume(victim.name, eng=dataclasses.replace(
            victim.eng, n_shards=victim.eng.n_shards * 2))
        snap = svc.run()

        for r in reqs:
            sess = svc.sessions[r.name]
            assert sess.status == DONE
            want, _ = _solo(r.cfg, sess.eng, r.n_steps)
            assert sess.stream.signature() == want, r.name
        # resharded tenant really ran in the new layout
        assert svc.sessions[victim.name].eng.n_shards == 4
        assert svc.sessions[victim.name].resumes == 1
        # overloaded slots exercised the scheduler
        assert snap["preemptions"] > 0
        assert snap["queue_wait_rounds"] > 0
        assert snap["evictions"] >= 1 and snap["resumes"] >= 1
        # one trace per shape key, ever (3 keys: dense/H2, event/H2,
        # and the resume layout at H4)
        assert all(t == 1 for t in
                   snap["program_cache"]["traces"].values())
        assert snap["program_cache"]["builds"] == 3

    def test_zero_recompile_on_refill(self):
        """A tenant admitted into an existing group must not retrace:
        submit two waves into the same shape key."""
        svc = SimService(slots=2, round_steps=10)
        svc.submit(TenantRequest(
            "a", dataclasses.replace(CFG, seed=1), DENSE, 20))
        svc.submit(TenantRequest(
            "b", dataclasses.replace(CFG, seed=2), DENSE, 20))
        svc.step_round()
        svc.submit(TenantRequest(        # refills a's slot when it frees
            "c", dataclasses.replace(CFG, seed=3), DENSE, 20))
        snap = svc.run()
        assert all(svc.sessions[n].status == DONE for n in "abc")
        assert snap["program_cache"]["builds"] == 1
        assert sum(snap["program_cache"]["traces"].values()) == 1
        for n in "abc":
            sess = svc.sessions[n]
            want, _ = _solo(sess.request.cfg, sess.eng, 20)
            assert sess.stream.signature() == want

    def test_csv_stream_dir(self, tmp_path):
        svc = SimService(slots=1, round_steps=10,
                         stream_dir=str(tmp_path))
        svc.submit(TenantRequest("x", dataclasses.replace(CFG, seed=4),
                                 DENSE, 20))
        svc.run()
        path = os.path.join(str(tmp_path), "x.csv")
        lines = open(path).read().splitlines()
        assert lines[0] == "time_ms,neuron_gid"
        assert len(lines) - 1 == svc.sessions["x"].stream.n_events


class TestSaturationEviction:
    def test_evict_resume_preserves_raster_and_sat(self):
        """Satellite: a tiny event ring saturates (sat > 0); evicting
        mid-run and resuming must reproduce the uninterrupted run's
        raster AND saturation totals bit-exactly (the checkpoint
        round-trips the event ring via delay ranks and the sat
        counter)."""
        cfg = dataclasses.replace(CFG, seed=11)
        caps, cap_ev, n = (40, 64), 16, 60
        want_sig, want_sat = _solo(cfg, EVENT, n, caps=caps,
                                   cap_ev=cap_ev)
        assert want_sat > 0          # the regime under test: saturated

        svc = SimService(slots=2, round_steps=15)
        svc.submit(TenantRequest("sat", cfg, EVENT, n, caps=caps,
                                 cap_ev=cap_ev))
        svc.step_round()
        svc.step_round()
        svc.evict("sat")
        svc.step_round()             # a round elapses while parked
        svc.resume("sat")
        svc.run()
        sess = svc.sessions["sat"]
        assert sess.status == DONE and sess.evictions == 1
        assert sess.stream.signature() == want_sig
        assert sess.sat_total == want_sat


class TestMetrics:
    def test_snapshot_counts(self):
        svc = SimService(slots=1, round_steps=10, preempt=False)
        svc.submit(TenantRequest("a", dataclasses.replace(CFG, seed=1),
                                 DENSE, 20))
        svc.submit(TenantRequest("b", dataclasses.replace(CFG, seed=2),
                                 DENSE, 20))
        snap = svc.run()
        assert snap["completed"] == 2 and snap["submitted"] == 2
        assert snap["preemptions"] == 0          # disabled
        assert snap["tenant_steps"] == 40
        b = svc.sessions["b"]
        assert b.queue_wait_rounds > 0           # b waited for the slot
        assert snap["rounds"] == 4               # 2 rounds per tenant
        assert snap["tenant_steps_per_s"] > 0


class TestErrors:
    def test_duplicate_name_rejected(self):
        svc = SimService(slots=1)
        svc.submit(TenantRequest("a", CFG, DENSE, 10))
        with pytest.raises(ValueError):
            svc.submit(TenantRequest("a", CFG, DENSE, 10))

    def test_resume_cannot_change_delivery(self):
        svc = SimService(slots=1, round_steps=10)
        svc.submit(TenantRequest("a", CFG, DENSE, 30))
        svc.step_round()
        svc.evict("a")
        with pytest.raises(ValueError):
            svc.resume("a", eng=EVENT)

    def test_evict_requires_running(self):
        svc = SimService(slots=1)
        svc.submit(TenantRequest("a", CFG, DENSE, 10))
        with pytest.raises(ValueError):
            svc.evict("a")           # still queued, not running


class TestGracefulDegradation:
    """A group whose round execution raises loses the group, not the
    service: occupants evict to their last round-boundary checkpoint and
    requeue (bit-identical continuation); a tenant failing past the cap
    retires FAILED while everyone else keeps running."""

    def test_transient_group_failure_recovers_bit_identical(self):
        svc = SimService(slots=2, round_steps=10)
        cfg_b = dataclasses.replace(CFG, seed=11)
        a = svc.submit(TenantRequest("a", CFG, DENSE, n_steps=40))
        b = svc.submit(TenantRequest("b", cfg_b, DENSE, n_steps=40))
        assert svc.step_round()              # admit both, round 1 clean
        group = next(iter(svc.groups.values()))
        real, state = group.prog, {"left": 1}

        def boom(*args, **kw):
            if state["left"]:
                state["left"] -= 1
                raise RuntimeError("injected round failure")
            return real(*args, **kw)

        group.prog = boom
        snap = svc.run()
        assert a.done and b.done
        assert snap["group_failures"] == 1
        assert snap["failure_evictions"] == 2
        assert snap["failed"] == 0
        assert a.failures == 1 and b.failures == 1
        assert a.stream.signature() == _solo(CFG, DENSE, 40)[0]
        assert b.stream.signature() == _solo(cfg_b, DENSE, 40)[0]

    def test_permanent_failure_retires_failed_others_unaffected(self):
        svc = SimService(slots=2, round_steps=10, max_tenant_failures=2)
        a = svc.submit(TenantRequest("a", CFG, DENSE, n_steps=40))
        c = svc.submit(TenantRequest("c", CFG, EVENT, n_steps=40))
        assert svc.step_round()              # both groups form, round 1 ok
        dense_group = [g for g in svc.groups.values()
                       if svc.sessions["a"] in g.sessions][0]

        class Poison:
            """Delegates everything (metrics snapshots still read
            .traces) but every round execution raises."""
            def __init__(self, real):
                self._real = real
            def __getattr__(self, k):
                return getattr(self._real, k)
            def __call__(self, *args, **kw):
                raise RuntimeError("permanent failure")

        # poison the live group AND the cached program, so the running
        # group and every re-formed successor all die
        poisoned = Poison(svc.cache._programs[dense_group.key])
        svc.cache._programs[dense_group.key] = poisoned
        dense_group.prog = poisoned
        snap = svc.run(max_rounds=50)        # must terminate, not loop
        assert a.status == FAILED
        assert a.failures == svc.max_tenant_failures + 1
        assert snap["failed"] == 1
        assert snap["group_failures"] == svc.max_tenant_failures + 1
        assert c.done                        # the event group never noticed
        assert c.failures == 0
        assert c.stream.signature() == _solo(CFG, EVENT, 40)[0]
        # the failed tenant's last good checkpoint survives for forensics
        assert a.ckpt_path is not None and os.path.exists(a.ckpt_path)
