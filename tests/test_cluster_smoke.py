"""Cross-process determinism: the paper's Table 1 invariant extended over
the PROCESS axis.

A 2-process x 2-shard localhost job (real OS processes, real inter-process
collectives) must produce a spike raster bit-identical to the
single-process engine for the same (seed, grid) config.  Skips via the
live capability probe where the platform cannot spawn cluster jobs."""
import pytest

from _cluster_helpers import require_cluster
from repro.cluster import cli

pytestmark = pytest.mark.slow

WORKLOAD = dict(grid="2x2", neurons_per_column=50, synapses=20, seed=11,
                steps=50, shards=4)


def test_two_procs_two_shards_matches_single_process():
    require_cluster()
    args = cli.workload_namespace(**WORKLOAD, phase_steps=8)
    row = cli.run_point(args, nprocs=2, timeout=600)

    assert row["nprocs"] == 2 and row["shards"] == 4
    assert [pp["proc"] for pp in row["per_proc"]] == [0, 1]
    # every process timed all three phases of the paper's step split
    for pp in row["per_proc"]:
        for k in ("phase_a_s", "exchange_s", "phase_b_s"):
            assert pp[k] >= 0.0

    ref = cli.reference_signature(args)
    assert row["raster_sig"] == ref, \
        "cross-process raster differs from the single-process engine"


def test_halo_exchange_across_processes():
    """The sparse AER ppermute route must survive a real process boundary
    too (allgather and halo lower to different collectives)."""
    require_cluster()
    args = cli.workload_namespace(**WORKLOAD, exchange="halo")
    row = cli.run_point(args, nprocs=2, timeout=600)
    ref = cli.reference_signature(args)
    assert row["raster_sig"] == ref


def test_pipelined_schedule_across_processes():
    """The pipelined exchange schedule over a REAL process boundary: the
    one-step-lagged double-buffered exchange must still produce a raster
    bit-identical to the single-process engine (whose reference driver is
    schedule-independent by construction) — comm/compute overlap is an
    execution layout, never physics."""
    require_cluster()
    args = cli.workload_namespace(**WORKLOAD, exchange="halo",
                                  exchange_schedule="pipelined",
                                  phase_steps=8)
    row = cli.run_point(args, nprocs=2, timeout=600)
    assert row["exchange_schedule"] == "pipelined"
    # the schedule-aware phase split ran on every process
    for pp in row["per_proc"]:
        for k in ("phase_a_s", "exchange_s", "phase_b_s"):
            assert pp[k] >= 0.0
    ref = cli.reference_signature(args)
    assert row["raster_sig"] == ref, \
        "pipelined cross-process raster differs from the single-process " \
        "engine"


def test_event_delivery_across_processes():
    """The EVENT backend across a real process boundary: a 2-proc x
    2-shard event run must produce rasters bit-identical to the 1-process
    event driver for the same config — the Table 1 invariant extended to
    the event delivery mode, over the process axis, on the sparse halo
    wire."""
    require_cluster()
    args = cli.workload_namespace(**WORKLOAD, delivery="event",
                                  exchange="halo")
    row = cli.run_point(args, nprocs=2, timeout=600)
    assert row["delivery"] == "event"
    assert row.get("saturated", 0) == 0, "event caps saturated in smoke"
    ref = cli.reference_signature(args)
    assert row["raster_sig"] == ref, \
        "cross-process event raster differs from the 1-process event run"


def test_nondefault_profile_across_processes():
    """The Table 1 invariant must hold across the process axis at a
    wider-than-paper connectivity reach (gaussian sigma=1.5 -> reach 5).
    The 16x1 grid out-spans the kernel at 4 block shards (halo spans 14
    of 16 columns vs ring3's 10), so the halo AER route crossing the
    process boundary carries a genuinely different static schedule than
    the ring3 tests above — not the full-grid wrap a 2x2 grid would
    degenerate to."""
    require_cluster()
    args = cli.workload_namespace(grid="16x1", neurons_per_column=20,
                                  synapses=12, seed=11, steps=50,
                                  shards=4, exchange="halo",
                                  profile="gaussian:sigma=1.5")
    row = cli.run_point(args, nprocs=2, timeout=600)
    assert row["profile"] == "gaussian:sigma=1.5"
    ref = cli.reference_signature(args)
    assert row["raster_sig"] == ref, \
        "cross-process raster differs from the single-process engine " \
        "at gaussian reach 5"
