"""Per-phase profiler consistency (repro.bench.profile) on a small grid.

Checks the properties the benchmark reports rely on: phase times sum to
~the loop wall-clock (nothing substantial is left untimed), counters and
raster signatures are layout-invariant (the paper's Table 1 check), and
the profiler agrees with the plain engine on the physics.
"""
import jax
import numpy as np

from repro.bench import profile as BP
from repro.bench import timing
from repro.core import engine, observables
from repro.core.params import EngineConfig, GridConfig

CFG = GridConfig(grid_x=1, grid_y=2, neurons_per_column=100,
                 synapses_per_neuron=50)
STEPS = 30


class TestProfileCell:
    def test_phase_times_sum_to_total(self):
        cell = BP.profile_cell(CFG, EngineConfig(n_shards=2), STEPS)
        total = cell["phase_a_s"] + cell["exchange_s"] + cell["phase_b_s"]
        assert cell["phases_sum_s"] > 0
        # per-phase values are rounded to 4 decimals independently of the
        # rounded sum, so they can legitimately disagree by ~1.5e-4
        assert abs(total - cell["phases_sum_s"]) < 2e-4
        # untimed per-step bookkeeping must stay a small fraction of wall
        assert cell["phases_sum_s"] <= cell["wall_s"] * 1.001
        assert cell["phases_sum_s"] >= cell["wall_s"] * 0.5

    def test_layout_invariance_and_engine_agreement(self):
        # reference: the engine's own fused runner at H=1
        spec, plan, state = engine.build(CFG, EngineConfig(n_shards=1))
        _, raster, _ = jax.jit(
            lambda s: engine.run(spec, plan, s, 0, STEPS))(state)
        ref_sig = observables.raster_signature(
            np.asarray(raster), np.asarray(plan.gid)).hex()
        ref_spikes = int(np.asarray(raster).sum())

        cells = {}
        for ex in BP.EXCHANGES:
            for pl in BP.PLACEMENTS:
                eng = EngineConfig(n_shards=2, exchange=ex, placement=pl)
                cells[f"{ex}_{pl}"] = BP.profile_cell(CFG, eng, STEPS)
        for key, c in cells.items():
            assert c["raster_sig"] == ref_sig, key
            assert c["spikes"] == ref_spikes, key
        arr = {k: c["arrivals"] for k, c in cells.items()}
        assert len(set(arr.values())) == 1, arr

    def test_hlo_cost_positive_and_mode_sensitive(self):
        ag = BP.profile_cell(CFG, EngineConfig(n_shards=2,
                                               exchange="allgather"), 5)
        halo = BP.profile_cell(CFG, EngineConfig(n_shards=2,
                                                 exchange="halo"), 5)
        assert ag["hlo_bytes"] > 0 and halo["hlo_bytes"] > 0
        # the AER pack/sort/concat pipeline must leave a footprint
        assert halo["hlo_bytes"] != ag["hlo_bytes"]


class TestTiming:
    def test_time_fn_median_and_spread(self):
        t = timing.Timing(reps_s=(0.2, 0.1, 0.4))
        assert t.median_s == 0.2
        assert t.min_s == 0.1 and t.max_s == 0.4
        assert abs(t.spread - (0.3 / 0.2)) < 1e-9
        even = timing.Timing(reps_s=(0.1, 0.3))
        assert abs(even.median_s - 0.2) < 1e-9

    def test_time_fn_blocks_and_counts_reps(self):
        calls = []

        def f(x):
            calls.append(1)
            return x * 2

        t = timing.time_fn(f, np.ones(4), reps=3, warmup=2)
        assert len(calls) == 5
        assert len(t.reps_s) == 3 and t.median_s >= 0

    def test_norm_seconds_is_paper_metric(self):
        # 1 s wall, 1000 synapses, 100 steps (0.1 sim-s), 10 Hz
        got = timing.norm_seconds(1.0, 1000, 100, 10.0)
        assert abs(got - 1.0 / (1000 * 0.1 * 10.0)) < 1e-12
