"""Resumable plan execution + merged BENCH report (repro.bench.plans).

Resume semantics are the contract CI leans on: a completed cell is never
re-executed, a deleted or stale result re-runs exactly that cell, a
failed cell leaves no file (so the next run retries it) and
`assert_complete` turns "anything executed" into a failure — the proof
the experiment-plan job replays on the committed quick plan.
"""
import pytest

from repro.bench import plans
from repro.bench import report as bench_report
from repro.bench.plans import runner as RU

ENV = {"jax": "0.4.37", "backend": "cpu"}
SIG = "ab" * 32


def _plan(**over):
    doc = dict(name="unit",
               workload=dict(neurons_per_column=30, synapses_per_neuron=12,
                             steps=20, phase_steps=5, seed=7),
               axes=dict(delivery=["dense", "event"], exchange=["halo"],
                         shards=[2]))
    doc.update(over)
    return plans.validate(doc)


def _executor(calls=None, sig=SIG, fail_keys=()):
    def run(cell):
        if calls is not None:
            calls.append(cell["key"])
        if cell["key"] in fail_keys:
            raise RuntimeError("injected cell failure")
        res = dict(wall_s=0.5, spikes=10, rate_hz=1.0, raster_sig=sig,
                   phase_a_s=0.2, exchange_s=0.1, phase_b_s=0.2,
                   phase_steps=cell["phase_steps"])
        if cell["delivery"] == "event":
            res["saturated"] = 0
        return RU._finalize(cell, res)
    return run


def _run(plan, out, **kw):
    kw.setdefault("env", ENV)
    kw.setdefault("log", lambda m: None)
    return plans.run_plan(plan, str(out), **kw)


class TestResume:
    def test_first_run_executes_everything(self, tmp_path):
        calls = []
        s = _run(_plan(), tmp_path, executor=_executor(calls))
        assert (s["executed"], s["skipped"], s["failed"]) == (2, 0, 0)
        assert s["ok"] and len(calls) == 2
        store = plans.ResultStore(str(tmp_path), "unit")
        assert len(store.load_results()) == 2

    def test_second_run_executes_nothing(self, tmp_path):
        _run(_plan(), tmp_path, executor=_executor())
        calls = []
        s = _run(_plan(), tmp_path, executor=_executor(calls),
                 assert_complete=True)
        assert s["ok"] and s["executed"] == 0 and s["skipped"] == 2
        assert calls == []

    def test_deleted_cell_is_the_only_rerun(self, tmp_path):
        s0 = _run(_plan(), tmp_path, executor=_executor())
        victim = s0["executed_keys"][0]
        store = plans.ResultStore(str(tmp_path), "unit")
        assert store.drop_cell(victim)
        calls = []
        s = _run(_plan(), tmp_path, executor=_executor(calls))
        assert calls == [victim]
        assert s["executed_keys"] == [victim] and s["skipped"] == 1

    def test_stale_hash_reruns_the_cell(self, tmp_path):
        _run(_plan(), tmp_path, executor=_executor())
        calls = []
        s = _run(_plan(), tmp_path, executor=_executor(calls),
                 env={"jax": "9.9.9", "backend": "cpu"})
        assert s["executed"] == 2 and len(calls) == 2

    def test_assert_complete_fails_when_work_remained(self, tmp_path):
        s = _run(_plan(), tmp_path, executor=_executor(),
                 assert_complete=True)
        assert s["executed"] == 2 and not s["ok"]

    def test_failed_cell_leaves_no_file_and_retries(self, tmp_path):
        p = _plan()
        cells, _ = plans.expand(p, env=ENV)
        bad = cells[0]["key"]
        s = _run(p, tmp_path, executor=_executor(fail_keys={bad}))
        assert not s["ok"] and s["failed_keys"] == [bad]
        assert s["executed"] == 1          # the other cell still ran
        store = plans.ResultStore(str(tmp_path), "unit")
        assert store.load_cell(bad) is None
        calls = []
        s2 = _run(p, tmp_path, executor=_executor(calls))
        assert calls == [bad] and s2["ok"]

    def test_summary_is_persisted(self, tmp_path):
        s = _run(_plan(), tmp_path, executor=_executor())
        store = plans.ResultStore(str(tmp_path), "unit")
        assert store.load_summary()["executed"] == s["executed"]


class TestMergedReport:
    def test_report_validates_and_gates_identity(self, tmp_path):
        _run(_plan(), tmp_path, executor=_executor())
        path, rep = plans.write_report(_plan(), str(tmp_path), env=ENV)
        assert bench_report.validate(rep) == []
        det = rep["deterministic"]
        spikes = [k for k in det if k.endswith("_spikes")]
        sigs = [k for k in det if k.endswith("_sig")]
        idents = [k for k in det if k.startswith("identical_")]
        assert len(spikes) == len(sigs) == 2 and len(idents) == 1
        assert det[idents[0]] is True
        assert any(k.endswith("_wall_s") for k in rep["wall"])
        assert any(k.endswith("_exchange_s") for k in rep["wall"])

    def test_divergent_raster_flags_group(self, tmp_path):
        p = _plan()
        cells, _ = plans.expand(p, env=ENV)
        flip = cells[1]["key"]

        def run(cell):
            sig = "ff" * 32 if cell["key"] == flip else SIG
            return _executor(sig=sig)(cell)

        _run(p, tmp_path, executor=run)
        _, rep = plans.write_report(p, str(tmp_path), env=ENV)
        ident = [k for k in rep["deterministic"]
                 if k.startswith("identical_")]
        assert rep["deterministic"][ident[0]] is False
        assert any(not g["identical"]
                   for g in rep["extra"]["groups"].values())

    def test_partial_store_is_refused_without_flag(self, tmp_path):
        p = _plan()
        _run(p, tmp_path, executor=_executor())
        store = plans.ResultStore(str(tmp_path), "unit")
        store.drop_cell(store.load_results()[0]["key"])
        with pytest.raises(plans.PlanError):
            plans.write_report(p, str(tmp_path), env=ENV)
        _, rep = plans.write_report(p, str(tmp_path), allow_partial=True,
                                    env=ENV)
        assert len(rep["extra"]["cells"]) == 1

    def test_time_per_syn_event_derived(self, tmp_path):
        _run(_plan(), tmp_path, executor=_executor())
        store = plans.ResultStore(str(tmp_path), "unit")
        for rec in store.load_results():
            res = rec["result"]
            expect = res["wall_s"] / (res["spikes"] *
                                      rec["cell"]["synapses_per_neuron"])
            assert res["time_per_syn_event_s"] == pytest.approx(expect,
                                                                rel=1e-2)


@pytest.mark.slow
class TestRealSubprocess:
    def test_single_cell_plan_runs_in_fresh_interpreter(self, tmp_path):
        p = _plan(axes=dict(delivery=["dense"], exchange=["halo"],
                            shards=[2]),
                  workload=dict(neurons_per_column=20,
                                synapses_per_neuron=8, steps=10,
                                phase_steps=4, seed=7))
        s = plans.run_plan(p, str(tmp_path), log=lambda m: None)
        assert s["ok"] and s["executed"] == 1
        rec = plans.ResultStore(str(tmp_path), "unit").load_results()[0]
        res = rec["result"]
        assert res["spikes"] > 0 and len(res["raster_sig"]) == 64
        assert res["phase_steps"] == 4 and "exchange_s" in res
        s2 = plans.run_plan(p, str(tmp_path), assert_complete=True,
                            log=lambda m: None)
        assert s2["ok"] and s2["executed"] == 0
