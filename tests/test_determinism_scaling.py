"""Distributed determinism: the paper's Table 1 invariant as a pytest.

Both exchange modes ('halo' sparse AER delivery and 'allgather' dense
masks) must produce bit-identical raster signatures at every shard count
H in {1, 2, 4} — and for every lateral-connectivity profile, whose reach
sets the halo depth the exchange must provision (ring1 narrows it,
gaussian widens it past the paper's 3 rings).  One subprocess with 4
forced host devices runs all six (H, exchange) points of one profile;
the benchmark asserts the same invariant at larger scale outside pytest
(benchmarks/scaling.py)."""
import pytest

from _mp_helpers import run_with_devices

_CODE = """
import numpy as np
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D

cfg = GridConfig(grid_x={gx}, grid_y={gy}, neurons_per_column={npc},
                 synapses_per_neuron={syn}, seed=11,
                 connectivity={profile!r})
sigs = {{}}
n_offsets = {{}}
for H in (1, 2, 4):
    for exchange in ("halo", "allgather"):
        eng = EngineConfig(n_shards=H, exchange=exchange)
        sp = StepProgram(cfg, eng, mesh=D.make_mesh(H))
        if exchange == "halo":
            n_offsets[H] = len(D.halo_offsets(sp.spec, sp.plan))
        state_d = sp.place(sp.init_state())
        _, raster, _ = sp.run(state_d, 0, {steps})
        sigs[(H, exchange)] = observables.raster_signature(
            np.asarray(raster), np.asarray(sp.plan.gid))

vals = set(sigs.values())
assert len(vals) == 1, f'raster signatures diverge: {{sigs}}'
print('DETERMINISM OK', sorted(sigs)[0], len(sigs), 'OFFSETS',
      n_offsets[4])
"""


@pytest.mark.slow
def test_rasters_identical_across_H_and_exchange():
    out = run_with_devices(
        _CODE.format(gx=2, gy=2, npc=80, syn=30, steps=80,
                     profile="ring3"), 4, timeout=900)
    assert "DETERMINISM OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("profile,offsets_h4",
                         [("ring1", 3), ("gaussian:sigma=1.5", 4)])
def test_rasters_identical_across_H_and_exchange_per_profile(profile,
                                                             offsets_h4):
    """The same six (H, exchange) points at a narrower (reach 1) and a
    wider-than-paper (reach 5) halo.  The 16x1 grid out-spans every
    kernel at H=4 block shards (halo spans 6 / 10 / 14 of 16 columns for
    reach 1 / 3 / 5), so the halo schedules genuinely differ per profile
    — pinned via the H=4 offset count — instead of all wrapping to the
    full grid as they would on 2x2."""
    out = run_with_devices(
        _CODE.format(gx=16, gy=1, npc=24, syn=12, steps=60,
                     profile=profile), 4, timeout=900)
    assert "DETERMINISM OK" in out
    assert f"OFFSETS {offsets_h4}" in out
