"""Distributed determinism: the paper's Table 1 invariant as a pytest.

Both exchange modes ('halo' sparse AER delivery and 'allgather' dense
masks) must produce bit-identical raster signatures at every shard count
H in {1, 2, 4}.  One subprocess with 4 forced host devices runs all six
(H, exchange) points; the benchmark asserts the same invariant at larger
scale outside pytest (benchmarks/scaling.py)."""
import pytest

from _mp_helpers import run_with_devices

_CODE = """
import numpy as np
from repro.core import EngineConfig, GridConfig, build, observables
from repro.core import distributed as D

cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=80,
                 synapses_per_neuron=30, seed=11)
sigs = {}
for H in (1, 2, 4):
    for exchange in ("halo", "allgather"):
        eng = EngineConfig(n_shards=H, exchange=exchange)
        spec, plan, state = build(cfg, eng)
        mesh = D.make_mesh(H)
        state_d = D.shard_put(mesh, state)
        runner = D.make_sharded_run(spec, plan, mesh)
        _, raster, _ = runner(state_d, 0, 80)
        sigs[(H, exchange)] = observables.raster_signature(
            np.asarray(raster), np.asarray(plan.gid))

vals = set(sigs.values())
assert len(vals) == 1, f'raster signatures diverge: {sigs}'
print('DETERMINISM OK', sorted(sigs)[0], len(sigs))
"""


@pytest.mark.slow
def test_rasters_identical_across_H_and_exchange():
    out = run_with_devices(_CODE, 4, timeout=900)
    assert "DETERMINISM OK" in out
