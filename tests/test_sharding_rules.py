"""Sharding-rule inference unit tests (no devices needed beyond 1: we only
construct specs against an abstract mesh built from the single CPU device
via mesh_utils-style fakes — here we just need axis names/sizes, so we use
a 1-device mesh and check the *fallback* logic, plus a fake-shaped mesh via
subprocess for the 256-way rules)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.dist.compat import make_mesh

from _mp_helpers import run_with_devices


def test_fit_drops_missing_axes():
    mesh = make_mesh((1,), ("model",))
    spec = shd._fit((64, 64), [(("pod", "data"), "model")], mesh)
    assert spec == P(None, "model")


def test_fit_drops_nondivisible():
    mesh = make_mesh((1,), ("model",))
    # 63 not divisible by 1? always divisible by 1 -> kept
    spec = shd._fit((63,), [("model",)], mesh)
    assert spec == P("model")


def test_use_mesh_noop_without_binding():
    x = jax.numpy.ones((4, 4))
    assert shd.shard(x, "batch", None) is x


_RULES_CODE = """
import jax
from jax.sharding import PartitionSpec as P
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()           # 16 x 16

# embedding with divisible vocab -> vocab-sharded + fsdp
s = shd.infer_param_spec('/embed', (151936, 1024), mesh)
assert s == P('model', 'data'), s
# odd vocab -> d-dim fallback over both axes
s = shd.infer_param_spec('/embed', (122753, 2304), mesh)
assert s == P(None, ('data', 'model')), s
# attention in-proj
s = shd.infer_param_spec('/stack/units/layer0/mixer/wq', (1, 1024, 2048),
                         mesh)
assert s == P(None, 'data', 'model'), s
# moe experts divisible -> EP on 'model', f split on 'data'
# (einsum-local layout, EXPERIMENTS.md MoE iteration 1)
s = shd.infer_param_spec('/stack/units/layer0/mlp/w_in', (1, 128, 5120,
                                                          8192), mesh)
assert s == P(None, 'model', None, 'data'), s
# moe experts non-divisible (granite 40) -> data-local experts, f on model
s = shd.infer_param_spec('/stack/units/layer0/mlp/w_in', (1, 40, 1536,
                                                          512), mesh)
assert s == P(None, None, None, 'model'), s
# kv cache seq sharding
s = shd.infer_cache_spec('/layers/units/layer0/kv/0',
                         (1, 128, 32768, 8, 128), mesh)
assert s == P(None, 'data', 'model', None, None), s
# batch=1 long-decode cache: batch falls back to replicated
s = shd.infer_cache_spec('/layers/rem/0/kv/0', (1, 524288, 16, 128), mesh)
assert s == P(None, 'model', None, None), s
# tokens
s = shd.infer_batch_spec('tokens', (256, 4096), mesh)
assert s == P('data', None), s
print('RULES OK')
"""


@pytest.mark.slow
def test_production_rules():
    out = run_with_devices(_RULES_CODE, 256)
    assert "RULES OK" in out
