"""Shared capability gate for the multi-process (cluster) tests.

A live 2-process probe — raw `jax.distributed` + a cross-process gather,
no engine code — decides once per pytest session whether this platform
can run localhost cluster jobs at all.  Tests `pytest.skip` when it
cannot (sandboxes without fork/sockets, jax builds without CPU
collectives), which keeps tier-1 green everywhere while CI's dedicated
cluster-smoke job runs the real thing unconditionally.
"""
import pytest

from _mp_helpers import SRC  # noqa: F401  (sys.path bootstrap)

from repro.cluster import local

# Raw-jax probe: reads the launcher's env contract directly so an engine
# regression can never masquerade as "platform unsupported".
_PROBE = """
import os
import jax
try:
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
except Exception:
    pass
jax.distributed.initialize(
    coordinator_address=os.environ["REPRO_CLUSTER_COORD"],
    num_processes=int(os.environ["REPRO_CLUSTER_NPROCS"]),
    process_id=int(os.environ["REPRO_CLUSTER_PROC_ID"]))
import jax.numpy as jnp
from jax.experimental import multihost_utils
out = multihost_utils.process_allgather(
    jnp.full((1,), jax.process_index()), tiled=True)
assert out.shape[0] == jax.process_count(), out
print("PROBE_OK", jax.process_count(), jax.device_count())
"""

_capable = None


def require_cluster() -> None:
    """Skip the calling test when localhost multi-process jax is
    unavailable; cached across the session."""
    global _capable
    if _capable is None:
        if not local.spawn_supported():
            _capable = "platform cannot spawn localhost cluster workers"
        else:
            try:
                outs = local.launch(["-c", _PROBE], nprocs=2,
                                    devices_per_proc=1, timeout=300)
                assert all("PROBE_OK 2" in o for o in outs), outs
                _capable = True
            except local.LaunchError as e:
                _capable = (f"multi-process jax unavailable here: "
                            f"{str(e)[:500]}")
    if _capable is not True:
        pytest.skip(_capable)
