"""Attention path equivalences: pruned vs dense chunked vs direct, ring
cache reads, GQA grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def _mk(b, hkv, g, t, s, dh, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(ks[0], (b, hkv, g, t, dh), jnp.float32),
            jax.random.normal(ks[1], (b, s, hkv, dh), jnp.float32),
            jax.random.normal(ks[2], (b, s, hkv, dh), jnp.float32))


CASES = [
    dict(causal=True, window=None, q_offset=0, t=1024, s=1024),
    dict(causal=True, window=256, q_offset=0, t=2048, s=2048),
    dict(causal=True, window=100, q_offset=0, t=1024, s=1024),
    dict(causal=True, window=None, q_offset=1024, t=1024, s=2048),
]


@pytest.mark.parametrize("case", CASES)
def test_pruned_equals_dense_chunked(case):
    case = dict(case)
    t, s = case.pop("t"), case.pop("s")
    q, k, v = _mk(2, 2, 2, t, s, 64, seed=t + s)
    kw = dict(softcap=None, scale=0.125, chunk_q=256, chunk_k=256, **case)
    o1 = A._chunked_gqa_pruned(q, k, v, **kw)
    o2 = A._chunked_gqa_dense(q, k, v, **kw)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_pruned_equals_direct_small():
    q, k, v = _mk(1, 2, 2, 256, 256, 32, seed=5)
    kw = dict(causal=True, window=64, softcap=30.0, scale=0.2, q_offset=0)
    o1 = A._chunked_gqa_pruned(q, k, v, chunk_q=64, chunk_k=64, **kw)
    o2 = A._direct_gqa(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5,
                               atol=2e-5)


def test_visible_pair_count_causal():
    """Causal pruning keeps ~half the pairs (the lower triangle)."""
    n = sum(A._visible(i, j, 128, 128, 0, True, None)
            for i in range(8) for j in range(8))
    assert n == 8 * 9 // 2


def test_visible_pair_count_window():
    """A window of one chunk keeps a 2-wide band."""
    n = sum(A._visible(i, j, 128, 128, 0, True, 128)
            for i in range(8) for j in range(8))
    assert n == 8 + 7  # diagonal + first subdiagonal


def test_ring_cache_decode_equals_linear():
    """Ring-buffer window cache must reproduce full-cache decode."""
    from repro.configs import get_smoke_config
    from repro.models import lm
    cfg = get_smoke_config("gemma3-27b")       # has la layers, window=64
    cfg = cfg.scaled(window=8)                 # force wrap quickly
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 24), 0,
                              cfg.vocab_size)
    # teacher-forced reference
    full, _ = lm.forward(cfg, params, {"tokens": toks})
    # stepwise with ring caches (s_max 24 > window 8 -> la layers wrap)
    cache = lm.init_cache(cfg, 1, 24)
    outs = []
    for i in range(24):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, i:i + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=0.3)
