"""Fast, spawn-free coverage of the cluster subsystem's pure parts: env
construction, worker-result parsing/aggregation, the BENCH report shape,
and the subprocess error contract (exit codes, timeouts)."""
import json

import pytest

from _mp_helpers import SRC
from repro import _flags
from repro.bench import report as bench_report
from repro.bench.subproc import SubprocessError, resolve_timeout, \
    run_subprocess
from repro.cluster import local, report as crep, runtime
from repro.cluster.worker import RESULT_PREFIX, workload_argv
from repro.cluster.cli import workload_namespace


# ---------------------------------------------------------------------------
# env construction (the one helper every spawner shares)
# ---------------------------------------------------------------------------


def test_cluster_env_wires_coordinator_and_devices(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=8")
    env = _flags.cluster_env(2, SRC, coordinator="127.0.0.1:1234",
                             num_processes=4, process_id=3)
    assert env[_flags.ENV_COORD] == "127.0.0.1:1234"
    assert env[_flags.ENV_NUM_PROCS] == "4"
    assert env[_flags.ENV_PROC_ID] == "3"
    # last-flag-wins: worker count appended AFTER the ambient CI count
    assert env["XLA_FLAGS"].endswith(
        "--xla_force_host_platform_device_count=2")
    assert "device_count=8" in env["XLA_FLAGS"]
    assert env["JAX_CPU_COLLECTIVES_IMPLEMENTATION"] == "gloo"
    assert env["PYTHONPATH"].startswith(SRC)


def test_cluster_env_respects_explicit_collectives(monkeypatch):
    monkeypatch.setenv("JAX_CPU_COLLECTIVES_IMPLEMENTATION", "mpi")
    env = _flags.cluster_env(1, SRC, coordinator="h:1", num_processes=2,
                             process_id=0)
    assert env["JAX_CPU_COLLECTIVES_IMPLEMENTATION"] == "mpi"


def test_runtime_from_env_roundtrip(monkeypatch):
    for v in (_flags.ENV_COORD, _flags.ENV_NUM_PROCS, _flags.ENV_PROC_ID):
        monkeypatch.delenv(v, raising=False)
    assert runtime.from_env() is None
    monkeypatch.setenv(_flags.ENV_COORD, "127.0.0.1:9")
    with pytest.raises(RuntimeError, match="partial cluster environment"):
        runtime.from_env()
    monkeypatch.setenv(_flags.ENV_NUM_PROCS, "2")
    monkeypatch.setenv(_flags.ENV_PROC_ID, "1")
    cfg = runtime.from_env()
    assert cfg == runtime.ClusterConfig("127.0.0.1:9", 2, 1)


def test_workload_argv_roundtrips_through_parser():
    import argparse

    from repro.cluster.worker import add_workload_args
    args = workload_namespace(grid="4x2", neurons_per_column=75, steps=33,
                              shards=8, exchange="halo", ckpt="/tmp/c.npz")
    ap = argparse.ArgumentParser()
    add_workload_args(ap)
    args2 = ap.parse_args(workload_argv(args))
    assert vars(args2) == vars(args)


# ---------------------------------------------------------------------------
# worker-result parsing + aggregation
# ---------------------------------------------------------------------------


def _result(proc, nprocs=2, sig="ab" * 32, wall=1.0, **kw):
    r = dict(proc=proc, nprocs=nprocs, shards=4, t0=0, steps=50,
             exchange="allgather", placement="block", local_devices=2,
             wall_s=wall, spikes=123, rate_hz=10.5, raster_sig=sig,
             phase_a_s=0.2, exchange_s=0.1, phase_b_s=0.3)
    r.update(kw)
    return r


def _stdout(result):
    return ("some jax warning\n" + RESULT_PREFIX + json.dumps(result)
            + "\ntrailing noise\n")


def test_parse_worker_outputs_orders_by_proc():
    outs = [_stdout(_result(1)), _stdout(_result(0))]
    res = crep.parse_worker_outputs(outs)
    assert [r["proc"] for r in res] == [0, 1]


def test_parse_worker_outputs_rejects_missing_result():
    with pytest.raises(ValueError, match="exactly one"):
        crep.parse_worker_outputs(["no result line here"])


def test_summarize_point_takes_max_wall_and_phases():
    row = crep.summarize_point([_result(0, wall=1.0, exchange_s=0.1),
                                _result(1, wall=2.5, exchange_s=0.9)])
    assert row["wall_s"] == 2.5
    assert row["exchange_s"] == 0.9
    assert len(row["per_proc"]) == 2


def test_summarize_point_rejects_diverging_rasters():
    with pytest.raises(ValueError, match="diverge"):
        crep.summarize_point([_result(0, sig="aa" * 32),
                              _result(1, sig="bb" * 32)])


def test_summarize_point_rejects_missing_proc():
    with pytest.raises(ValueError, match="expected results from procs"):
        crep.summarize_point([_result(0), _result(0)])


def test_scaling_report_is_bench_schema_valid():
    rows = [crep.summarize_point([_result(0, nprocs=1)]),
            crep.summarize_point([_result(0), _result(1, wall=2.0)])]
    rep = crep.scaling_report(rows, dict(quick=True, nprocs=[1, 2]))
    assert bench_report.validate(rep) == []
    assert rep["deterministic"]["identical_across_procs"] is True
    assert rep["wall"]["p1_wall_s"] == 1.0
    assert rep["wall"]["p2_wall_s"] == 2.0
    assert rep["wall"]["p2_exchange_s"] == 0.1
    assert rep["extra"]["points"][1]["per_proc"][1]["proc"] == 1


# ---------------------------------------------------------------------------
# subprocess error contract (shared by tests/bench/cluster spawners)
# ---------------------------------------------------------------------------


def test_run_subprocess_surfaces_exit_code():
    with pytest.raises(SubprocessError) as ei:
        run_subprocess("import sys; sys.exit(3)", timeout=60)
    assert ei.value.returncode == 3
    assert "exit code 3" in str(ei.value)


def test_run_subprocess_timeout_mentions_budget():
    with pytest.raises(SubprocessError) as ei:
        run_subprocess("import time; time.sleep(60)", timeout=1)
    assert ei.value.returncode is None
    assert "timed out after" in str(ei.value)


def test_resolve_timeout_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_SUBPROC_TIMEOUT", "123.5")
    assert resolve_timeout(None) == 123.5
    assert resolve_timeout(7.0) == 7.0


def test_launch_rejects_bad_nprocs():
    with pytest.raises(ValueError):
        local.launch(["-c", "pass"], nprocs=0)


def test_free_port_is_bindable_int():
    p = local.free_port()
    assert isinstance(p, int) and 0 < p < 65536
