"""Per-architecture smoke tests: reduced config of the same family, one
forward + train-grad step + (where applicable) decode step on CPU; asserts
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm

B, T = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    b = {}
    if cfg.modality == "vlm":
        b["embeds"] = jax.random.normal(ks[0], (B, T, cfg.d_model),
                                        jnp.bfloat16)
    else:
        b["tokens"] = jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.random.normal(ks[1], (B, 24, cfg.d_model),
                                            jnp.bfloat16)
        b["tokens"] = jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size)
    b["labels"] = jax.random.randint(ks[2], (B, T), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits, aux = jax.jit(lambda p, b: lm.forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))

    def loss(p):
        l, _ = lm.loss_fn(cfg, p, batch)
        return l

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert bool(jnp.isfinite(val))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all())
               for g in flat)
    # at least some gradient signal everywhere important
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in flat) ** 0.5
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    if cfg.modality == "vlm":
        pytest.skip("vlm decode exercised via text path (same backbone)")
    params = lm.init_params(cfg, jax.random.key(0))
    enc_out = None
    if cfg.family == "encdec":
        enc = jax.random.normal(jax.random.key(5), (B, 24, cfg.d_model),
                                jnp.bfloat16)
        enc_out = lm.encode(cfg, params, enc)
    cache = lm.init_cache(cfg, B, 32, enc_out=enc_out)
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda c, t: lm.decode_step(cfg, params, c, t))
    for i in range(3):
        logits, cache = step(cache, tok)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        tok = logits.argmax(-1).astype(jnp.int32)
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Greedy decode logits == teacher-forced forward logits (same prefix).

    Recurrent/windowed archs must agree too: the cache math is exact."""
    cfg = get_smoke_config(arch)
    if cfg.modality == "vlm":
        pytest.skip("vlm uses embeds input; equivalence tested via text archs")
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(7), (B, 8), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    enc_out = None
    if cfg.family == "encdec":
        enc = jax.random.normal(jax.random.key(5), (B, 24, cfg.d_model),
                                jnp.bfloat16)
        batch["enc_embeds"] = enc
        enc_out = lm.encode(cfg, params, enc)
    full_logits, _ = jax.jit(lambda p, b: lm.forward(cfg, p, b))(params,
                                                                 batch)

    cache = lm.init_cache(cfg, B, 8, enc_out=enc_out)
    outs = []
    step = jax.jit(lambda c, t: lm.decode_step(cfg, params, c, t))
    for i in range(8):
        lg, cache = step(cache, toks[:, i:i + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    # bf16 params + different accumulation order => noise on near-zero
    # logits; atol set to ~0.2% of the observed logit scale.
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=0.25)


def test_param_counts_match_published():
    """Full configs must land near the published parameter counts."""

    def count(cfg):
        d, H, Hkv, dh, f, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim, cfg.d_ff, cfg.vocab_size,
                                  cfg.n_layers)
        total = V * d * (1 if cfg.tie_embeddings else 2)
        for (mixer, mlp) in cfg.layers:
            if mixer in ("ga", "la", "bi", "xa"):
                attn = d * H * dh + 2 * d * Hkv * dh + H * dh * d
                total += attn * (2 if mixer == "xa" else 1)
            elif mixer == "rg":
                dr = cfg.rg_lru_width or d
                total += 2 * d * dr + 2 * dr * dr + dr * d
            elif mixer == "rwkv":
                total += 4 * d * d + d * d
            if mlp == "dense":
                total += d * f * (3 if cfg.act == "swiglu" else 2)
            elif mlp == "moe":
                m = cfg.moe
                per = m.d_ff_expert * d * (3 if cfg.act == "swiglu" else 2)
                total += m.n_experts * per + d * m.n_experts
                if m.shared_expert:
                    total += per
            elif mlp == "cmix":
                total += d * f * 2 + d * d
        if cfg.family == "encdec":
            attn = d * H * dh + 2 * d * Hkv * dh + H * dh * d
            total += cfg.n_encoder_layers * (
                attn + d * f * (3 if cfg.act == "swiglu" else 2))
        return total

    published = {
        "minicpm-2b": 2.4e9, "internlm2-20b": 19.9e9, "gemma3-27b": 27e9,
        "qwen3-0.6b": 0.6e9, "llava-next-34b": 34e9,
        "recurrentgemma-2b": 2.7e9, "rwkv6-1.6b": 1.6e9,
        # seamless-m4t-medium is 1.2B incl. the conformer speech frontend,
        # which is a stub by spec; the transformer backbone is ~0.6B.
        "seamless-m4t-medium": 0.6e9, "granite-moe-3b-a800m": 3.3e9,
        "llama4-maverick-400b-a17b": 400e9,
    }
    for arch, want in published.items():
        got = count(get_config(arch))
        assert 0.55 * want < got < 1.55 * want, \
            f"{arch}: analytic {got/1e9:.2f}B vs published {want/1e9:.1f}B"
