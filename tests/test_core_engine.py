"""Core engine behaviour: Izhikevich dynamics, STDP rule, delay ring,
and the paper's headline property — identical rasters over any distribution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DEFAULT_IZH, DEFAULT_STDP, EngineConfig, GridConfig,
                        build, observables, run)
from repro.core import engine as E

SMALL = GridConfig(grid_x=2, grid_y=2, neurons_per_column=100,
                   synapses_per_neuron=40, seed=7)


# ---------------------------------------------------------------------------
# Izhikevich neuron unit behaviour
# ---------------------------------------------------------------------------

class TestIzhikevich:
    def _run_single(self, current, steps=300, exc=True):
        from repro.core import neuron
        p = DEFAULT_IZH
        exc_mask = jnp.array([exc])
        st = neuron.init_state(exc_mask, p)
        vs, spikes = [], 0
        for _ in range(steps):
            st, spk = neuron.step(st, jnp.array([current], jnp.float32),
                                  exc_mask, p)
            vs.append(float(st.v[0]))
            spikes += int(spk[0])
        return np.array(vs), spikes

    def test_resting_neuron_stays_near_rest(self):
        vs, spikes = self._run_single(0.0)
        assert spikes == 0
        # equilibrium of 0.04v^2+5v+140-u = 0 with u = b v  ->  v = -70
        assert abs(vs[-1] + 70.0) < 5.0

    def test_dc_current_causes_regular_spiking(self):
        vs, spikes = self._run_single(10.0)
        assert spikes > 3
        assert np.isfinite(vs).all()

    def test_fs_spikes_faster_than_rs(self):
        _, rs = self._run_single(10.0, exc=True)
        _, fs = self._run_single(10.0, exc=False)
        assert fs > rs  # FS inhibitory neurons have a higher firing rate

    def test_reset_after_spike(self):
        from repro.core import neuron
        p = DEFAULT_IZH
        exc_mask = jnp.array([True])
        st = neuron.init_state(exc_mask, p)
        fired = False
        for _ in range(200):
            st, spk = neuron.step(st, jnp.array([15.0], jnp.float32),
                                  exc_mask, p)
            if bool(spk[0]):
                fired = True
                assert float(st.v[0]) == pytest.approx(p.c_exc)
                break
        assert fired


# ---------------------------------------------------------------------------
# engine end-to-end on a small grid
# ---------------------------------------------------------------------------

class TestEngineRun:
    def test_runs_and_spikes(self):
        spec, plan, state = build(SMALL, EngineConfig(n_shards=1))
        state, raster, tm = run(spec, plan, state, 0, 200)
        raster = np.asarray(raster)
        assert raster.shape == (200, 1, spec.n_local)
        rate = observables.mean_rate_hz(raster, SMALL.n_neurons)
        assert 1.0 < rate < 200.0      # alive, not epileptic
        assert np.isfinite(np.asarray(state.v)).all()
        assert np.isfinite(np.asarray(state.w)).all()

    def test_weights_stay_in_bounds(self):
        spec, plan, state = build(SMALL, EngineConfig(n_shards=1))
        state, _, _ = run(spec, plan, state, 0, 300)
        w = np.asarray(state.w)
        plastic = np.asarray(plan.syn_plastic)
        valid = np.asarray(plan.syn_valid)
        assert (w[plastic & valid] >= DEFAULT_STDP.w_min - 1e-6).all()
        assert (w[plastic & valid] <= DEFAULT_STDP.w_max + 1e-6).all()
        # inhibitory weights are non-plastic: exactly the initial value
        inh = valid & ~plastic
        assert np.all(w[inh] == SMALL.w_inh_init)

    def test_stdp_changes_weights(self):
        spec, plan, state = build(SMALL, EngineConfig(n_shards=1))
        w0 = np.asarray(state.w).copy()
        state, _, _ = run(spec, plan, state, 0, 300)
        w1 = np.asarray(state.w)
        plastic = np.asarray(plan.syn_plastic & plan.syn_valid)
        assert np.abs(w1[plastic] - w0[plastic]).max() > 1e-3

    def test_initial_rate_in_paper_band(self):
        """Paper Table 1: initial activity 20-48 Hz with strong init weights.
        (Single 1000-neuron column -> paper reports 20 Hz.)"""
        cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=1000,
                         synapses_per_neuron=200)
        spec, plan, state = build(cfg, EngineConfig(n_shards=1))
        _, raster, _ = run(spec, plan, state, 0, 500)
        rate = observables.mean_rate_hz(np.asarray(raster), cfg.n_neurons)
        assert 10.0 < rate < 60.0


# ---------------------------------------------------------------------------
# THE paper property: identical spiking for every distribution
# ---------------------------------------------------------------------------

def _signature(cfg, eng, steps=150):
    spec, plan, state = build(cfg, eng)
    _, raster, _ = run(spec, plan, state, 0, steps)
    return observables.raster_signature(np.asarray(raster),
                                        np.asarray(plan.gid))


class TestDistributionInvariance:
    def test_identical_rasters_across_shard_counts(self):
        ref = _signature(SMALL, EngineConfig(n_shards=1))
        for h in (2, 4, 8):
            assert _signature(SMALL, EngineConfig(n_shards=h)) == ref, \
                f"raster changed at H={h}"

    def test_identical_rasters_block_vs_scatter(self):
        ref = _signature(SMALL, EngineConfig(n_shards=1))
        assert _signature(SMALL, EngineConfig(n_shards=4,
                                              placement="scatter")) == ref

    def test_identical_rasters_fractional_columns(self):
        # 3 shards over 4 columns: shards own 133.33 neurons -> column splits
        ref = _signature(SMALL, EngineConfig(n_shards=1))
        assert _signature(SMALL, EngineConfig(n_shards=3)) == ref

    def test_single_column_self_projection(self):
        # paper: a single column projects all synapses onto itself
        cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=80,
                         synapses_per_neuron=30, seed=3)
        ref = _signature(cfg, EngineConfig(n_shards=1))
        assert _signature(cfg, EngineConfig(n_shards=2)) == ref


# ---------------------------------------------------------------------------
# delay / polychrony machinery
# ---------------------------------------------------------------------------

class TestDelays:
    def test_arrival_ring_slots(self):
        """A spike emitted at t with delay d must arrive exactly at t+d."""
        cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=50,
                         synapses_per_neuron=10, seed=11,
                         stim_events_per_ms_per_column=0)  # silence
        spec, plan, state = build(cfg, EngineConfig(n_shards=1))
        step = E.make_step_fn(spec, plan)

        # force neuron 0 to spike at t=0 by injecting via v
        state = state._replace(v=state.v.at[0, 0].set(40.0))
        arrivals = []
        for t in range(8):
            state, (spiked, tm) = jax.jit(step)(state, jnp.int32(t))
            arrivals.append(int(tm.arrivals[0]))
        # synapses of neuron 0 (valid, src==0)
        src_gid = np.asarray(plan.src_gid[0])
        syn_src = np.asarray(plan.syn_src[0])
        valid = np.asarray(plan.syn_valid[0])
        from_n0 = valid & (src_gid[syn_src] == 0)
        delays = np.asarray(plan.syn_delay[0])[from_n0]
        expect = np.zeros(8, dtype=int)
        for d in delays:
            if d < 8:
                expect[d] += 1
        # no other activity: arrivals must match the delay histogram exactly
        assert arrivals == expect.tolist()

    def test_no_stimulus_no_activity(self):
        cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=50,
                         synapses_per_neuron=10,
                         stim_events_per_ms_per_column=0)
        spec, plan, state = build(cfg, EngineConfig(n_shards=1))
        _, raster, _ = run(spec, plan, state, 0, 50)
        assert np.asarray(raster).sum() == 0
