import os
import sys

# Make src/ importable when PYTHONPATH is not set.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device.  Multi-device tests spawn
# subprocesses with their own XLA_FLAGS (see tests/_mp_helpers.py).
