"""Miniature dry-run: the full lower->compile->analyze pipeline on a small
mesh (8 fake devices) with reduced configs — fast enough for CI, proves the
launch plumbing end-to-end.  The production 512-device matrix runs via
`python -m repro.launch.dryrun --all` (results in results/dryrun/)."""

import pytest

from _mp_helpers import run_with_devices

_CODE = """
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.dist.compat import make_mesh
from repro.dist import sharding as shd
from repro.launch import hlo_cost
from repro.launch import input_specs as ispec
from repro.optim import schedules
from repro.train import step as step_mod
from repro.train.train_state import TrainState
from repro.optim import adamw
from repro.models import lm

mesh = make_mesh((2, 4), ('data', 'model'))
cfg = get_smoke_config({arch!r})

with shd.use_mesh(mesh):
    params = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                            jax.random.key(0))
    shardings = shd.tree_shardings(params, mesh, shd.infer_param_spec)
    params = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params, shardings)

    def like_f32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                    sharding=p.sharding)
    state = TrainState(params=params,
                       opt=adamw.AdamWState(
                           step=jax.ShapeDtypeStruct((), jnp.int32),
                           m=jax.tree.map(like_f32, params),
                           v=jax.tree.map(like_f32, params)),
                       step=jax.ShapeDtypeStruct((), jnp.int32),
                       ef_residual=None)
    B, T = 8, 64
    batch = {{'tokens': jax.ShapeDtypeStruct((B, T), jnp.int32),
             'labels': jax.ShapeDtypeStruct((B, T), jnp.int32)}}
    if cfg.modality == 'vlm':
        batch['embeds'] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                               jnp.bfloat16)
    if cfg.family == 'encdec':
        batch['enc_embeds'] = jax.ShapeDtypeStruct((B, 32, cfg.d_model),
                                                   jnp.bfloat16)
    fn = step_mod.make_train_step(
        cfg, lr_schedule=schedules.constant(1e-3))
    lowered = jax.jit(fn).lower(state, batch)
    compiled = lowered.compile()

mem = compiled.memory_analysis()
res = hlo_cost.analyze(compiled.as_text())
assert res['flops'] > 0, 'analyzer found no FLOPs'
assert mem.temp_size_in_bytes > 0
print('DRYRUN_SMALL OK', res['flops'] > 0, res['collectives']['total'])
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "granite-moe-3b-a800m",
                                  "recurrentgemma-2b", "rwkv6-1.6b",
                                  "seamless-m4t-medium"])
def test_small_mesh_dryrun(arch):
    out = run_with_devices(_CODE.format(arch=arch), 8, timeout=900)
    assert "DRYRUN_SMALL OK" in out
