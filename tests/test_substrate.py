"""Substrate tests: optimizer, schedules, grad compression, data pipeline,
trainer fault tolerance, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import pipeline, synthetic
from repro.models import lm
from repro.optim import adamw, grad_utils, schedules
from repro.serve.engine import Request, ServeEngine
from repro.train import step as step_mod
from repro.train import train_state as ts_mod
from repro.train.train_state import create
from repro.train.trainer import Trainer


class TestAdamW:
    def test_minimizes_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        st = adamw.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, st = adamw.update(g, st, params, lr=0.05,
                                      weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.array([1.0])}
        st = adamw.init(params)
        g = {"w": jnp.array([0.0])}
        p2, _ = adamw.update(g, st, params, lr=0.1, weight_decay=0.5)
        assert float(p2["w"][0]) < 1.0


class TestSchedules:
    def test_wsd_phases(self):
        f = schedules.wsd(1e-3, warmup=10, stable=20, decay=10,
                          final_frac=0.1)
        assert float(f(jnp.int32(5))) == pytest.approx(5e-4)
        assert float(f(jnp.int32(20))) == pytest.approx(1e-3)
        assert float(f(jnp.int32(40))) == pytest.approx(1e-4, rel=1e-3)

    def test_cosine_endpoints(self):
        f = schedules.cosine(1e-3, warmup=10, total=100)
        assert float(f(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


class TestGradUtils:
    def test_clip(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = grad_utils.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        assert grad_utils.global_norm(clipped) <= 1.0 + 1e-5

    def test_error_feedback_unbiased(self):
        """Sum of compressed grads + final residual == sum of true grads."""
        key = jax.random.key(0)
        res = {"w": jnp.zeros((64,), jnp.float32)}
        total_true = jnp.zeros((64,))
        total_sent = jnp.zeros((64,))
        for i in range(20):
            g = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                        (64,)) * 1e-3}
            comp, res = grad_utils.compress_with_feedback(g, res)
            total_true += g["w"]
            total_sent += comp["w"].astype(jnp.float32)
        np.testing.assert_allclose(total_sent + res["w"], total_true,
                                   rtol=1e-5, atol=1e-6)


class TestData:
    def test_deterministic(self):
        a = synthetic.batch_tokens(1, 5, 4, 32, 1000)
        b = synthetic.batch_tokens(1, 5, 4, 32, 1000)
        assert np.array_equal(a, b)
        c = synthetic.batch_tokens(1, 6, 4, 32, 1000)
        assert not np.array_equal(a, c)

    def test_labels_shifted(self):
        cfg = get_smoke_config("qwen3-0.6b")
        b = pipeline.Batcher(cfg, 2, 16, seed=0).make(0)
        assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetch(self):
        it = pipeline.prefetch(iter(range(5)), depth=2)
        assert list(it) == [0, 1, 2, 3, 4]


class TestTrainerFaultTolerance:
    def _mk(self, tmp_path):
        cfg = get_smoke_config("qwen3-0.6b")
        params = lm.init_params(cfg, jax.random.key(0))
        state = create(params)
        step = step_mod.make_train_step(
            cfg, lr_schedule=schedules.constant(1e-3))
        data = iter(pipeline.Batcher(cfg, 2, 16, seed=1))
        return cfg, state, step, data

    def test_resume_from_checkpoint(self, tmp_path):
        cfg, state, step, data = self._mk(tmp_path)
        tr = Trainer(step, state, ckpt_dir=str(tmp_path), ckpt_every=5,
                     log_every=100, log_fn=lambda *a: None)
        tr.run(data, 7)
        assert ts_mod.latest(str(tmp_path)) is not None

        # simulate preemption: new trainer, must resume at step 7
        cfg, state2, step2, data2 = self._mk(tmp_path)
        tr2 = Trainer(step2, state2, ckpt_dir=str(tmp_path),
                      log_every=100, log_fn=lambda *a: None)
        assert tr2.maybe_resume() == 7

    def test_checkpoint_roundtrip_exact(self, tmp_path):
        cfg, state, step, data = self._mk(tmp_path)
        state2, _ = jax.jit(step)(state, next(data))
        p = ts_mod.save(os.path.join(str(tmp_path), "lm_1.npz"), state2)
        state3 = ts_mod.load(p, state2)
        for a, b in zip(jax.tree.leaves(state2), jax.tree.leaves(state3)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestServeEngine:
    def test_batched_requests(self):
        cfg = get_smoke_config("qwen3-0.6b")
        params = lm.init_params(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, batch=2, s_max=48)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=6)
                        .astype(np.int32), max_new=4) for _ in range(3)]
        done = eng.run(reqs)
        assert all(r.out is not None and r.out.shape == (4,) for r in done)

    def test_greedy_deterministic(self):
        cfg = get_smoke_config("qwen3-0.6b")
        params = lm.init_params(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, batch=1, s_max=32)
        prompt = np.arange(5, dtype=np.int32)
        a = eng.run([Request(prompt=prompt, max_new=5)])[0].out
        b = eng.run([Request(prompt=prompt, max_new=5)])[0].out
        assert np.array_equal(a, b)

    def test_refill_does_not_change_existing_slots(self):
        # continuous batching: a long request's output must be identical
        # whether it decodes alone or a finished companion's slot is
        # refilled mid-flight (per-slot prefill touches only slot b)
        cfg = get_smoke_config("qwen3-0.6b")
        params = lm.init_params(cfg, jax.random.key(0))
        rng = np.random.default_rng(7)
        long_p = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
        solo = ServeEngine(cfg, params, batch=2, s_max=48).run(
            [Request(prompt=long_p.copy(), max_new=8)])[0].out
        reqs = [Request(prompt=long_p.copy(), max_new=8),
                Request(prompt=rng.integers(0, cfg.vocab_size, size=4)
                        .astype(np.int32), max_new=2),
                Request(prompt=rng.integers(0, cfg.vocab_size, size=5)
                        .astype(np.int32), max_new=2),
                Request(prompt=rng.integers(0, cfg.vocab_size, size=3)
                        .astype(np.int32), max_new=2)]
        done = ServeEngine(cfg, params, batch=2, s_max=48).run(reqs)
        assert all(r.out is not None for r in done)
        assert np.array_equal(done[0].out, solo)


class TestMicrobatch:
    def test_accumulation_matches_full_batch(self):
        cfg = get_smoke_config("qwen3-0.6b")
        params = lm.init_params(cfg, jax.random.key(0))
        b = pipeline.Batcher(cfg, 4, 16, seed=1).make(0)
        b = jax.tree.map(jnp.asarray, b)
        full = step_mod.make_train_step(
            cfg, lr_schedule=schedules.constant(1e-3))
        micro = step_mod.make_train_step(
            cfg, lr_schedule=schedules.constant(1e-3), microbatch=2)
        s1, m1 = jax.jit(full)(create(params), b)
        s2, m2 = jax.jit(micro)(create(params), b)
        # identical data => losses close; params close after 1 step
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  rel=2e-2)
        d = max(float(jnp.abs(a.astype(jnp.float32)
                              - c.astype(jnp.float32)).max())
                for a, c in zip(jax.tree.leaves(s1.params),
                                jax.tree.leaves(s2.params)))
        assert d < 5e-2
