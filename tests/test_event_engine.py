"""Event-driven delivery backend: equivalence vs the dense engine and
AER-style saturation accounting."""
import jax
import numpy as np
import pytest

from repro.core import (EngineConfig, GridConfig, observables)
from repro.core import engine as E
from repro.core import event_engine as EV

CFG = GridConfig(grid_x=2, grid_y=2, neurons_per_column=100,
                 synapses_per_neuron=40, seed=7)


@pytest.fixture(scope="module")
def built():
    eng = EngineConfig(n_shards=2, delivery="event")
    spec, plan, eplan, state = EV.build(CFG, eng)
    return spec, plan, eplan, state


def test_event_matches_dense_rasters(built):
    spec, plan, eplan, estate = built
    steps = 150
    # dense reference
    _, plan_d, dstate = E.build(CFG, EngineConfig(n_shards=2))
    _, raster_d, _ = E.run(spec, plan_d, dstate, 0, steps)
    sig_d = observables.raster_signature(np.asarray(raster_d),
                                         np.asarray(plan_d.gid))
    # event backend
    estate2, raster_e = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, steps))(estate)
    sig_e = observables.raster_signature(np.asarray(raster_e),
                                         np.asarray(plan.gid))
    assert sig_e == sig_d, "event backend diverged from dense rasters"
    assert int(np.asarray(estate2.sat).sum()) == 0, "unexpected saturation"


def test_event_matches_dense_weights(built):
    spec, plan, eplan, estate = built
    steps = 120
    _, plan_d, dstate = E.build(CFG, EngineConfig(n_shards=2))
    dstate2, _, _ = E.run(spec, plan_d, dstate, 0, steps)
    estate2, _ = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, steps))(estate)
    # scatter-add vs canonical segment-sum: fp32 order differs -> allclose
    np.testing.assert_allclose(np.asarray(estate2.base.w),
                               np.asarray(dstate2.w), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(estate2.base.v),
                               np.asarray(dstate2.v), rtol=1e-3, atol=1e-2)


def test_saturation_counter_triggers_when_capped():
    """Tiny event capacity must saturate, not corrupt."""
    eng = EngineConfig(n_shards=1, delivery="event")
    spec, plan, base = E.build(
        GridConfig(grid_x=1, grid_y=1, neurons_per_column=100,
                   synapses_per_neuron=40, seed=3), eng)
    eplan, _ = EV.build_event_plan(spec)
    state = EV.init_event_state(spec, base, cap_ev=8)   # absurdly small
    state2, raster = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, 80))(state)
    assert int(np.asarray(state2.sat).sum()) > 0
    assert np.isfinite(np.asarray(state2.base.v)).all()
