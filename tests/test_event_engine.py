"""Event-driven delivery backend: equivalence vs the dense engine,
AER-style saturation accounting (ring AND compaction caps), and the
sort-free hot path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, GridConfig, observables)
from repro.core import engine as E
from repro.core import event_engine as EV

CFG = GridConfig(grid_x=2, grid_y=2, neurons_per_column=100,
                 synapses_per_neuron=40, seed=7)


@pytest.fixture(scope="module")
def built():
    eng = EngineConfig(n_shards=2, delivery="event")
    spec, plan, eplan, state = EV.build(CFG, eng)
    return spec, plan, eplan, state


def test_event_matches_dense_rasters(built):
    spec, plan, eplan, estate = built
    steps = 150
    # dense reference
    _, plan_d, dstate = E.build(CFG, EngineConfig(n_shards=2))
    _, raster_d, _ = E.run(spec, plan_d, dstate, 0, steps)
    sig_d = observables.raster_signature(np.asarray(raster_d),
                                         np.asarray(plan_d.gid))
    # event backend
    estate2, raster_e, _ = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, steps))(estate)
    sig_e = observables.raster_signature(np.asarray(raster_e),
                                         np.asarray(plan.gid))
    assert sig_e == sig_d, "event backend diverged from dense rasters"
    assert int(np.asarray(estate2.sat).sum()) == 0, "unexpected saturation"


def test_event_matches_dense_weights(built):
    spec, plan, eplan, estate = built
    steps = 120
    _, plan_d, dstate = E.build(CFG, EngineConfig(n_shards=2))
    dstate2, _, _ = E.run(spec, plan_d, dstate, 0, steps)
    estate2, _, _ = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, steps))(estate)
    # scatter-add vs canonical segment-sum: fp32 order differs -> allclose
    np.testing.assert_allclose(np.asarray(estate2.base.w),
                               np.asarray(dstate2.w), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(estate2.base.v),
                               np.asarray(dstate2.v), rtol=1e-3, atol=1e-2)


def test_event_timings_count_events(built):
    """phase_a reports (spikes, arrivals) like the dense engine — the
    arrival counter is the event-list occupancy, which bounds per-step
    synaptic work (the paper's event-driven claim, measurable)."""
    spec, plan, eplan, estate = built
    _, raster, tm = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, 50))(estate)
    spikes = int(np.asarray(tm.spikes).sum())
    arrivals = int(np.asarray(tm.arrivals).sum())
    assert spikes == int(np.asarray(raster).sum())
    assert arrivals > 0
    # every spike fans out to at most Kf * ... events; arrivals are the
    # delivered subset and must stay far below E * steps (dense work)
    assert arrivals < spec.e_cap * 50 * 2


def test_no_sort_on_event_hot_path(built):
    """Acceptance gate: compaction is cumsum-rank based — no sort
    primitive anywhere in the step (including nested scan/vmap bodies)."""
    spec, plan, eplan, estate = built

    def prims(jaxpr, acc):
        for eqn in jaxpr.eqns:
            acc.add(eqn.primitive.name)
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    prims(v.jaxpr, acc)
                if isinstance(v, (list, tuple)):
                    for vv in v:
                        if hasattr(vv, "jaxpr"):
                            prims(vv.jaxpr, acc)
        return acc

    step = EV.make_step_fn(spec, plan, eplan)
    closed = jax.make_jaxpr(step)(estate, jnp.int32(0))
    names = prims(closed.jaxpr, set())
    assert not any("sort" in n for n in names), sorted(names)


# ---------------------------------------------------------------------------
# saturation paths: every static capacity must degrade by dropping events
# (counted in state.sat), never by corrupting state
# ---------------------------------------------------------------------------


def _finite_and_counted(state2, raster):
    assert int(np.asarray(state2.sat).sum()) > 0, "expected saturation"
    assert np.isfinite(np.asarray(state2.base.v)).all()
    assert np.isfinite(np.asarray(state2.base.w)).all()
    r = np.asarray(raster)
    assert r.dtype == np.bool_ and r.ndim == 3


def test_ring_capacity_saturates_not_corrupts():
    """Tiny cap_ev: slot lists overflow."""
    eng = EngineConfig(n_shards=1, delivery="event")
    spec, plan, base = E.build(
        GridConfig(grid_x=1, grid_y=1, neurons_per_column=100,
                   synapses_per_neuron=40, seed=3), eng)
    eplan, _ = EV.build_event_plan(spec)
    state = EV.init_event_state(spec, base, cap_ev=8)   # absurdly small
    state2, raster, _ = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, 80))(state)
    _finite_and_counted(state2, raster)


def test_post_compaction_cap_saturates_not_corrupts():
    """Tiny c_post: the LTP spike-compaction overflows; spikes beyond the
    cap lose their LTP update but the raster itself must stay exact."""
    eng = EngineConfig(n_shards=1, delivery="event")
    cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=100,
                     synapses_per_neuron=40, seed=3)
    spec, plan, base = E.build(cfg, eng)
    eplan, cap_ev = EV.build_event_plan(spec)
    state = EV.init_event_state(spec, base, cap_ev)
    state2, raster, _ = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, 80, c_post=2))(state)
    _finite_and_counted(state2, raster)
    # rasters are computed BEFORE the LTP compaction touches them: the
    # spike trains must equal the uncapped run's
    stateu, rasteru, _ = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, 80))(
            EV.init_event_state(spec, base, cap_ev))
    assert int(np.asarray(stateu.sat).sum()) == 0
    # same until weights drift enough to change spiking; the first steps
    # must match exactly (weight perturbation needs arrivals to land)
    assert np.array_equal(np.asarray(raster)[:5], np.asarray(rasteru)[:5])


def test_src_compaction_cap_saturates_not_corrupts():
    """Tiny c_src: emission drops whole sources, counted in sat."""
    eng = EngineConfig(n_shards=1, delivery="event")
    cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=100,
                     synapses_per_neuron=40, seed=3)
    spec, plan, base = E.build(cfg, eng)
    eplan, cap_ev = EV.build_event_plan(spec)
    state = EV.init_event_state(spec, base, cap_ev)
    state2, raster, _ = jax.jit(
        lambda s: EV.run(spec, plan, eplan, s, 0, 80, c_src=2))(state)
    _finite_and_counted(state2, raster)


def test_dropped_events_only_ever_reduce_arrivals():
    """Capped run delivers a subset of the uncapped run's events: total
    arrivals under tiny caps must be <= the uncapped total (drops are
    drops — never duplicated or misrouted into extra arrivals)."""
    eng = EngineConfig(n_shards=1, delivery="event")
    cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=100,
                     synapses_per_neuron=40, seed=3)
    spec, plan, base = E.build(cfg, eng)
    eplan, cap_ev = EV.build_event_plan(spec)
    run = lambda s, **kw: jax.jit(
        lambda st: EV.run(spec, plan, eplan, st, 0, 60, **kw))(s)
    _, _, tm_uncapped = run(EV.init_event_state(spec, base, cap_ev))
    st_c, _, tm_capped = run(EV.init_event_state(spec, base, 16))
    assert int(np.asarray(st_c.sat).sum()) > 0
    assert int(np.asarray(tm_capped.arrivals).sum()) \
        <= int(np.asarray(tm_uncapped.arrivals).sum())
