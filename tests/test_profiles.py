"""Connectivity-profile subsystem: spec parsing, bit-identity of the
default with the paper kernel, reach-derived halo sufficiency, and
backend-dispatch (use_pallas fallback) raster identity at every profile.
"""
import numpy as np
import pytest

from repro.core import (EngineConfig, GridConfig, build, checkpoint,
                        connectivity, engine, observables, profiles,
                        topology)

PROFILE_SPECS = ("ring3", "ring1", "gaussian:sigma=1.0",
                 "exponential:lambda=0.7")


class TestParsing:
    def test_default_is_paper_kernel(self):
        p = profiles.parse("ring3")
        assert isinstance(p, profiles.RingProfile)
        assert p.fractions == profiles.PAPER_RING_FRACTIONS
        assert p.reach() == 3

    @pytest.mark.parametrize("alias", ["paper", "default", "RING3"])
    def test_aliases(self, alias):
        assert profiles.parse(alias) == profiles.parse("ring3")

    def test_explicit_ring3_is_bit_identical_to_default(self):
        assert profiles.parse("ring:max_ring=3") == profiles.parse("ring3")

    @pytest.mark.parametrize("spec,reach", [
        ("ring1", 1), ("ring2", 2), ("ring5", 5), ("ring:max_ring=4", 4),
        ("gaussian:sigma=1.0", 3), ("gaussian:sigma=1.5", 5),
        ("gaussian:sigma=1.5,cutoff=2", 3),
        ("exponential:lambda=1.0", 5), ("exp:lambda=0.5,cutoff=4", 2),
    ])
    def test_reach(self, spec, reach):
        assert profiles.parse(spec).reach() == reach

    @pytest.mark.parametrize("spec", PROFILE_SPECS + (
        "ring:max_ring=5", "gaussian:sigma=2,cutoff=2"))
    def test_spec_round_trips(self, spec):
        p = profiles.parse(spec)
        assert profiles.parse(p.spec()) == p

    @pytest.mark.parametrize("bad", [
        "nope", "gaussian:sigma=0", "gaussian:sigma=1,zap=2",
        "ring:max_ring=-1", "ring3:sigma=1", "exponential:lambda=-2",
        "gaussian:sigma", "ring:"])
    def test_rejects_malformed(self, bad):
        with pytest.raises(ValueError):
            profiles.parse(bad)

    def test_ring_masses_decay(self):
        for spec in ("gaussian:sigma=1.0", "exponential:lambda=0.7"):
            m = np.asarray(profiles.parse(spec).ring_masses())
            per_col = m / np.array([profiles.ring_size(r)
                                    for r in range(m.shape[0])])
            assert (np.diff(per_col) < 0).all(), spec

    def test_custom_ring_fractions_flow_through(self):
        cfg = GridConfig(ring_fractions=(0.5, 0.3, 0.15, 0.05))
        assert profiles.from_config(cfg).ring_masses() == \
            (0.5, 0.3, 0.15, 0.05)


class TestKernelGeneration:
    def test_offset_tables_match_legacy(self):
        off, start = profiles.offset_tables(3)
        assert start.tolist() == [0, 1, 9, 25, 49]
        legacy = np.concatenate(
            [np.asarray(topology.ring_offsets(r), dtype=np.int64)
             for r in range(4)])
        assert np.array_equal(off, legacy)

    def test_explicit_ring3_config_generates_identical_synapses(self):
        a = GridConfig(grid_x=2, grid_y=2, neurons_per_column=30,
                       synapses_per_neuron=10, seed=5)
        b = GridConfig(grid_x=2, grid_y=2, neurons_per_column=30,
                       synapses_per_neuron=10, seed=5,
                       connectivity="ring:max_ring=3")
        g = np.arange(a.n_neurons)
        fa, fb = (connectivity.forward_synapses(c, g) for c in (a, b))
        for name in ("tgt_gid", "delay", "weight", "plastic"):
            assert np.array_equal(getattr(fa, name), getattr(fb, name)), name

    @pytest.mark.parametrize("spec", PROFILE_SPECS)
    def test_targets_within_reach(self, spec):
        """Every excitatory target column is within `reach` Chebyshev rings
        of the source (on a grid wide enough not to wrap-alias)."""
        p = profiles.parse(spec)
        side = 2 * p.reach() + 3
        cfg = GridConfig(grid_x=side, grid_y=side, neurons_per_column=10,
                         synapses_per_neuron=8, seed=9, connectivity=spec)
        g = np.arange(cfg.n_neurons)
        fwd = connectivity.forward_synapses(cfg, g)
        exc = topology.is_excitatory(cfg, g)
        scol = topology.gid_column(cfg, g)[:, None]
        tcol = topology.gid_column(cfg, fwd.tgt_gid)
        sx, sy = topology.column_coords(cfg, scol)
        tx, ty = topology.column_coords(cfg, tcol)
        # periodic Chebyshev distance
        dx = np.minimum(np.abs(sx - tx), side - np.abs(sx - tx))
        dy = np.minimum(np.abs(sy - ty), side - np.abs(sy - ty))
        dist = np.maximum(dx, dy)[exc]
        assert dist.max() <= p.reach(), spec
        if p.reach() > 1:
            assert dist.max() > 1, f"{spec}: kernel never left ring 1?"

    @pytest.mark.parametrize("spec", PROFILE_SPECS)
    @pytest.mark.parametrize("placement", ["block", "scatter"])
    def test_halo_superset_of_actual_sources(self, spec, placement):
        """reach()-derived halo columns must cover every actual presynaptic
        source, and build_shard must capture exactly the incoming synapses
        a brute-force scan over ALL neurons finds (a truncated halo would
        silently drop synapses)."""
        cfg = GridConfig(grid_x=5, grid_y=4, neurons_per_column=10,
                         synapses_per_neuron=6, seed=3, connectivity=spec)
        eng = EngineConfig(n_shards=3, placement=placement)
        fwd = connectivity.forward_synapses(cfg, np.arange(cfg.n_neurons))
        src_all = np.repeat(np.arange(cfg.n_neurons),
                            cfg.synapses_per_neuron)
        tgt_all = fwd.tgt_gid.ravel()
        owner = topology.owner_of(cfg, tgt_all, eng.n_shards, eng.placement)
        for h in range(eng.n_shards):
            halo = topology.shard_halo_columns(cfg, h, eng.n_shards,
                                               eng.placement)
            incoming_src_cols = np.unique(topology.gid_column(
                cfg, src_all[owner == h]))
            assert np.isin(incoming_src_cols, halo).all(), (spec, h)
            t = connectivity.build_shard(cfg, eng, h)
            assert t.n_valid == int((owner == h).sum()), (spec, h)


class TestEngineAcrossProfiles:
    @pytest.mark.parametrize("spec", ["ring1", "gaussian:sigma=1.0"])
    def test_vmap_shards_invariant(self, spec):
        """H=1 vs H=2 logical shards spike identically for non-default
        profiles (single-device vmap path; the shard_map/cluster paths are
        covered by test_determinism_scaling/test_cluster_smoke)."""
        cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=40,
                         synapses_per_neuron=12, seed=21, connectivity=spec)
        sigs = set()
        for H in (1, 2):
            spec_, plan, state = build(cfg, EngineConfig(n_shards=H))
            _, raster, _ = engine.run(spec_, plan, state, 0, 40)
            sigs.add(observables.raster_signature(np.asarray(raster),
                                                  np.asarray(plan.gid)))
        assert len(sigs) == 1

    def test_profiles_change_the_physics(self):
        """Different kernels must produce different rasters — otherwise the
        profile knob is not actually wired into the build."""
        sigs = {}
        for spec in PROFILE_SPECS:
            cfg = GridConfig(grid_x=3, grid_y=3, neurons_per_column=30,
                             synapses_per_neuron=10, seed=21,
                             connectivity=spec)
            s, plan, state = build(cfg, EngineConfig())
            _, raster, _ = engine.run(s, plan, state, 0, 30)
            sigs[spec] = observables.raster_signature(
                np.asarray(raster), np.asarray(plan.gid))
        assert len(set(sigs.values())) == len(sigs), sigs

    @pytest.mark.parametrize("spec", PROFILE_SPECS)
    def test_use_pallas_fallback_bit_identical(self, spec):
        """EngineConfig(use_pallas=True) on CPU must fall back to the
        reference kernels (kernels.ops._resolve) and leave the raster
        bit-identical — at every profile, not just ring3."""
        cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=30,
                         synapses_per_neuron=10, seed=13, connectivity=spec)
        rasters = []
        for up in (False, True):
            s, plan, state = build(cfg, EngineConfig(use_pallas=up))
            _, raster, _ = engine.run(s, plan, state, 0, 30)
            rasters.append(np.asarray(raster))
        assert np.array_equal(*rasters), spec


class TestCheckpointProfileGuard:
    @staticmethod
    def _save(tmp_path, **cfg_kw):
        cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=30,
                         synapses_per_neuron=8, seed=2, **cfg_kw)
        s, plan, state = build(cfg, EngineConfig())
        path = str(tmp_path / "ckpt_1.npz")
        checkpoint.save(path, s, plan,
                        __import__("jax").tree.map(np.asarray, state), 1)
        return path

    @staticmethod
    def _load(path, **cfg_kw):
        cfg = GridConfig(grid_x=1, grid_y=1, neurons_per_column=30,
                         synapses_per_neuron=8, seed=2, **cfg_kw)
        s, plan, _ = build(cfg, EngineConfig())
        return checkpoint.load(path, s, plan)

    def test_profile_mismatch_rejected(self, tmp_path):
        path = self._save(tmp_path, connectivity="gaussian:sigma=1.0")
        with pytest.raises(AssertionError, match="connectivity"):
            self._load(path)

    def test_equivalent_spec_strings_load(self, tmp_path):
        """The guard gates the resolved kernel, not the spec string:
        ring:max_ring=3 IS ring3."""
        path = self._save(tmp_path, connectivity="ring:max_ring=3")
        _, t = self._load(path, connectivity="ring3")
        assert t == 1

    def test_same_spec_different_fractions_rejected(self, tmp_path):
        """...and conversely, the same 'ring3' string over different
        ring_fractions is a different kernel and must not load."""
        path = self._save(tmp_path,
                          ring_fractions=(0.5, 0.3, 0.15, 0.05))
        with pytest.raises(AssertionError, match="connectivity"):
            self._load(path)
