"""Streamed on-the-fly connectivity (core.stream_engine) — the bit-identity
test wall.

The streamed mode's whole contract is "same bits, O(chunk) table bytes":
per-chunk tables regenerated inside the jitted step from the same
counter-based splitmix64 draw lanes must concatenate to exactly the
materialized tables, and full runs must reproduce materialized rasters AND
weights bit-for-bit across every layout knob.  Anything weaker silently
forks the paper's Table 1 invariant, so everything here asserts exact
equality, never closeness.  (The randomized-geometry form of the key
equality lives with the other hypothesis tests in test_properties.py.)
"""
import numpy as np
import pytest

import jax

from _mp_helpers import run_with_devices
from repro.core import checkpoint, connectivity, engine, observables, topology
from repro.core import stream_engine as SE
from repro.core.params import EngineConfig, GridConfig
from repro.core.step_program import StepProgram

PROFILES = ("ring3", "ring:max_ring=1", "gaussian:sigma=1.5")


def _cfg(gx=2, gy=3, npc=10, M=8, profile="ring3", seed=7):
    return GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc,
                      synapses_per_neuron=M, seed=seed,
                      connectivity=profile)


def _materialized_keys(cfg, eng, shard):
    """Canonical (tgt_gid, src_gid, j) from the materialized builder —
    build_shard already emits shard-local canonical order."""
    t = connectivity.build_shard(cfg, eng, shard)
    v = t.valid
    gids = topology.owned_gids(cfg, shard, eng.n_shards, eng.placement)
    return (gids[t.tgt_local[v]].astype(np.int64),
            t.src_gid[t.src_idx[v]].astype(np.int64),
            t.j[v].astype(np.int64))


def _assert_keys_equal(cfg, eng, shard, chunk):
    mt, ms, mj = _materialized_keys(cfg, eng, shard)
    st, ss, sj = connectivity.streamed_shard_keys(cfg, eng, shard, chunk)
    np.testing.assert_array_equal(st, mt)
    np.testing.assert_array_equal(ss, ms)
    np.testing.assert_array_equal(sj, mj)


class TestParseMode:
    def test_materialized(self):
        assert connectivity.parse_mode("materialized") == \
            ("materialized", None)

    @pytest.mark.parametrize("spec,chunk", [
        ("streamed", 1), ("streamed:chunk=1", 1), ("streamed:chunk=4", 4)])
    def test_streamed(self, spec, chunk):
        assert connectivity.parse_mode(spec) == ("streamed", chunk)

    @pytest.mark.parametrize("spec", [
        "paged", "streamed:chunk=0", "streamed:chunk=-2",
        "streamed:rows=3"])
    def test_rejects(self, spec):
        with pytest.raises(ValueError):
            connectivity.parse_mode(spec)


class TestChunkKeyEquality:
    """Regenerated chunk tables concatenate bit-equal to the materialized
    builder — every profile x shard layout x chunk size, including a K
    that does not divide the per-shard column count."""

    @pytest.mark.parametrize("profile", PROFILES)
    @pytest.mark.parametrize("placement", ["block", "scatter"])
    @pytest.mark.parametrize("chunk", [1, 2, 3])
    def test_streamed_keys_match_materialized(self, profile, placement,
                                              chunk):
        cfg = _cfg(profile=profile)
        eng = EngineConfig(n_shards=2, placement=placement)
        for shard in range(eng.n_shards):
            _assert_keys_equal(cfg, eng, shard, chunk)

    @pytest.mark.parametrize("placement", ["block", "scatter"])
    def test_in_jit_tables_match_host_reference(self, placement):
        """The jitted generator (uint32-limb splitmix64, integer ring
        select, stable argsort) reproduces the host chunk reference
        entry-by-entry: src gid, target, delay, plastic flag AND forward
        slot j, with the valid entries exactly the leading e_start run."""
        cfg = _cfg(profile="ring:max_ring=1")
        eng = EngineConfig(n_shards=2, placement=placement,
                           connectivity="streamed:chunk=2")
        spec, plan, splan, _ = SE.build(cfg, eng)
        ss = spec.stream
        gen = SE.make_chunk_tables(
            spec, jax.tree_util.tree_map(lambda a: a[0], plan))
        gen_j = jax.jit(gen, static_argnums=2)
        cand_np = np.asarray(splan.cand[0])
        e_start = np.asarray(splan.e_start[0])
        src_table = np.asarray(plan.src_gid[0])
        for c in range(ss.n_chunks):
            tb = gen_j(c, splan.cand[0][c], True)
            lo, hi = c * ss.q, (c + 1) * ss.q
            sidx = cand_np[c]
            ref = connectivity._chunk_synapses(
                cfg, eng, 0, src_table[sidx[sidx >= 0]].astype(np.int64),
                lo, hi)
            e = int(e_start[c + 1] - e_start[c])
            assert e == ref.src_gid.shape[0]
            valid = np.asarray(tb.valid)
            assert valid[:e].all() and not valid[e:].any()
            np.testing.assert_array_equal(
                src_table[np.asarray(tb.src)[:e]], ref.src_gid)
            np.testing.assert_array_equal(
                np.asarray(tb.tgt_rel)[:e] + lo, ref.tgt_local)
            np.testing.assert_array_equal(np.asarray(tb.delay)[:e],
                                          ref.delay)
            np.testing.assert_array_equal(np.asarray(tb.plastic)[:e],
                                          ref.plastic)
            np.testing.assert_array_equal(np.asarray(tb.j)[:e], ref.j)


def _final_weights(sp, state):
    """Valid synapse weights in canonical per-shard order, concatenated —
    directly comparable between the two residency modes."""
    w = np.asarray(state.w)
    outs = []
    if sp.splan is not None:
        e_start = np.asarray(sp.splan.e_start)
        for h in range(w.shape[0]):
            outs.append(w[h, :int(e_start[h, -1])])
    else:
        valid = np.asarray(sp.plan.syn_valid)
        for h in range(w.shape[0]):
            outs.append(w[h][valid[h]])
    return np.concatenate(outs)


class TestRunBitIdentity:
    """Full streamed StepProgram runs equal materialized: raster
    signature AND final weights, across exchange wires and schedules."""

    STEPS = 15

    def _pair(self, exchange, schedule, chunk=2):
        cfg = _cfg(gx=2, gy=2, npc=16, M=10)
        base = dict(n_shards=2, exchange=exchange,
                    exchange_schedule=schedule)
        return (StepProgram(cfg, EngineConfig(**base)),
                StepProgram(cfg, EngineConfig(
                    **base, connectivity=f"streamed:chunk={chunk}")))

    def _assert_identical_run(self, spm, sps):
        sm, rm, _ = spm.run(spm.init_state(), 0, self.STEPS)
        ssf, rs, _ = sps.run(sps.init_state(), 0, self.STEPS)
        gid = np.asarray(spm.plan.gid)
        assert observables.raster_signature(np.asarray(rm), gid) == \
            observables.raster_signature(np.asarray(rs), gid)
        np.testing.assert_array_equal(_final_weights(spm, sm),
                                      _final_weights(sps, ssf))

    @pytest.mark.parametrize("exchange", ["halo", "allgather"])
    def test_fused_run(self, exchange):
        spm, sps = self._pair(exchange, "sync")
        self._assert_identical_run(spm, sps)

    @pytest.mark.parametrize("exchange", ["halo", "allgather"])
    @pytest.mark.parametrize("schedule", ["sync", "pipelined"])
    def test_phase_split(self, exchange, schedule):
        """The vmap phase programs (the profiler path) under both
        schedules: streamed rasters and weights equal materialized."""
        spm, sps = self._pair(exchange, schedule)
        sm, _, rm, _ = spm.time_phases(spm.init_state(), 0, self.STEPS,
                                       collect_rasters=True)
        ssf, _, rs, _ = sps.time_phases(sps.init_state(), 0, self.STEPS,
                                        collect_rasters=True)
        assert np.array_equal(np.stack(rm), np.stack(rs))
        np.testing.assert_array_equal(_final_weights(spm, sm),
                                      _final_weights(sps, ssf))

    def test_nondividing_chunk(self):
        """chunk=2 over 3 owned columns per shard: the ragged last chunk
        must not change a single bit."""
        cfg = _cfg(gx=2, gy=3, npc=12, M=8)
        spm = StepProgram(cfg, EngineConfig(n_shards=2))
        sps = StepProgram(cfg, EngineConfig(
            n_shards=2, connectivity="streamed:chunk=2"))
        self._assert_identical_run(spm, sps)


_STREAM_DIST_CODE = """
import numpy as np
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D

cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=40,
                 synapses_per_neuron=16, seed=7)

# reference: single-process MATERIALIZED vmap driver (cross-mode identity
# and cross-process identity gated in one comparison)
ref = StepProgram(cfg, EngineConfig(n_shards=4))
_, raster_ref, _ = ref.run(ref.init_state(), 0, 60)
sig_ref = observables.raster_signature(np.asarray(raster_ref),
                                       np.asarray(ref.plan.gid))

eng = EngineConfig(n_shards=4, exchange={exchange!r},
                   exchange_schedule={schedule!r},
                   connectivity='streamed:chunk=1')
sp = StepProgram(cfg, eng, mesh=D.make_mesh(4))
state_d = sp.place(sp.init_state())
state_d, raster_d, tm = sp.run(state_d, 0, 60)
sig_d = observables.raster_signature(np.asarray(raster_d),
                                     np.asarray(sp.plan.gid))
assert sig_d == sig_ref, 'streamed shard_map raster forked'
print('OK', int(np.asarray(raster_d).sum()))
"""


@pytest.mark.slow
@pytest.mark.parametrize("exchange,schedule", [
    ("halo", "sync"), ("halo", "pipelined"), ("allgather", "sync")])
def test_streamed_shard_map_matches_materialized(exchange, schedule):
    """Streamed under REAL collectives (shard_map, 4 devices) against the
    materialized single-device reference — Table 1 across both the
    process axis and the residency-mode axis at once."""
    out = run_with_devices(
        _STREAM_DIST_CODE.format(exchange=exchange, schedule=schedule), 4)
    assert "OK" in out


class TestMemoryBound:
    """Streamed live synapse-table bytes are O(chunk), not O(E)."""

    def _specs(self, gx, gy, chunk=1, npc=20, M=60):
        cfg = _cfg(gx=gx, gy=gy, npc=npc, M=M, profile="ring:max_ring=1")
        spec_s = SE.build(cfg, EngineConfig(
            n_shards=1, connectivity=f"streamed:chunk={chunk}"))[0]
        spec_m = engine.build(cfg, EngineConfig(n_shards=1))[0]
        return spec_s, spec_m

    def test_chunk_table_bytes_invariant_under_grid_doubling(self):
        """Double the grid at fixed chunk: the regenerated-table buffer
        (k_cap slots) must not grow — only the O(n_chunks) metadata may.
        The materialized tables, by contrast, double with the grid."""
        s1, m1 = self._specs(4, 4)
        s2, m2 = self._specs(8, 4)
        s4, m4 = self._specs(8, 8)
        assert s1.stream.k_cap == s2.stream.k_cap == s4.stream.k_cap
        assert SE.chunk_table_bytes(s1) == SE.chunk_table_bytes(s2) == \
            SE.chunk_table_bytes(s4)
        assert m2.e_cap >= 2 * m1.e_cap - 16
        assert m4.e_cap >= 2 * m2.e_cap - 16

    def test_ratio_floor_on_residency_grid(self):
        """The weak_scaling residency claim re-derived from the actual
        built specs: materialized tables >= 8x streamed live bytes on
        the suite's quick grid."""
        spec_s, spec_m = self._specs(10, 10, npc=30, M=100)
        ratio = SE.materialized_table_bytes(spec_m.e_cap) / \
            SE.streamed_table_bytes(spec_s)
        assert ratio >= 8.0, f"residency ratio {ratio:.1f}x < 8x"

    def test_jitted_step_inputs_are_chunk_sized(self):
        """Program-level check: lower the streamed fused step and walk
        its plan-tree inputs — no table/metadata leaf may reach synapse-
        table scale.  Only the synapse STATE (weights, arrivals:
        checkpointable physics, O(E) in either mode) is allowed to be
        big; the regenerated tables live only inside the scan body."""
        cfg = _cfg(gx=8, gy=8, npc=20, M=60, profile="ring:max_ring=1")
        sps = StepProgram(cfg, EngineConfig(
            n_shards=1, connectivity="streamed:chunk=1"))
        spec_m = engine.build(cfg, EngineConfig(n_shards=1))[0]
        assert sps.fused.lower(sps.planT, sps.init_state(), 0) is not None
        budget = SE.materialized_table_bytes(spec_m.e_cap) / 8
        for leaf in jax.tree_util.tree_leaves(sps.planT):
            nbytes = leaf.size * leaf.dtype.itemsize
            assert nbytes < budget, \
                f"streamed plan leaf {leaf.shape} {leaf.dtype} is " \
                f"{nbytes} B >= 1/8 of materialized tables ({budget} B)"


class TestStreamedCheckpoint:
    STEPS = 10

    def _program(self, eng):
        return StepProgram(_cfg(gx=2, gy=2, npc=16, M=10), eng)

    def test_elastic_restore_other_shards_and_chunk(self, tmp_path):
        """streamed save -> restore into a different shard count AND
        placement AND chunk size -> continuation is bit-exact (raster
        signature and every saved weight)."""
        sp1 = self._program(EngineConfig(
            n_shards=2, connectivity="streamed:chunk=1"))
        s1, _, _ = sp1.run(sp1.init_state(), 0, self.STEPS)
        p = checkpoint.save(str(tmp_path / "ckpt.npz"), sp1.spec,
                            sp1.plan, s1, self.STEPS)
        sref, rref, _ = sp1.run(s1, self.STEPS, self.STEPS)
        sig_ref = observables.raster_signature(
            np.asarray(rref), np.asarray(sp1.plan.gid))

        sp2 = self._program(EngineConfig(
            n_shards=3, placement="scatter",
            connectivity="streamed:chunk=2"))
        s2, t0 = checkpoint.load(p, sp2.spec, sp2.plan)
        assert t0 == self.STEPS
        s2f, r2, _ = sp2.run(s2, t0, self.STEPS)
        assert observables.raster_signature(
            np.asarray(r2), np.asarray(sp2.plan.gid)) == sig_ref
        # weights re-saved from both layouts land in the same global
        # canonical order and must match bit-for-bit
        pa = checkpoint.save(str(tmp_path / "a.npz"), sp1.spec, sp1.plan,
                             sref, 2 * self.STEPS)
        pb = checkpoint.save(str(tmp_path / "b.npz"), sp2.spec, sp2.plan,
                             s2f, 2 * self.STEPS)
        za, zb = np.load(pa), np.load(pb)
        np.testing.assert_array_equal(za["tgt"], zb["tgt"])
        np.testing.assert_array_equal(za["src"], zb["src"])
        np.testing.assert_array_equal(za["w"], zb["w"])

    def test_cross_mode_load_refused_both_ways(self, tmp_path):
        sps = self._program(EngineConfig(
            n_shards=2, connectivity="streamed:chunk=1"))
        spm = self._program(EngineConfig(n_shards=2))
        ss, _, _ = sps.run(sps.init_state(), 0, self.STEPS)
        sm, _, _ = spm.run(spm.init_state(), 0, self.STEPS)
        ps = checkpoint.save(str(tmp_path / "s.npz"), sps.spec, sps.plan,
                             ss, self.STEPS)
        pm = checkpoint.save(str(tmp_path / "m.npz"), spm.spec, spm.plan,
                             sm, self.STEPS)
        with pytest.raises(AssertionError, match="connectivity mode"):
            checkpoint.load(ps, spm.spec, spm.plan)
        with pytest.raises(AssertionError, match="connectivity mode"):
            checkpoint.load(pm, sps.spec, sps.plan)


class TestEventExclusion:
    def test_step_program_refuses_event_streamed(self):
        with pytest.raises(ValueError, match="dense"):
            StepProgram(_cfg(), EngineConfig(
                delivery="event", connectivity="streamed:chunk=1"))

    def test_event_build_refuses_streamed(self):
        from repro.core import event_engine
        with pytest.raises(ValueError, match="materialized"):
            event_engine.build(_cfg(), EngineConfig(
                delivery="event", connectivity="streamed:chunk=1"))


class TestPallasFallbackWarning:
    """`use_pallas=True` off-TPU falls back to the jnp oracle — loudly,
    once, with unchanged numbers."""

    def _args(self):
        import jax.numpy as jnp
        return [jnp.zeros((4,), jnp.float32)] * 7

    def test_explicit_true_off_tpu_warns_once(self, monkeypatch):
        from repro.kernels import ops
        if jax.default_backend() == "tpu":
            pytest.skip("fallback warning only fires off-TPU")
        monkeypatch.setattr(ops, "_warned_fallback", False)
        args = self._args()
        with pytest.warns(UserWarning, match="use_pallas=True"):
            out_pallas = ops.izhikevich_update(*args, v_peak=30.0,
                                               use_pallas=True)
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ops.izhikevich_update(*args, v_peak=30.0, use_pallas=True)
        out_ref = ops.izhikevich_update(*args, v_peak=30.0,
                                        use_pallas=False)
        for a, b in zip(out_pallas, out_ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_auto_never_warns(self):
        from repro.kernels import ops
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ops.izhikevich_update(*self._args(), v_peak=30.0)
