"""Unit tests for the trip-count-aware HLO cost analyzer, validated against
programs whose true costs are known analytically."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestHloCost:
    def test_single_matmul_flops_exact(self):
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
        res = hlo_cost.analyze(_hlo(lambda x, y: x @ y, a, b))
        assert res["flops"] == pytest.approx(2 * 128 * 256 * 512, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def f(x):
            def body(c, _):
                return c @ c, None
            y, _ = jax.lax.scan(body, x, None, length=17)
            return y

        res = hlo_cost.analyze(_hlo(f, a))
        want = 17 * 2 * 64 * 64 * 64
        assert res["flops"] == pytest.approx(want, rel=0.05)

    def test_nested_scan(self):
        a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

        def f(x):
            def outer(c, _):
                def inner(c2, _):
                    return c2 @ c2, None
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y

        res = hlo_cost.analyze(_hlo(f, a))
        want = 5 * 3 * 2 * 32 ** 3
        assert res["flops"] == pytest.approx(want, rel=0.05)

    def test_batched_dot(self):
        a = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
        res = hlo_cost.analyze(_hlo(
            lambda x, y: jnp.einsum("bik,bkj->bij", x, y), a, b))
        assert res["flops"] == pytest.approx(2 * 4 * 16 * 32 * 8, rel=0.01)

    def test_bytes_nonzero_and_bounded(self):
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        res = hlo_cost.analyze(_hlo(lambda x: x @ x + 1.0, a))
        size = 256 * 256 * 4
        assert res["bytes"] >= 2 * size       # at least read + write
        assert res["bytes"] <= 40 * size      # sane upper bound

    def test_no_collectives_single_device(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        res = hlo_cost.analyze(_hlo(lambda x: x @ x, a))
        assert res["collectives"]["total"] == 0
