"""Experiment-plan schema + expansion (repro.bench.plans).

A plan file is reviewed config: validation must be strict (typos fail
loudly, every problem reported at once), expansion must never silently
shrink (every dropped cell carries a reason), and the resume fingerprint
must move exactly when the physics or the code-relevant environment
moves.
"""
import json
import os

import pytest

from repro.bench import plans
from repro.bench.plans import schema as S

ENV = {"jax": "0.4.37", "backend": "cpu"}
PLANS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                         "plans")


def _doc(**over):
    doc = dict(name="unit",
               workload=dict(neurons_per_column=30, synapses_per_neuron=12,
                             steps=20, seed=7),
               axes=dict(delivery=["dense", "event"], shards=[2]))
    doc.update(over)
    return doc


class TestValidate:
    def test_defaults_fill_unset_knobs(self):
        p = plans.validate(_doc())
        assert p.axes["placement"] == ["block"]
        assert p.axes["stim"] == ["default"]
        assert p.workload["phase_steps"] == 0
        assert p.budgets == dict(S.BUDGET_DEFAULTS)

    def test_all_errors_reported_at_once(self):
        doc = _doc(bogus=1, axes=dict(delivery=["dens"], warp=[1]),
                   workload=dict(steps=-1, nope=2))
        with pytest.raises(plans.PlanError) as ei:
            plans.validate(doc)
        text = str(ei.value)
        for frag in ("bogus", "dens", "warp", "steps", "nope"):
            assert frag in text, f"{frag!r} missing from: {text}"

    @pytest.mark.parametrize("axes", [
        dict(delivery=["dense", "sparse"]),
        dict(exchange=["ring"]),
        dict(exchange_schedule=["eager"]),
        dict(stim=["loud"]),
        dict(grid=["2x"]),
        dict(grid=["0x2"]),
        dict(shards=[0]),
        dict(shards=[True]),
        dict(nprocs=["2"]),
        dict(profile=["definitely-not-a-profile"]),
        dict(connectivity=["paged"]),
        dict(connectivity=["streamed:chunk=0"]),
    ])
    def test_out_of_domain_axis_value_rejected(self, axes):
        with pytest.raises(plans.PlanError):
            plans.validate(_doc(axes=axes))

    def test_duplicate_axis_value_rejected(self):
        with pytest.raises(plans.PlanError) as ei:
            plans.validate(_doc(axes=dict(delivery=["dense", "dense"])))
        assert "duplicate" in str(ei.value)

    def test_bad_name_rejected(self):
        with pytest.raises(plans.PlanError):
            plans.validate(_doc(name="no spaces allowed"))

    def test_exclude_unknown_axis_rejected(self):
        with pytest.raises(plans.PlanError):
            plans.validate(_doc(exclude=[{"warp": 2}]))

    def test_exclude_bad_value_rejected(self):
        with pytest.raises(plans.PlanError):
            plans.validate(_doc(exclude=[{"delivery": "sparse"}]))

    @pytest.mark.parametrize("budgets", [
        dict(reps=0), dict(timeout_s=-5), dict(gpu_hours=1)])
    def test_bad_budgets_rejected(self, budgets):
        with pytest.raises(plans.PlanError):
            plans.validate(_doc(budgets=budgets))


class TestExpand:
    def test_cells_carry_key_hash_and_group(self):
        cells, excluded = plans.expand(plans.validate(_doc()), env=ENV)
        assert len(cells) == 2 and not excluded
        for c in cells:
            assert c["key"] and len(c["hash"]) == 16
            assert c["physics_group"] == cells[0]["physics_group"]

    def test_structural_shards_divisibility(self):
        p = plans.validate(_doc(axes=dict(shards=[2], nprocs=[1, 3])))
        cells, excluded = plans.expand(p, env=ENV)
        assert [c["nprocs"] for c in cells] == [1]
        assert len(excluded) == 1
        assert "divisible" in excluded[0]["reason"]

    def test_structural_hier_needs_processes(self):
        p = plans.validate(_doc(axes=dict(exchange=["halo", "hier"],
                                          shards=[2], nprocs=[1, 2])))
        cells, excluded = plans.expand(p, env=ENV)
        hier = [c for c in cells if c["exchange"] == "hier"]
        assert hier and all(c["nprocs"] >= 2 for c in hier)
        assert any("hier" in e["reason"] for e in excluded)

    def test_structural_event_refuses_streamed(self):
        p = plans.validate(_doc(axes=dict(
            delivery=["dense", "event"], shards=[2],
            connectivity=["materialized", "streamed:chunk=2"])))
        cells, excluded = plans.expand(p, env=ENV)
        assert len(cells) == 3          # event x streamed dropped
        assert not [c for c in cells if c["delivery"] == "event"
                    and c["connectivity"] != "materialized"]
        assert any("materialized" in e["reason"] for e in excluded)

    def test_connectivity_is_layout_not_physics(self):
        p = plans.validate(_doc(axes=dict(
            delivery=["dense"], shards=[2],
            connectivity=["materialized", "streamed:chunk=1"])))
        cells, _ = plans.expand(p, env=ENV)
        assert len(cells) == 2
        assert len({c["physics_group"] for c in cells}) == 1
        assert len({c["hash"] for c in cells}) == 2
        assert len({c["key"] for c in cells}) == 2

    def test_user_exclude_drops_with_reason(self):
        p = plans.validate(_doc(exclude=[{"delivery": "event"}]))
        cells, excluded = plans.expand(p, env=ENV)
        assert [c["delivery"] for c in cells] == ["dense"]
        assert "excluded by" in excluded[0]["reason"]

    def test_everything_excluded_is_an_error(self):
        p = plans.validate(_doc(exclude=[{"delivery": ["dense", "event"]}]))
        with pytest.raises(plans.PlanError) as ei:
            plans.expand(p, env=ENV)
        assert "zero cells" in str(ei.value)

    def test_duplicate_cells_are_an_error(self):
        # bypass validate (which already catches duplicate axis values) to
        # prove expansion itself refuses colliding keys/hashes
        p = plans.validate(_doc())
        axes = {a: list(v) for a, v in p.axes.items()}
        axes["delivery"] = ["dense", "dense"]
        dup = S.Plan(name=p.name, workload=p.workload, axes=axes,
                     exclude=(), budgets=p.budgets)
        with pytest.raises(plans.PlanError) as ei:
            plans.expand(dup, env=ENV)
        assert "duplicate" in str(ei.value)


class TestFingerprint:
    def _cell(self, **over):
        doc = _doc(axes=dict(delivery=["dense"], shards=[2]))
        doc.update(over)
        cells, _ = plans.expand(plans.validate(doc), env=ENV)
        return cells[0]

    def test_env_change_moves_hash(self):
        c = self._cell()
        assert plans.cell_hash(c, ENV) != plans.cell_hash(
            c, {"jax": "9.9.9", "backend": "cpu"})

    def test_physics_change_moves_hash_and_group(self):
        a = self._cell()
        b = self._cell(workload=dict(neurons_per_column=30,
                                     synapses_per_neuron=12, steps=20,
                                     seed=8))
        assert a["hash"] != b["hash"]
        assert a["physics_group"] != b["physics_group"]

    def test_budget_timeout_does_not_move_hash(self):
        a = self._cell()
        b = self._cell(budgets=dict(timeout_s=123))
        assert a["hash"] == b["hash"]

    def test_layout_shares_physics_group(self):
        doc = _doc(axes=dict(delivery=["dense", "event"],
                             exchange=["halo", "allgather"], shards=[1, 2]))
        cells, _ = plans.expand(plans.validate(doc), env=ENV)
        assert len({c["physics_group"] for c in cells}) == 1
        assert len({c["hash"] for c in cells}) == len(cells)


class TestLoad:
    def test_json_plan_loads(self, tmp_path):
        path = tmp_path / "p.json"
        path.write_text(json.dumps(_doc()))
        assert plans.load(str(path)).name == "unit"

    def test_yaml_plan_loads_with_filename_hint(self, tmp_path):
        pytest.importorskip("yaml")
        path = tmp_path / "hinted.yaml"
        path.write_text("workload: {steps: 10}\n"
                        "axes: {delivery: [dense]}\n")
        p = plans.load(str(path))
        assert p.name == "hinted" and p.workload["steps"] == 10

    def test_missing_file_is_a_plan_error(self):
        with pytest.raises(plans.PlanError):
            plans.load("/nonexistent/plan.yaml")

    @pytest.mark.parametrize("fname,n_cells", [
        ("quick.yaml", 15), ("paper_scaling.yaml", 36)])
    def test_committed_plans_load_and_expand(self, fname, n_cells):
        pytest.importorskip("yaml")
        p = plans.load(os.path.join(PLANS_DIR, fname))
        cells, excluded = plans.expand(p, env=ENV)
        assert len(cells) == n_cells
        assert all(e["reason"] for e in excluded)

    def test_committed_quick_is_one_physics_group(self):
        pytest.importorskip("yaml")
        p = plans.load(os.path.join(PLANS_DIR, "quick.yaml"))
        cells, _ = plans.expand(p, env=ENV)
        assert len({c["physics_group"] for c in cells}) == 1
