"""shard_map runtime: real collectives must reproduce the vmap reference
exactly, for both exchange modes, and the halo schedule must be sparse."""
import pytest

from repro.core import EngineConfig, GridConfig, build
from repro.core import distributed as D

from _mp_helpers import run_with_devices

SMALL = GridConfig(grid_x=2, grid_y=2, neurons_per_column=100,
                   synapses_per_neuron=40, seed=7)


def test_halo_offsets_sparse_on_large_grid():
    """With 1 column/shard on a 12x12 grid, the halo is the 7x7 column
    neighbourhood; periodic wrap aliases offsets into the adjacent shard-id
    band, giving at most 9x7=63 distinct offsets — far below the 144-shard
    all-to-all the paper's first construction step avoids."""
    cfg = GridConfig(grid_x=12, grid_y=12, neurons_per_column=20,
                     synapses_per_neuron=10)
    eng = EngineConfig(n_shards=144, exchange="halo")
    spec, plan, _ = build(cfg, eng)
    offs = D.halo_offsets(spec, plan)
    assert len(offs) <= 63 < 144
    assert 0 in offs  # every shard listens to itself


def test_halo_offsets_cover_connectivity():
    spec, plan, _ = build(SMALL, EngineConfig(n_shards=4, exchange="halo"))
    offs = D.halo_offsets(spec, plan)
    assert len(offs) >= 1


_DIST_CODE = """
import numpy as np
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D

cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=100,
                 synapses_per_neuron=40, seed=7)
eng = EngineConfig(n_shards=4, exchange={exchange!r}, placement={placement!r})

# reference: single-process vmap driver (StepProgram without a mesh)
sp_ref = StepProgram(cfg, eng)
_, raster_ref, _ = sp_ref.run(sp_ref.init_state(), 0, 120)
sig_ref = observables.raster_signature(np.asarray(raster_ref),
                                       np.asarray(sp_ref.plan.gid))

# distributed: one shard per device (StepProgram places the plan)
sp = StepProgram(cfg, eng, mesh=D.make_mesh(4))
state_d = sp.place(sp.init_state())
state_d, raster_d, tm = sp.run(state_d, 0, 120)
sig_d = observables.raster_signature(np.asarray(raster_d),
                                     np.asarray(sp.plan.gid))
assert sig_d == sig_ref, 'distributed raster differs from reference'
print('OK', int(np.asarray(raster_d).sum()))
"""


@pytest.mark.slow
@pytest.mark.parametrize("exchange", ["allgather", "halo"])
def test_shard_map_matches_reference(exchange):
    out = run_with_devices(
        _DIST_CODE.format(exchange=exchange, placement="block"), 4)
    assert "OK" in out


@pytest.mark.slow
def test_shard_map_scatter_placement():
    out = run_with_devices(
        _DIST_CODE.format(exchange="allgather", placement="scatter"), 4)
    assert "OK" in out


_EVENT_DIST_CODE = """
import jax
import numpy as np
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D
from repro.core import event_engine as EV

cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=100,
                 synapses_per_neuron=40, seed=7)
eng = EngineConfig(n_shards=4, exchange={exchange!r}, delivery='event')

# reference: single-device vmap event driver
spec, plan, eplan, state = EV.build(cfg, eng)
st_ref, raster_ref, _ = jax.jit(
    lambda s: EV.run(spec, plan, eplan, s, 0, 120))(state)
sig_ref = observables.raster_signature(np.asarray(raster_ref),
                                       np.asarray(plan.gid))

# distributed: one shard per device, event plan threaded as a jit arg
sp = StepProgram.from_parts(spec, plan, eplan, mesh=D.make_mesh(4))
state_d = sp.place(state)
state_d, raster_d, tm = sp.run(state_d, 0, 120)
sig_d = observables.raster_signature(np.asarray(raster_d),
                                     np.asarray(plan.gid))
assert sig_d == sig_ref, 'event shard_map raster differs from reference'
# same per-shard fp ops + boolean exchange: weights must be BIT-identical
assert np.array_equal(np.asarray(st_ref.base.w), np.asarray(state_d.base.w))
assert int(np.asarray(state_d.sat).sum()) == 0
print('OK', int(np.asarray(raster_d).sum()))
"""


@pytest.mark.slow
@pytest.mark.parametrize("exchange", ["allgather", "halo"])
def test_event_shard_map_matches_vmap_event(exchange):
    """The event backend under real collectives: rasters AND weights must
    bit-match the single-device event driver for both exchange wires."""
    out = run_with_devices(_EVENT_DIST_CODE.format(exchange=exchange), 4)
    assert "OK" in out
