"""Static dashboard generation (repro.bench.plans.dashboard).

The dashboard must render from `file://` anywhere — inline SVG, no
scripts, no network fetches — and chart the committed BENCH_*.json
history (one figure per suite) next to the plan's own sections.
"""
import os

from repro.bench import plans
from repro.bench import report as bench_report
from repro.bench.plans import dashboard as dash

ENV = {"jax": "0.4.37", "backend": "cpu"}
BASELINES = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                         "baselines")


def _records(sig="ab" * 32, sig_for=None):
    doc = dict(name="unit",
               workload=dict(neurons_per_column=30, synapses_per_neuron=12,
                             steps=20, phase_steps=5, seed=7),
               axes=dict(delivery=["dense"], exchange=["halo"],
                         exchange_schedule=["sync", "pipelined"],
                         shards=[2], nprocs=[1, 2]))
    plan = plans.validate(doc)
    cells, _ = plans.expand(plan, env=ENV)
    recs = []
    for c in cells:
        s = (sig_for or {}).get(c["key"], sig)
        exch = 0.1 if c["exchange_schedule"] == "sync" else 0.04
        recs.append(dict(
            key=c["key"], hash=c["hash"], cell=c, elapsed_s=1.0,
            result=dict(wall_s=0.5 * c["nprocs"], spikes=10, rate_hz=1.0,
                        raster_sig=s, phase_a_s=0.2, exchange_s=exch,
                        phase_b_s=0.2, phase_steps=5,
                        time_per_syn_event_s=4.2e-3)))
    return plan.to_config(), recs


class TestRender:
    def test_self_contained_html(self):
        cfg, recs = _records()
        html = dash.render(cfg, recs)
        assert html.startswith("<!DOCTYPE html>")
        assert "<svg" in html and "<script" not in html
        assert "http://" not in html and "https://" not in html

    def test_plan_sections_present(self):
        cfg, recs = _records()
        html = dash.render(cfg, recs)
        assert "Scaling over nprocs" in html
        assert "Per-phase split" in html
        assert "Hidden exchange fraction" in html
        assert "Time per synaptic event" in html
        assert "Table 1 invariant" in html and "identical" in html

    def test_divergent_group_marked(self):
        cfg, recs = _records()
        bad = {recs[0]["key"]: "ff" * 32}
        cfg, recs = _records(sig_for=bad)
        html = dash.render(cfg, recs)
        assert "DIVERGED" in html

    def test_phase_colors_use_fixed_slots(self):
        cfg, recs = _records()
        html = dash.render(cfg, recs)
        # phase A / exchange / B always wear categorical slots 1/2/3
        for slot in ("--s1", "--s2", "--s3"):
            assert f"var({slot})" in html
        assert "prefers-color-scheme" in html

    def test_summary_line_rendered(self):
        cfg, recs = _records()
        html = dash.render(cfg, recs,
                           summary=dict(executed=4, skipped=0, failed=0))
        assert "4 executed" in html

    def test_write_creates_file(self, tmp_path):
        cfg, recs = _records()
        path = dash.write(str(tmp_path / "dashboard.html"), cfg, recs)
        assert os.path.getsize(path) > 1000


class TestHistory:
    def test_one_chart_per_committed_suite(self):
        history = bench_report.load_dir(BASELINES)
        assert history, "committed benchmarks/baselines disappeared?"
        cfg, recs = _records()
        html = dash.render(cfg, recs, history=history)
        assert html.count("<figcaption><strong>BENCH ") == len(history)
        for name in history:
            assert f"BENCH {name}" in html
        assert "http://" not in html and "https://" not in html

    def test_wall_metric_overflow_is_declared(self):
        wall = {f"m{i:02d}_wall_s": 0.1 + i / 100 for i in range(30)}
        rep = bench_report.make_report("wide", dict(quick=True),
                                      dict(sig="ab"), wall)
        html = dash.history_section({"wide": rep})
        assert "first 24 of 30 wall metrics shown" in html
        assert html.count("<rect") == 24


class TestPlanHistory:
    def _prior(self, cfg, recs, label):
        wall = {f"{r['key']}_wall_s": r["result"]["wall_s"] + 0.1
                for r in recs}
        rep = bench_report.make_report(f"plan_{cfg['name']}",
                                       dict(quick=True), dict(), wall)
        return (label, rep)

    def test_plan_over_plan_section_rendered(self):
        cfg, recs = _records()
        prior = [self._prior(cfg, recs, "plan_unit_0601"),
                 self._prior(cfg, recs, "plan_unit_0701")]
        html = dash.render(cfg, recs, prior_reports=prior)
        assert "Wall across plan runs" in html
        # run-index key maps every prior label plus the live store
        assert "0=plan_unit_0601" in html and "2=current" in html
        assert "<script" not in html

    def test_no_section_without_prior_runs(self):
        cfg, recs = _records()
        html = dash.render(cfg, recs, prior_reports=[])
        assert "Wall across plan runs" not in html

    def test_load_plan_history_filters_by_name(self, tmp_path):
        cfg, recs = _records()
        _, rep = self._prior(cfg, recs, "x")
        bench_report.save(rep, str(tmp_path))
        other = bench_report.make_report("table1", dict(quick=True),
                                         dict(), dict(a_wall_s=1.0))
        bench_report.save(other, str(tmp_path))
        got = plans.load_plan_history(str(tmp_path), cfg["name"])
        assert len(got) == 1
        assert got[0][1]["name"] == f"plan_{cfg['name']}"
        assert plans.load_plan_history(str(tmp_path), "nope") == []
        assert plans.load_plan_history("", "unit") == []
