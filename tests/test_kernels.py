"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(installed via the [test] extra in CI)")
from hypothesis import given, settings, strategies as st

from repro.core.engine import NEG_TIME
from repro.kernels import ops, ref

STDP_KW = dict(a_minus=0.12, tau_minus=20.0, w_min=0.0, w_max=10.0,
               neg_time=float(NEG_TIME))
LTP_KW = dict(a_plus=0.1, tau_plus=20.0, w_min=0.0, w_max=10.0,
              neg_time=float(NEG_TIME))


def _neuron_inputs(n, seed=0):
    k = jax.random.split(jax.random.key(seed), 3)
    v = jax.random.uniform(k[0], (n,), jnp.float32, -80.0, 29.0)
    u = jax.random.uniform(k[1], (n,), jnp.float32, -20.0, 10.0)
    cur = jax.random.uniform(k[2], (n,), jnp.float32, -10.0, 25.0)
    exc = jnp.arange(n) % 5 != 4
    a = jnp.where(exc, 0.02, 0.1).astype(jnp.float32)
    b = jnp.full((n,), 0.2, jnp.float32)
    c = jnp.full((n,), -65.0, jnp.float32)
    d = jnp.where(exc, 8.0, 2.0).astype(jnp.float32)
    return v, u, cur, a, b, c, d


class TestIzhikevichKernel:
    @pytest.mark.parametrize("n", [7, 128, 1000, 4096, 5003])
    def test_matches_oracle(self, n):
        # fp32 op-ordering in interpret mode differs by a few ulp; the v^2
        # term amplifies that to ~1e-4 relative near threshold.
        args = _neuron_inputs(n, seed=n)
        v1, u1, s1 = ops.izhikevich_update(*args, v_peak=30.0,
                                           interpret=True)
        v2, u2, s2 = ref.izhikevich_update(*args, v_peak=30.0)
        np.testing.assert_allclose(v1, v2, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(u1, u2, rtol=1e-4, atol=1e-3)
        # spike flags must agree except within an ulp-band of threshold
        v, u, cur, a, b, _, _ = args
        vpre = v
        for _ in range(2):
            vpre = vpre + 0.5 * (0.04 * vpre * vpre + 5.0 * vpre + 140.0
                                 - u + cur)
        disagree = np.asarray(s1 != s2)
        borderline = np.abs(np.asarray(vpre) - 30.0) < 1e-2
        assert not (disagree & ~borderline).any()

    def test_some_spikes_occur(self):
        args = _neuron_inputs(512, seed=3)
        args = (jnp.full((512,), 29.9, jnp.float32),) + args[1:]
        _, _, s = ops.izhikevich_update(*args, v_peak=30.0, interpret=True)
        assert int(s.sum()) > 0


def _stdp_inputs(e, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    arr = jax.random.bernoulli(ks[0], 0.2, (e,))
    w = jax.random.uniform(ks[1], (e,), jnp.float32, 0.0, 10.0)
    lp = jnp.where(jax.random.bernoulli(ks[2], 0.7, (e,)),
                   jax.random.uniform(ks[3], (e,), jnp.float32, 0.0, 90.0),
                   NEG_TIME)
    la = jnp.where(jax.random.bernoulli(ks[4], 0.7, (e,)),
                   jax.random.uniform(ks[3], (e,), jnp.float32, 0.0, 99.0),
                   NEG_TIME)
    plastic = jax.random.bernoulli(ks[2], 0.8, (e,))
    return arr, w, lp, la, plastic


class TestStdpKernels:
    @pytest.mark.parametrize("e", [16, 1024, 4096, 9999])
    def test_arrival_matches_oracle(self, e):
        arr, w, lp, la, plastic = _stdp_inputs(e, seed=e)
        t = jnp.float32(100.0)
        out1 = ops.stdp_arrival(arr, w, lp, la, plastic, t, interpret=True,
                                **STDP_KW)
        out2 = ref.stdp_arrival(arr, w, lp, la, plastic, t, **STDP_KW)
        for a, b in zip(out1, out2):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("e", [16, 1024, 9999])
    def test_ltp_matches_oracle(self, e):
        arr, w, lp, la, plastic = _stdp_inputs(e, seed=e + 1)
        valid = jnp.ones((e,), bool)
        t = jnp.float32(100.0)
        w1 = ops.stdp_ltp(arr, w, la, plastic, valid, t, interpret=True,
                          **LTP_KW)
        w2 = ref.stdp_ltp(arr, w, la, plastic, valid, t, **LTP_KW)
        np.testing.assert_allclose(w1, w2, rtol=1e-6, atol=1e-6)

    def test_ltd_depresses_ltp_potentiates(self):
        e = 256
        arr = jnp.ones((e,), bool)
        w = jnp.full((e,), 5.0, jnp.float32)
        lp = jnp.full((e,), 99.0, jnp.float32)   # recent post spike
        la = jnp.full((e,), 99.0, jnp.float32)
        plastic = jnp.ones((e,), bool)
        t = jnp.float32(100.0)
        w_ltd, _, _ = ops.stdp_arrival(arr, w, lp, la, plastic, t,
                                       interpret=True, **STDP_KW)
        assert float(w_ltd.max()) < 5.0          # depression
        w_ltp = ops.stdp_ltp(arr, w, la, plastic, jnp.ones((e,), bool), t,
                             interpret=True, **LTP_KW)
        assert float(w_ltp.min()) > 5.0          # potentiation

    def test_stdp_window_shape(self):
        """LTP magnitude decays with dt; at dt=0 it is exactly a_plus."""
        w = jnp.full((4,), 5.0, jnp.float32)
        la = jnp.array([100.0, 80.0, 60.0, 40.0], jnp.float32)
        post = jnp.ones((4,), bool)
        out = ref.stdp_ltp(post, w, la, post, post, jnp.float32(100.0),
                           **LTP_KW)
        dw = np.asarray(out) - 5.0
        assert dw[0] == pytest.approx(0.1, rel=1e-5)
        assert np.all(np.diff(dw) < 0)           # monotone decay


class TestFlashAttention:
    @pytest.mark.parametrize("t,s,d", [(128, 128, 64), (256, 256, 64),
                                       (128, 384, 128), (256, 256, 80)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_oracle(self, t, s, d, dtype):
        ks = jax.random.split(jax.random.key(t + s + d), 3)
        q = jax.random.normal(ks[0], (2, t, d), dtype)
        k = jax.random.normal(ks[1], (2, s, d), dtype)
        v = jax.random.normal(ks[2], (2, s, d), dtype)
        o1 = ops.attention(q, k, v, causal=True, interpret=True)
        o2 = ref.attention(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(o1, np.float32),
                                   np.asarray(o2, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("window", [64, 128, 1024])
    def test_window_matches_oracle(self, window):
        ks = jax.random.split(jax.random.key(window), 3)
        q = jax.random.normal(ks[0], (2, 256, 64), jnp.float32)
        k = jax.random.normal(ks[1], (2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (2, 256, 64), jnp.float32)
        o1 = ops.attention(q, k, v, causal=True, window=window,
                           interpret=True)
        o2 = ref.attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        ks = jax.random.split(jax.random.key(9), 3)
        q = jax.random.normal(ks[0], (1, 128, 64), jnp.float32) * 4
        k = jax.random.normal(ks[1], (1, 128, 64), jnp.float32) * 4
        v = jax.random.normal(ks[2], (1, 128, 64), jnp.float32)
        o1 = ops.attention(q, k, v, causal=True, softcap=50.0,
                           interpret=True)
        o2 = ref.attention(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)

    def test_decode_offset_alignment(self):
        """T < S: queries are the LAST T positions (KV-cache decode)."""
        ks = jax.random.split(jax.random.key(4), 3)
        q = jax.random.normal(ks[0], (1, 128, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 512, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 512, 64), jnp.float32)
        o1 = ops.attention(q, k, v, causal=True, interpret=True)
        o2 = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)
        # the last query row attends to everything: equals full softmax row
        full = ref.attention(q[:, -1:], k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o2)[:, -1:], full, rtol=2e-5,
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# hypothesis property tests on the kernels' invariants
# ---------------------------------------------------------------------------

class TestProperties:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 600), seed=st.integers(0, 2 ** 16))
    def test_izh_kernel_equals_oracle_any_shape(self, n, seed):
        args = _neuron_inputs(n, seed=seed)
        v1, u1, s1 = ops.izhikevich_update(*args, v_peak=30.0,
                                           interpret=True)
        v2, u2, s2 = ref.izhikevich_update(*args, v_peak=30.0)
        np.testing.assert_allclose(v1, v2, rtol=1e-4, atol=1e-3)
        v, u, cur = args[0], args[1], args[2]
        vpre = v
        for _ in range(2):
            vpre = vpre + 0.5 * (0.04 * vpre * vpre + 5.0 * vpre + 140.0
                                 - u + cur)
        disagree = np.asarray(s1 != s2)
        borderline = np.abs(np.asarray(vpre) - 30.0) < 1e-2
        assert not (disagree & ~borderline).any()

    @settings(max_examples=20, deadline=None)
    @given(e=st.integers(1, 3000), seed=st.integers(0, 2 ** 16),
           t=st.floats(1.0, 1e5))
    def test_stdp_weights_always_bounded(self, e, seed, t):
        arr, w, lp, la, plastic = _stdp_inputs(e, seed=seed)
        wt = jnp.float32(t)
        w1, la1, _ = ops.stdp_arrival(arr, w, lp, la, plastic, wt,
                                      interpret=True, **STDP_KW)
        w2 = ops.stdp_ltp(arr, w1, la1, plastic, jnp.ones((e,), bool), wt,
                          interpret=True, **LTP_KW)
        pl_ = np.asarray(plastic)
        if pl_.any():
            assert np.asarray(w2)[pl_].min() >= 0.0 - 1e-6
            assert np.asarray(w2)[pl_].max() <= 10.0 + 1e-6
        # non-plastic weights untouched by both passes
        np.testing.assert_array_equal(np.asarray(w2)[~pl_],
                                      np.asarray(w)[~pl_])

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_attention_rows_are_convex_combinations(self, seed):
        """Each output row lies in the convex hull of v rows => bounded by
        per-coordinate min/max of v (prefix for causal)."""
        ks = jax.random.split(jax.random.key(seed), 3)
        q = jax.random.normal(ks[0], (1, 128, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 128, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 128, 64), jnp.float32)
        o = np.asarray(ops.attention(q, k, v, causal=True, interpret=True))
        vv = np.asarray(v)
        run_max = np.maximum.accumulate(vv[0], axis=0)
        run_min = np.minimum.accumulate(vv[0], axis=0)
        assert (o[0] <= run_max + 1e-4).all()
        assert (o[0] >= run_min - 1e-4).all()


class TestRgLruKernel:
    @pytest.mark.parametrize("shape", [(2, 64, 128), (3, 100, 96),
                                       (8, 256, 256), (1, 17, 130)])
    def test_matches_oracle(self, shape):
        B, T, D = shape
        ks = jax.random.split(jax.random.key(sum(shape)), 3)
        a = jax.random.uniform(ks[0], shape, jnp.float32, 0.8, 0.999)
        b = jax.random.normal(ks[1], shape, jnp.float32) * 0.1
        h0 = jax.random.normal(ks[2], (B, D), jnp.float32)
        out = ops.rg_lru_scan(a, b, h0, interpret=True)
        want = ref.rg_lru_scan(a, b, h0)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_decay_contracts_state(self):
        """With b=0 and |a|<1 the state decays monotonically."""
        B, T, D = 2, 64, 128
        a = jnp.full((B, T, D), 0.9, jnp.float32)
        b = jnp.zeros((B, T, D), jnp.float32)
        h0 = jnp.ones((B, D), jnp.float32)
        out = np.asarray(ops.rg_lru_scan(a, b, h0, interpret=True))
        norms = np.abs(out).max(axis=(0, 2))
        assert (np.diff(norms) < 0).all()

    @settings(max_examples=10, deadline=None)
    @given(t=st.integers(2, 80), seed=st.integers(0, 2 ** 16))
    def test_property_any_length(self, t, seed):
        ks = jax.random.split(jax.random.key(seed), 3)
        a = jax.random.uniform(ks[0], (2, t, 128), jnp.float32, 0.5, 1.0)
        b = jax.random.normal(ks[1], (2, t, 128), jnp.float32)
        h0 = jax.random.normal(ks[2], (2, 128), jnp.float32)
        out = ops.rg_lru_scan(a, b, h0, interpret=True)
        want = ref.rg_lru_scan(a, b, h0)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)
