"""Unit coverage for the fault-tolerance layer — everything that does
not need a live 2-process jax job (that part lives in
tests/test_cluster_faults.py, probe-gated).

Covered here, jax-free and fast:
  * `cluster.faults` — injection grammar, injector gating (the
    irreversible actions are routed through interceptable module
    globals), progress beacons;
  * `core.integrity` — digest round-trip, truncation/bit-flip detection,
    corrupt-tolerant newest-valid discovery;
  * `cluster.local` — bounded `_reap` escalation, `LaunchError` partial
    CLUSTER_RESULT payloads, the free_port TOCTOU bind retry, and
    `supervised_launch`'s restart budget / lost-result detection with
    trivially failing subprocess commands.
"""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from _mp_helpers import SRC  # noqa: F401  (sys.path bootstrap)

from repro.cluster import faults, local
from repro.cluster.worker import RESULT_PREFIX, _chunk_spans
from repro.core import integrity


class TestFaultGrammar:
    def test_parse_and_roundtrip(self):
        s = faults.FaultSpec.parse("crash@step=30:rank=1")
        assert (s.kind, s.step, s.rank, s.ms) == ("crash", 30, 1, 0)
        assert faults.FaultSpec.parse(s.spec()) == s
        assert faults.FaultSpec.parse("slow@step=10:ms=500").ms == 500
        assert faults.FaultSpec.parse("drop_result").spec() == "drop_result"
        assert faults.FaultSpec.parse("corrupt_ckpt@step=20").step == 20

    @pytest.mark.parametrize("bad", [
        "explode@step=1",        # unknown kind
        "slow@step=1",           # slow without ms
        "crash@step=x",          # non-integer value
        "crash@foo=1",           # unknown key
        "crash@step",            # missing '='
    ])
    def test_bad_specs_name_the_grammar(self, bad):
        with pytest.raises(ValueError, match="grammar|integer"):
            faults.FaultSpec.parse(bad)

    def test_from_env(self, monkeypatch):
        monkeypatch.delenv(faults.ENV_FAULT, raising=False)
        assert faults.FaultInjector.from_env(0).spec is None
        monkeypatch.setenv(faults.ENV_FAULT, "crash@step=5")
        assert faults.FaultInjector.from_env(0).spec.step == 5
        monkeypatch.setenv(faults.ENV_FAULT, "")   # supervisor's disarm
        assert faults.FaultInjector.from_env(0).spec is None


class TestFaultInjector:
    @pytest.fixture
    def exits(self, monkeypatch):
        fired = []
        monkeypatch.setattr(faults, "_hard_exit", fired.append)
        return fired

    def test_crash_fires_on_covering_chunk_and_matching_rank(self, exits):
        spec = faults.FaultSpec.parse("crash@step=30:rank=1")
        inj = faults.FaultInjector(spec, rank=1)
        inj.on_chunk(0, 30)                    # 30 not in [0, 30)
        assert exits == []
        inj.on_chunk(30, 40)
        assert exits == [faults.EXIT_CRASH]
        other = faults.FaultInjector(spec, rank=0)
        other.on_chunk(30, 40)                 # wrong rank: no-op
        assert exits == [faults.EXIT_CRASH]

    def test_disarmed_and_fired_are_noops(self, exits):
        inj = faults.FaultInjector(None, 0)
        inj.on_chunk(0, 100)
        inj.on_checkpoint_written("/nope", 50)
        assert inj.emit_result() is True and exits == []

    def test_slow_sleeps_once(self, monkeypatch, exits):
        slept = []
        monkeypatch.setattr(faults, "_sleep", slept.append)
        inj = faults.FaultInjector(
            faults.FaultSpec.parse("slow@step=10:ms=250"), 0)
        inj.on_chunk(10, 20)
        inj.on_chunk(20, 30)
        assert slept == [0.25] and exits == []

    def test_corrupt_ckpt_truncates_then_exits(self, tmp_path, exits):
        path = str(tmp_path / "ckpt_20.npz")
        integrity.write_verified(path, {"a": np.arange(4000)})
        inj = faults.FaultInjector(
            faults.FaultSpec.parse("corrupt_ckpt@step=20"), 0)
        inj.on_checkpoint_written(path, 10)    # before step: no-op
        assert exits == [] and integrity.verify(path)
        inj.on_checkpoint_written(path, 20)
        assert exits == [faults.EXIT_CORRUPT]
        assert not integrity.verify(path)      # the digest catches it

    def test_drop_result_swallows_exactly_once(self, exits):
        inj = faults.FaultInjector(faults.FaultSpec.parse("drop_result"), 0)
        assert inj.emit_result() is False
        assert inj.emit_result() is True       # fired latch


class TestBeacons:
    def test_roundtrip_and_tolerance(self, tmp_path):
        d = str(tmp_path / "beacons")
        faults.BeaconWriter(d, 1).write(30, "chunk", attempt=2)
        faults.BeaconWriter(d, 0).write(40, "report")
        got = faults.read_beacons(d)
        assert got[1]["step"] == 30 and got[1]["phase"] == "chunk"
        assert got[1]["attempt"] == 2 and got[0]["phase"] == "report"
        # torn/garbage files are skipped, not fatal
        with open(os.path.join(d, "beacon_9.json"), "w") as f:
            f.write("{not json")
        assert 9 not in faults.read_beacons(d)
        assert faults.read_beacons(None) == {}
        assert faults.read_beacons(str(tmp_path / "missing")) == {}

    def test_disabled_writer_is_noop(self, tmp_path):
        faults.BeaconWriter(None, 0).write(1, "x")   # must not raise


class TestIntegrity:
    def test_write_read_roundtrip(self, tmp_path):
        path = str(tmp_path / "ok.npz")
        arrays = {"w": np.linspace(0, 1, 100).reshape(10, 10),
                  "t": np.int64(7)}
        integrity.write_verified(path, arrays)
        back = integrity.read_verified(path)
        assert np.array_equal(back["w"], arrays["w"])
        assert int(back["t"]) == 7
        assert integrity.verify(path)
        assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))

    def test_truncation_raises_checkpoint_corrupt(self, tmp_path):
        path = str(tmp_path / "trunc.npz")
        integrity.write_verified(path, {"a": np.arange(5000)})
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size * 2 // 3)
        with pytest.raises(integrity.CheckpointCorrupt) as ei:
            integrity.read_verified(path)
        assert ei.value.path == path

    def test_bitflip_raises_checkpoint_corrupt(self, tmp_path):
        path = str(tmp_path / "flip.npz")
        integrity.write_verified(path, {"a": np.arange(5000)})
        blob = bytearray(open(path, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        with open(path, "wb") as f:
            f.write(bytes(blob))
        with pytest.raises(integrity.CheckpointCorrupt):
            integrity.read_verified(path)

    def test_latest_valid_falls_back_past_corruption(self, tmp_path):
        d = str(tmp_path)
        for t in (10, 20, 30):
            integrity.write_verified(os.path.join(d, f"ckpt_{t}.npz"),
                                     {"t": np.int64(t)})
        newest = os.path.join(d, "ckpt_30.npz")
        assert integrity.latest_valid(d) == newest
        with open(newest, "r+b") as f:            # corrupt the newest epoch
            f.truncate(os.path.getsize(newest) // 2)
        assert integrity.latest_valid(d) == os.path.join(d, "ckpt_20.npz")
        assert integrity.checkpoint_steps(d) == [
            (10, os.path.join(d, "ckpt_10.npz")),
            (20, os.path.join(d, "ckpt_20.npz")),
            (30, newest)]
        assert integrity.latest_valid(str(tmp_path / "none")) is None


class TestChunkSpans:
    def test_alignment_is_base_relative(self):
        assert _chunk_spans(0, 40, 10, 0) == [(0, 10), (10, 20), (20, 30),
                                              (30, 40)]
        # a resume at an epoch re-enters the same boundary sequence
        assert _chunk_spans(20, 40, 10, 0) == [(20, 30), (30, 40)]
        # nonzero base (explicit --ckpt continuation)
        assert _chunk_spans(15, 35, 10, 15) == [(15, 25), (25, 35)]
        # ragged tail + k=0 single chunk + empty window
        assert _chunk_spans(0, 25, 10, 0) == [(0, 10), (10, 20), (20, 25)]
        assert _chunk_spans(5, 40, 0, 0) == [(5, 40)]
        assert _chunk_spans(40, 40, 10, 0) == []


@pytest.mark.skipif(not local.spawn_supported(),
                    reason="cannot spawn subprocesses here")
class TestSupervisorUnits:
    def test_reap_bounds_total_time_and_logs_sigkill(self):
        stubborn = ("import signal, time;"
                    "signal.signal(signal.SIGTERM, signal.SIG_IGN);"
                    "time.sleep(60)")
        procs = [subprocess.Popen([sys.executable, "-c", stubborn])
                 for _ in range(3)]
        time.sleep(1.0)                       # let the handlers install
        t0 = time.monotonic()
        info = local._reap(procs, total_timeout=1.5)
        elapsed = time.monotonic() - t0
        assert info["terminated"] == [0, 1, 2]
        assert info["killed"] == [0, 1, 2]    # SIGTERM ignored everywhere
        assert elapsed < 10.0                 # one shared grace, not 3x
        assert all(p.poll() is not None for p in procs)

    def test_reap_gentle_exit_needs_no_sigkill(self):
        procs = [subprocess.Popen([sys.executable, "-c",
                                   "import time; time.sleep(60)"])]
        time.sleep(0.3)
        info = local._reap(procs, total_timeout=5.0)
        assert info["terminated"] == [0] and info["killed"] == []

    def test_launch_error_carries_partial_results(self):
        payload = {"proc": 1, "spikes": 3}
        outs = [f"noise\n{RESULT_PREFIX}{json.dumps(payload)}\n", "dead"]
        err = local.LaunchError("boom", [0, 41], outs)
        assert err.partial_results == {0: payload}
        assert "partial CLUSTER_RESULT" in str(err)

    def test_bind_failure_retries_once_with_fresh_port(self, monkeypatch):
        calls = []

        def fake_attempt(cmd, nprocs, devices_per_proc, timeout,
                         coordinator, extra_env, tuned_env, **kw):
            calls.append(coordinator)
            if len(calls) == 1:
                raise local.LaunchError(
                    "worker failed", [1],
                    ["F0809 coordinator Address already in use"])
            return ["ok"]

        monkeypatch.setattr(local, "_launch_attempt", fake_attempt)
        monkeypatch.setattr(local.time, "sleep", lambda s: None)
        assert local.launch(["-c", "pass"], nprocs=1) == ["ok"]
        assert len(calls) == 2 and calls[0] != calls[1]

    def test_bind_retry_not_taken_for_pinned_port_or_other_failures(
            self, monkeypatch):
        def fail(*a, **kw):
            raise local.LaunchError("worker failed", [1],
                                    ["Address already in use"])
        monkeypatch.setattr(local, "_launch_attempt", fail)
        with pytest.raises(local.LaunchError):
            local.launch(["-c", "pass"], nprocs=1, port=12345)

        def fail_other(*a, **kw):
            raise local.LaunchError("worker failed", [1], ["segfault"])
        monkeypatch.setattr(local, "_launch_attempt", fail_other)
        with pytest.raises(local.LaunchError):
            local.launch(["-c", "pass"], nprocs=1)

    def test_budget_exhaustion_raises_with_attempt_history(self):
        with pytest.raises(local.LaunchError) as ei:
            local.supervised_launch(["-c", "import sys; sys.exit(3)"],
                                    nprocs=1, max_restarts=2,
                                    backoff_s=0.01, timeout=120)
        err = ei.value
        assert "restart budget exhausted" in str(err)
        assert [a["index"] for a in err.attempts] == [0, 1, 2]
        assert all(a["returncodes"] == [3] for a in err.attempts)
        backoffs = [a["backoff_s"] for a in err.attempts]
        assert backoffs == [0.01, 0.02, 0.04]   # exponential

    def test_lost_result_line_is_a_failure_when_expected(self):
        with pytest.raises(local.LaunchError, match="CLUSTER_RESULT"):
            local.supervised_launch(["-c", "print('fine')"], nprocs=1,
                                    max_restarts=0, backoff_s=0.01,
                                    timeout=120)

    def test_supervised_success_returns_empty_history(self):
        code = (f"print({RESULT_PREFIX!r} + '{{}}')")
        outs, attempts = local.supervised_launch(
            ["-c", code], nprocs=1, max_restarts=1, backoff_s=0.01,
            timeout=120)
        assert attempts == [] and RESULT_PREFIX in outs[0]

    def test_supervised_rejects_bad_fault_grammar_fast(self):
        with pytest.raises(ValueError, match="grammar"):
            local.supervised_launch(["-c", "pass"], nprocs=1,
                                    fault="explode@step=1")

    def test_fault_armed_on_first_attempt_only(self, monkeypatch):
        seen = []

        def fake_launch(cmd, nprocs, devices_per_proc, timeout, port=None,
                        extra_env=None, echo=False, tuned_env=False,
                        stall_timeout=None, beacon_dir=None):
            seen.append(extra_env[faults.ENV_FAULT])
            if len(seen) == 1:
                raise local.LaunchError("worker failed", [41], ["dead"])
            return [RESULT_PREFIX + "{}"]

        monkeypatch.setattr(local, "launch", fake_launch)
        monkeypatch.setattr(local.time, "sleep", lambda s: None)
        outs, attempts = local.supervised_launch(
            ["-c", "x"], nprocs=1, fault="crash@step=5", max_restarts=2)
        assert seen == ["crash@step=5", ""]    # recovery runs clean
        assert len(attempts) == 1 and attempts[0]["index"] == 0
