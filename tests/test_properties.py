"""Hypothesis property tests on system-level invariants.

The paper's central invariant — identical construction and dynamics for
ANY process layout — is checked here over randomly drawn grid shapes,
shard counts, placements and seeds (not just the hand-picked cases in
test_core_engine.py)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(installed via the [test] extra in CI)")
from hypothesis import given, settings, strategies as st

from repro.core import EngineConfig, GridConfig, build, observables, run
from repro.core import connectivity as C
from repro.core import topology as T


@settings(max_examples=10, deadline=None)
@given(gx=st.integers(1, 3), gy=st.integers(1, 3),
       h=st.integers(1, 6), seed=st.integers(0, 2 ** 16),
       placement=st.sampled_from(["block", "scatter"]))
def test_connectivity_layout_invariant(gx, gy, h, seed, placement):
    """The global synapse multiset is identical for every layout."""
    cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=40,
                     synapses_per_neuron=10, seed=seed)
    h = min(h, cfg.n_neurons)

    def global_set(eng):
        out = []
        for sh, t in enumerate(C.build_all_shards(cfg, eng)):
            gids = T.owned_gids(cfg, sh, eng.n_shards, eng.placement)
            m = t.valid
            out += list(zip(t.src_gid[t.src_idx[m]].tolist(),
                            gids[t.tgt_local[m]].tolist(),
                            t.j[m].tolist(), t.delay[m].tolist()))
        return sorted(out)

    ref = global_set(EngineConfig(n_shards=1))
    assert global_set(EngineConfig(n_shards=h, placement=placement)) == ref


@settings(max_examples=6, deadline=None)
@given(h=st.integers(1, 5), seed=st.integers(0, 1000),
       placement=st.sampled_from(["block", "scatter"]))
def test_raster_layout_invariant(h, seed, placement):
    """Short simulations produce identical rasters for any drawn layout."""
    cfg = GridConfig(grid_x=2, grid_y=1, neurons_per_column=50,
                     synapses_per_neuron=16, seed=seed)
    h = min(h, cfg.n_neurons)

    def sig(eng):
        spec, plan, state = build(cfg, eng)
        _, raster, _ = run(spec, plan, state, 0, 60)
        return observables.raster_signature(np.asarray(raster),
                                            np.asarray(plan.gid))

    assert sig(EngineConfig(n_shards=h, placement=placement)) == sig(
        EngineConfig(n_shards=1))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), gx=st.integers(1, 4),
       gy=st.integers(1, 4))
def test_forward_synapse_counts_exact(seed, gx, gy):
    """Every neuron projects exactly M synapses; inhibitory ones stay
    intra-column onto excitatory targets with minimum delay."""
    cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=30,
                     synapses_per_neuron=12, seed=seed)
    gids = np.arange(cfg.n_neurons)
    f = C.forward_synapses(cfg, gids)
    assert f.tgt_gid.shape == (cfg.n_neurons, 12)
    assert (f.tgt_gid >= 0).all() and (f.tgt_gid < cfg.n_neurons).all()
    inh = ~T.is_excitatory(cfg, gids)
    own_col = T.gid_column(cfg, gids)[:, None]
    tcol = T.gid_column(cfg, f.tgt_gid)
    assert (tcol[inh] == np.broadcast_to(own_col, tcol.shape)[inh]).all()
    assert (f.delay[inh] == cfg.delay_min).all()
    assert (~f.plastic[inh]).all()
    n_exc_t = T.gid_local_n(cfg, f.tgt_gid)
    assert (n_exc_t[inh] < cfg.n_exc_per_column).all()


@settings(max_examples=20, deadline=None)
@given(gx=st.integers(1, 3), gy=st.integers(1, 3),
       npc=st.integers(4, 12), M=st.integers(2, 10),
       h=st.integers(1, 3), chunk=st.integers(1, 4),
       placement=st.sampled_from(["block", "scatter"]),
       profile=st.sampled_from(["ring3", "ring:max_ring=1",
                                "gaussian:sigma=1.5"]),
       seed=st.integers(0, 2 ** 31 - 1))
def test_streamed_keys_match_materialized(gx, gy, npc, M, h, chunk,
                                          placement, profile, seed):
    """Chunk-wise regenerated synapse keys concatenate bit-equal to the
    materialized builder for ANY geometry x profile x layout x chunk size
    (the streamed-connectivity contract, randomized form — hand-picked
    cases live in test_stream_connectivity.py)."""
    cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc,
                     synapses_per_neuron=M, seed=seed,
                     connectivity=profile)
    eng = EngineConfig(n_shards=h, placement=placement)
    for shard in range(h):
        t = C.build_shard(cfg, eng, shard)
        v = t.valid
        gids = T.owned_gids(cfg, shard, h, placement)
        st_, ss, sj = C.streamed_shard_keys(cfg, eng, shard, chunk)
        np.testing.assert_array_equal(st_, gids[t.tgt_local[v]])
        np.testing.assert_array_equal(ss, t.src_gid[t.src_idx[v]])
        np.testing.assert_array_equal(sj, t.j[v])
