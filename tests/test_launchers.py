"""CLI launcher smoke tests: the actual entry points users run, exercised
in subprocesses (fresh jax init per scenario)."""
import os

import pytest

from _mp_helpers import SRC, run_with_devices


@pytest.mark.slow
def test_snn_cli_dense_and_event(tmp_path):
    out = run_with_devices(
        "import sys; sys.argv=['snn','--grid','1x1',"
        "'--neurons-per-column','200','--synapses','20','--steps','80'];"
        "from repro.launch.snn import main; main()", 1)
    assert "done at t=80" in out
    out = run_with_devices(
        "import sys; sys.argv=['snn','--grid','1x1',"
        "'--neurons-per-column','200','--synapses','20','--steps','80',"
        "'--delivery','event'];"
        "from repro.launch.snn import main; main()", 1)
    assert "done at t=80" in out and "saturated 0" in out


@pytest.mark.slow
def test_snn_cli_event_distributed_with_checkpoint(tmp_path):
    """--delivery event is a first-class citizen of the sharded launcher:
    shards>1, halo exchange, checkpoint write + resume."""
    code = (
        "import sys; sys.argv=['snn','--grid','2x1',"
        "'--neurons-per-column','100','--synapses','20','--steps','60',"
        "'--shards','2','--exchange','halo','--delivery','event',"
        f"'--ckpt-dir',{str(tmp_path)!r},'--ckpt-every','30'];"
        "from repro.launch.snn import main; main()")
    out = run_with_devices(code, 2)
    assert "done at t=60" in out
    assert os.path.exists(os.path.join(str(tmp_path), "ckpt_60.npz"))
    # resume from the event-mode checkpoint
    code2 = code.replace("'--steps','60'", "'--steps','30'")
    out2 = run_with_devices(code2, 2)
    assert "resumed at t=60" in out2


@pytest.mark.slow
def test_snn_cli_distributed_with_checkpoint(tmp_path):
    code = (
        "import sys; sys.argv=['snn','--grid','2x1',"
        "'--neurons-per-column','100','--synapses','20','--steps','60',"
        "'--shards','2','--exchange','halo',"
        f"'--ckpt-dir',{str(tmp_path)!r},'--ckpt-every','30'];"
        "from repro.launch.snn import main; main()")
    out = run_with_devices(code, 2)
    assert "done at t=60" in out
    assert os.path.exists(os.path.join(str(tmp_path), "ckpt_60.npz"))
    # resume
    code2 = code.replace("'--steps','60'", "'--steps','30'")
    out2 = run_with_devices(code2, 2)
    assert "resumed at t=60" in out2


@pytest.mark.slow
def test_train_cli_smoke():
    out = run_with_devices(
        "import sys; sys.argv=['train','--arch','qwen3-0.6b','--smoke',"
        "'--steps','6','--batch','2','--seq','32'];"
        "from repro.launch.train import main; main()", 1, timeout=900)
    assert "'steps': 6" in out


@pytest.mark.slow
def test_serve_cli_smoke():
    out = run_with_devices(
        "import sys; sys.argv=['serve','--arch','rwkv6-1.6b','--smoke',"
        "'--requests','2','--batch','2','--max-new','4','--s-max','32'];"
        "from repro.launch.serve import main; main()", 1, timeout=900)
    assert "[serve] 2 requests" in out


@pytest.mark.slow
def test_dryrun_cli_one_cell():
    """The real dry-run driver end to end on the cheapest cell (its own
    XLA_FLAGS line forces 512 devices inside the subprocess)."""
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "rwkv6-1.6b", "--shape", "long_500k", "--single-pod-only"],
        capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[dryrun] OK" in out.stdout
