"""Command-line entry for `repro.simserve`.

    python -m repro.simserve demo            # small mixed fleet, verified
    python -m repro.simserve soak --reshard  # overload + forced evict/resume

Both modes submit a fleet of tenants (alternating dense/event delivery —
two shape keys minimum), drive the service to completion, verify EVERY
tenant's streamed raster signature against the same config run solo
through `StepProgram`, and print per-tenant metrics plus the service
snapshot.  Exit status is non-zero on any signature mismatch, so the CI
smoke job can gate on it.

`soak` additionally overloads the slots (queueing + preemption), force-
evicts one running tenant mid-soak and resumes it a round later —
optionally into a doubled shard count (`--reshard`), exercising the
checkpointed elastic-reshard path under load.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List

from ..core.params import EngineConfig, GridConfig
from . import batcher
from .queue import SimService
from .session import DONE, RUNNING, TenantRequest


def _fleet(args) -> List[TenantRequest]:
    cfg0 = GridConfig(grid_x=args.grid_x, grid_y=args.grid_y,
                      neurons_per_column=args.npc,
                      synapses_per_neuron=args.spn)
    reqs = []
    for i in range(args.tenants):
        cfg = dataclasses.replace(cfg0, seed=args.seed0 + 7919 * i)
        eng = EngineConfig(n_shards=args.shards,
                           delivery="event" if i % 2 else "dense")
        reqs.append(TenantRequest(f"t{i:02d}", cfg, eng, args.steps))
    return reqs


def _verify(svc: SimService, reqs: List[TenantRequest]) -> int:
    failures = 0
    for req in reqs:
        sess = svc.sessions[req.name]
        if sess.status != DONE:
            print(f"  FAIL {req.name}: status={sess.status}")
            failures += 1
            continue
        want = batcher.solo_signature(req.cfg, req.eng, req.n_steps,
                                      req.caps, req.cap_ev)
        ok = sess.stream.signature() == want
        failures += 0 if ok else 1
        m = sess.metrics()
        print(f"  {'ok  ' if ok else 'FAIL'} {req.name} "
              f"delivery={m['delivery']} shards={m['shards']} "
              f"events={m['n_events']} chunks={sess.stream.chunks} "
              f"evictions={m['evictions']} resumes={m['resumes']} "
              f"wait={m['queue_wait_rounds']}")
    return failures


def _finish(svc: SimService, reqs: List[TenantRequest],
            snap: dict) -> int:
    print(f"service: rounds={snap['rounds']} "
          f"admissions={snap['admissions']} evictions={snap['evictions']} "
          f"resumes={snap['resumes']} preemptions={snap['preemptions']} "
          f"tenant_steps/s={snap['tenant_steps_per_s']:.0f}")
    print(f"programs: {json.dumps(snap['program_cache'])}")
    print("verifying against solo StepProgram runs...")
    failures = _verify(svc, reqs)
    if failures:
        print(f"{failures} signature mismatch(es)")
        return 1
    print("all tenant signatures bit-identical to solo runs")
    return 0


def cmd_demo(args) -> int:
    reqs = _fleet(args)
    svc = SimService(slots=args.slots, round_steps=args.round_steps,
                     stream_dir=args.stream_dir)
    for r in reqs:
        svc.submit(r)
    snap = svc.run()
    return _finish(svc, reqs, snap)


def cmd_soak(args) -> int:
    reqs = _fleet(args)
    svc = SimService(slots=args.slots, round_steps=args.round_steps,
                     stream_dir=args.stream_dir)
    for r in reqs:
        svc.submit(r)
    # warm-up rounds, then force-evict one running tenant...
    for _ in range(args.evict_round):
        svc.step_round()
    victim = next(s for s in svc.sessions.values()
                  if s.status == RUNNING)
    print(f"soak: evicting {victim.name} at t={victim.t}")
    svc.evict(victim.name)
    svc.step_round()
    # ...and resume it, optionally into a doubled shard count
    eng = None
    if args.reshard:
        eng = dataclasses.replace(victim.eng,
                                  n_shards=victim.eng.n_shards * 2)
        print(f"soak: resuming {victim.name} resharded "
              f"H{victim.eng.n_shards}->H{eng.n_shards}")
    svc.resume(victim.name, eng=eng)
    snap = svc.run()
    return _finish(svc, reqs, snap)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.simserve",
        description="multi-tenant SNN simulation service")
    sub = p.add_subparsers(dest="cmd", required=True)
    for name, fn in (("demo", cmd_demo), ("soak", cmd_soak)):
        sp = sub.add_parser(name)
        sp.set_defaults(fn=fn)
        sp.add_argument("--tenants", type=int,
                        default=4 if name == "demo" else 6)
        sp.add_argument("--steps", type=int, default=60)
        sp.add_argument("--slots", type=int,
                        default=4 if name == "demo" else 2)
        sp.add_argument("--round-steps", type=int, default=15)
        sp.add_argument("--grid-x", type=int, default=2)
        sp.add_argument("--grid-y", type=int, default=2)
        sp.add_argument("--npc", type=int, default=20)
        sp.add_argument("--spn", type=int, default=10)
        sp.add_argument("--shards", type=int, default=2)
        sp.add_argument("--seed0", type=int, default=2013)
        sp.add_argument("--stream-dir", default=None,
                        help="also append per-tenant event CSVs here")
        if name == "soak":
            sp.add_argument("--evict-round", type=int, default=2)
            sp.add_argument("--reshard", action="store_true",
                            help="resume the evicted tenant at 2x shards")
    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
