"""Per-tenant and service-level counters for `repro.simserve`.

Everything the scheduler knows is counted here: admissions, queue wait
(in rounds — the service's unit of time), evictions/resumes/preemptions,
tenant-steps advanced, and the program-cache hit/miss/trace counts that
back the zero-recompile acceptance criterion.  `snapshot()` renders one
JSON-able dict; the CLI prints it and the bench suite lifts aggregate
rates (steps/s, rounds/s) from it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class ServiceMetrics:
    submitted: int = 0
    admissions: int = 0
    completed: int = 0
    evictions: int = 0
    resumes: int = 0
    preemptions: int = 0
    group_failures: int = 0      # group round executions that raised
    failure_evictions: int = 0   # tenants evicted+requeued by a failure
    failed: int = 0              # tenants retired FAILED (cap exceeded)
    rounds: int = 0              # scheduler rounds executed
    group_rounds: int = 0        # round-program launches (one per live group)
    tenant_rounds: int = 0       # tenant-slot rounds advanced
    tenant_steps: int = 0        # tenant simulation steps advanced (truncated)
    queue_wait_rounds: int = 0   # summed over tenants, one per waiting round
    wall_s: float = 0.0

    def snapshot(self, cache: Optional[object] = None) -> dict:
        d = dataclasses.asdict(self)
        wall = max(self.wall_s, 1e-9)
        d["rounds_per_s"] = self.rounds / wall
        d["tenant_steps_per_s"] = self.tenant_steps / wall
        if cache is not None:
            d["program_cache"] = dict(
                hits=cache.hits, misses=cache.misses,
                builds=cache.builds, traces=cache.trace_counts())
        return d
