"""Admission queue + lockstep-round scheduler for `repro.simserve`.

Continuous-batching-lite, mirroring `serve/engine.py`'s static-shape
design: tenants are admitted into fixed-width batch groups (one per
shape key), all live groups advance in lockstep rounds of `round_steps`
simulation steps, and slots refill from the FIFO queue *between* rounds
— so every eviction/checkpoint happens at an exact round boundary and
the restart machinery's bit-identity guarantees carry over unchanged.

Scheduling policy:
  - FIFO admission per shape key; a group is created on first demand.
  - Saturation preemption (optional): when a queued tenant's group is
    full, the occupant with the most completed steps that has been
    resident >= `min_resident_rounds` is evicted to a checkpoint and
    re-queued — round-robin time-sharing that keeps every tenant making
    progress under overload.
  - Explicit `evict(name)` parks an idle tenant on disk (status
    EVICTED) until `resume(name, eng=...)` re-queues it — possibly into
    a different shard layout: the checkpoint is layout-free, so a resume
    is live autoscaling.
  - A tenant whose realized capacities overflow its group's negotiated
    padding triggers a regroup: occupants are checkpointed + re-queued,
    the group re-forms with grown capacities (rare — `negotiate`'s
    headroom absorbs seed-to-seed variation; counted in metrics).
  - Graceful degradation: a group whose round execution raises loses the
    group, not the service — occupants are evicted to their last
    round-boundary state and re-queued (`_fail_group`); a tenant failing
    past `max_tenant_failures` retires FAILED.  Other groups and queued
    tenants are untouched, and survivors' outputs stay bit-identical.

Every tenant's streamed raster signature is bit-identical to the same
config run solo through `StepProgram` regardless of batch companions,
refill order, or evict/resume(/reshard) cycles — the paper's Table 1
invariant applied to multi-tenancy.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from ..core import checkpoint, connectivity, distributed
from ..core.params import EngineConfig
from . import batcher
from .metrics import ServiceMetrics
from .session import (DONE, EVICTED, FAILED, QUEUED, RUNNING,
                      TenantRequest, TenantSession)


class SimService:
    """Multi-tenant simulation service over shape-keyed batch groups."""

    def __init__(self, slots: int = 4, round_steps: int = 20,
                 ckpt_dir: Optional[str] = None,
                 stream_dir: Optional[str] = None,
                 preempt: bool = True, min_resident_rounds: int = 2,
                 max_tenant_failures: int = 2):
        self.slots = int(slots)
        self.round_steps = int(round_steps)
        self.preempt = preempt
        self.min_resident_rounds = int(min_resident_rounds)
        self.max_tenant_failures = int(max_tenant_failures)
        self.cache = batcher.ProgramCache(round_steps)
        self.groups: Dict[batcher.ShapeKey, batcher.BatchGroup] = {}
        self.queue: List[TenantSession] = []
        self.sessions: Dict[str, TenantSession] = {}
        self.metrics = ServiceMetrics()
        self.round_no = 0
        self.regroups = 0
        self._ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="simserve_")
        self._stream_dir = stream_dir

    # -- client surface --------------------------------------------------

    def submit(self, request: TenantRequest) -> TenantSession:
        if request.name in self.sessions:
            raise ValueError(f"tenant {request.name!r} already exists")
        csv = (os.path.join(self._stream_dir, f"{request.name}.csv")
               if self._stream_dir else None)
        sess = TenantSession(request, submitted_round=self.round_no,
                             csv_path=csv)
        self.sessions[request.name] = sess
        self.queue.append(sess)
        self.metrics.submitted += 1
        return sess

    def evict(self, name: str) -> str:
        """Park a tenant on disk (between rounds); returns the checkpoint
        path.  The tenant stays EVICTED until `resume`."""
        sess = self.sessions[name]
        if sess.status != RUNNING:
            raise ValueError(f"tenant {name!r} is {sess.status}, not "
                             f"running")
        group, b = self._locate(sess)
        self._evict_slot(group, b, requeue=False)
        return sess.ckpt_path

    def resume(self, name: str,
               eng: Optional[EngineConfig] = None) -> TenantSession:
        """Re-queue an evicted tenant, optionally into a different engine
        layout (elastic reshard: the checkpoint is layout-free)."""
        sess = self.sessions[name]
        if sess.status != EVICTED:
            raise ValueError(f"tenant {name!r} is {sess.status}, not "
                             f"evicted")
        if eng is not None:
            if eng.delivery != sess.eng.delivery:
                raise ValueError("cannot change delivery on resume: the "
                                 "backends' fp32 summation orders differ")
            sess.eng = eng
        sess.status = QUEUED
        sess.resumes += 1
        self.metrics.resumes += 1
        self.queue.append(sess)
        return sess

    def run(self, max_rounds: int = 100_000) -> dict:
        """Drive rounds until every submitted tenant is DONE (or parked
        EVICTED with nothing left to schedule).  Returns the metrics
        snapshot."""
        t0 = time.perf_counter()
        for _ in range(max_rounds):
            if not self.step_round():
                break
        self.metrics.wall_s += time.perf_counter() - t0
        return self.metrics.snapshot(self.cache)

    # -- the lockstep round ----------------------------------------------

    def step_round(self) -> bool:
        """One scheduler round: refill slots from the queue, advance every
        live group `round_steps` steps, stream chunks, retire completed
        tenants.  Returns False when nothing is runnable."""
        self._refill()
        live_groups = [g for g in self.groups.values() if g.live()]
        if not live_groups and not self.queue:
            return False
        self.round_no += 1
        self.metrics.rounds += 1
        for group in live_groups:
            try:
                rasters = group.round()      # [slots, R, H, N]
            except Exception as err:         # noqa: BLE001 — degrade, don't die
                self._fail_group(group, err)
                continue
            self.metrics.group_rounds += 1
            for b, sess in group.live():
                take = min(self.round_steps,
                           sess.request.n_steps - sess.t)
                chunk = rasters[b, :take]
                gid = np.asarray(
                    distributed._base_plan(sess.planT).gid)
                sess.stream.push(chunk, gid, t0=sess.t)
                sess.spike_total += int(chunk.sum())
                sess.t += self.round_steps
                sess.rounds += 1
                self.metrics.tenant_rounds += 1
                self.metrics.tenant_steps += take
                if sess.t >= sess.request.n_steps:
                    self._complete(group, b, sess)
        for sess in self.queue:
            sess.queue_wait_rounds += 1
            self.metrics.queue_wait_rounds += 1
        return True

    # -- internals -------------------------------------------------------

    def _locate(self, sess: TenantSession):
        for group in self.groups.values():
            for b, s in group.live():
                if s is sess:
                    return group, b
        raise KeyError(sess.name)

    def _session_key(self, sess: TenantSession) -> batcher.ShapeKey:
        req = sess.request
        return batcher.shape_key(req.cfg, sess.eng, req.caps, req.cap_ev)

    def _refill(self) -> None:
        pending, self.queue = self.queue, []
        deferred: List[TenantSession] = []
        while pending:
            sess = pending.pop(0)
            if not self._try_admit(sess):
                deferred.append(sess)
        # re-queued preemption victims land behind deferred waiters
        self.queue = deferred + self.queue

    def _try_admit(self, sess: TenantSession) -> bool:
        key = self._session_key(sess)
        group = self.groups.get(key)
        b = group.free_slot() if group is not None else None
        if group is not None and b is None:
            if not self.preempt:
                return False
            b = self._preempt_slot(group, sess)
            if b is None:
                return False

        req = sess.request
        tables = connectivity.build_all_shards(req.cfg, sess.eng)
        spec_r, planT_r, state_r = batcher.build_parts(
            req.cfg, sess.eng, req.caps, req.cap_ev, tables=tables)
        caps_r = batcher.measure_caps(spec_r, planT_r, state_r)

        if group is not None and not group.caps.fits(caps_r):
            # regroup: grow the negotiated capacities, park the current
            # occupants (bit-exact via checkpoint), re-form the group
            self.regroups += 1
            grown = batcher.negotiate(caps_r, cap_ev=req.cap_ev,
                                      prior=group.caps)
            for ob, osess in group.live():
                self._evict_slot(group, ob, requeue=True)
            del self.groups[key]
            group, b = None, None
            gcaps = grown
        elif group is None:
            gcaps = batcher.negotiate(caps_r, cap_ev=req.cap_ev)
        else:
            gcaps = group.caps

        spec_p, planT_p, state_p = batcher.build_parts(
            req.cfg, sess.eng, req.caps, req.cap_ev, pad=gcaps,
            tables=tables)
        if sess.ckpt_path is not None:
            state_p = self._load_state(sess, spec_r, planT_r, gcaps)

        if group is None:
            prog = self.cache.get(key, spec_p)
            group = batcher.BatchGroup(key, prog, self.slots, gcaps,
                                       planT_p, state_p)
            self.groups[key] = group
            b = 0
        elif b is None:                      # group was just re-formed
            b = group.free_slot()

        sess.spec, sess.planT = spec_r, planT_r
        group.install(b, sess, planT_p, state_p, self.round_no)
        sess.status = RUNNING
        sess.admitted_round = self.round_no
        if sess.first_admit_round is None:
            sess.first_admit_round = self.round_no
        self.metrics.admissions += 1
        return True

    def _load_state(self, sess: TenantSession, spec_r, planT_r,
                    gcaps: batcher.GroupCaps):
        """Checkpoint -> realized-layout state -> group-padded state."""
        plan_r = distributed._base_plan(planT_r)
        cap_ev = gcaps.cap_ev if sess.eng.delivery == "event" else None
        state, t = checkpoint.load(sess.ckpt_path, spec_r, plan_r,
                                   cap_ev=cap_ev)
        assert t == sess.t, (t, sess.t)
        return batcher.pad_state(state, gcaps.e_cap)

    def _preempt_slot(self, group: batcher.BatchGroup,
                      waiter: TenantSession) -> Optional[int]:
        cands = [(b, s) for b, s in group.live()
                 if self.round_no - group.admit_round[b]
                 >= self.min_resident_rounds
                 and s.t > waiter.t]
        if not cands:
            return None
        b, _ = max(cands, key=lambda bs: (bs[1].t, -bs[0]))
        self._evict_slot(group, b, requeue=True)
        self.metrics.preemptions += 1
        return b

    def _evict_slot(self, group: batcher.BatchGroup, b: int,
                    requeue: bool) -> None:
        sess = group.sessions[b]
        state = batcher.unpad_state(group.slot_state(b),
                                    sess.spec.e_cap)
        path = os.path.join(self._ckpt_dir,
                            f"{sess.name}_t{sess.t}.npz")
        plan_r = distributed._base_plan(sess.planT)
        checkpoint.save(path, sess.spec, plan_r, state, sess.t)
        sess.ckpt_path = path
        group.release(b)
        sess.evictions += 1
        self.metrics.evictions += 1
        if requeue:
            sess.status = QUEUED
            self.queue.append(sess)
        else:
            sess.status = EVICTED

    def _fail_group(self, group: batcher.BatchGroup, err: Exception) -> None:
        """Graceful degradation: a group whose round raised loses the
        group, not the service.  `BatchGroup.round` commits its state
        only when the compiled program returns, so every slot still holds
        the tenant's last round-boundary state — exactly what the normal
        eviction path checkpoints.  Occupants are evicted+requeued (they
        re-admit into a freshly built group, replaying nothing and
        changing no output bit); a tenant that keeps failing past
        `max_tenant_failures` retires FAILED instead of retrying forever.
        The dead group is dropped (a fresh one forms on re-admission); the
        compiled round program stays cached — it is shape-keyed, not
        group-owned, and recompiling it would not change its behavior."""
        self.metrics.group_failures += 1
        print(f"[simserve] group {group.key} round failed: {err!r}; "
              f"evicting {len(group.live())} tenant(s)", flush=True)
        for b, sess in group.live():
            sess.failures += 1
            if sess.failures > self.max_tenant_failures:
                group.release(b)
                sess.status = FAILED
                self.metrics.failed += 1
                print(f"[simserve] tenant {sess.name!r} FAILED after "
                      f"{sess.failures} group failures", flush=True)
                continue
            self._evict_slot(group, b, requeue=True)
            self.metrics.failure_evictions += 1
        self.groups.pop(group.key, None)

    def _complete(self, group: batcher.BatchGroup, b: int,
                  sess: TenantSession) -> None:
        state = group.slot_state(b)
        if hasattr(state, "sat"):
            sess.sat_total = int(np.asarray(state.sat).sum())
        group.release(b)
        sess.status = DONE
        self.metrics.completed += 1
