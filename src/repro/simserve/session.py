"""Tenant lifecycle for `repro.simserve`.

A tenant is one independent user simulation: its own `GridConfig` (seed
included), engine layout, requested step count and optional event-backend
capacity overrides.  The session tracks the tenant through

    QUEUED -> RUNNING -> (EVICTED -> QUEUED -> RUNNING)* -> DONE

where every RUNNING stretch lives in one slot of a shape-key batch group
(`batcher.BatchGroup`) and every EVICTED stretch is a layout-free
checkpoint on disk (`core.checkpoint`).  A resume may change the engine
layout (`TenantSession.eng` vs the original `request.eng`) — the
checkpoint machinery reshards elastically, and the correctness contract
(`RasterStream.signature()` == the solo `StepProgram` run of the original
config) is layout-independent by the paper's Table 1 invariant.

Raster output is streamed: each scheduler round pushes one `[take, H, N]`
chunk; `RasterStream` accumulates the extracted (t, gid) events (and
optionally appends them to a CSV via `observables.dump_events_csv`)
without ever materializing the full raster.  Because `raster_events`
sorts each chunk by (t, g) and chunk time ranges never overlap, the
concatenation of chunk events IS the canonical order and
`observables.events_signature` over it is bit-equal to the full-run
`raster_signature` by construction.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core import observables
from ..core.params import EngineConfig, GridConfig

QUEUED = "queued"
RUNNING = "running"
EVICTED = "evicted"
DONE = "done"
FAILED = "failed"   # terminal: exceeded the service's per-tenant failure cap


@dataclasses.dataclass(frozen=True)
class TenantRequest:
    """One user simulation: config + how long to run it.

    `caps` / `cap_ev` override the event backend's compaction and ring
    capacities (they change traced shapes, so they are part of the shape
    key — tenants with custom capacities batch only with like tenants)."""
    name: str
    cfg: GridConfig
    eng: EngineConfig
    n_steps: int
    caps: Optional[Tuple[int, int]] = None   # (c_post, c_src)
    cap_ev: Optional[int] = None             # event ring capacity


class RasterStream:
    """Incremental spike-event accumulation with a streaming signature."""

    def __init__(self, csv_path: Optional[str] = None):
        self._ts: List[np.ndarray] = []
        self._gs: List[np.ndarray] = []
        self.csv_path = csv_path
        self.n_events = 0
        self.chunks = 0

    def push(self, raster: np.ndarray, gid: np.ndarray, t0: int) -> None:
        """Append one raster chunk starting at absolute step `t0`."""
        t, g = observables.raster_events(raster, gid, t0=t0)
        self._ts.append(t)
        self._gs.append(g)
        self.n_events += int(t.shape[0])
        self.chunks += 1
        if self.csv_path:
            observables.dump_events_csv(self.csv_path, raster, gid,
                                        append=True, t0=t0)

    def events(self) -> Tuple[np.ndarray, np.ndarray]:
        if not self._ts:
            z = np.zeros((0,), np.int64)
            return z, z
        return np.concatenate(self._ts), np.concatenate(self._gs)

    def signature(self) -> bytes:
        return observables.events_signature(*self.events())


class TenantSession:
    """Scheduler-side view of one tenant."""

    def __init__(self, request: TenantRequest, submitted_round: int,
                 csv_path: Optional[str] = None):
        self.request = request
        self.status = QUEUED
        self.t = 0                    # steps completed (round-granular)
        self.stream = RasterStream(csv_path)
        self.eng = request.eng        # CURRENT layout (resume may change it)
        self.spec = None              # set on admission (current layout)
        self.planT = None
        self.ckpt_path: Optional[str] = None
        self.sat_total = 0            # event-backend drop counter at DONE
        self.spike_total = 0
        # metrics
        self.submitted_round = submitted_round
        self.first_admit_round: Optional[int] = None
        self.admitted_round: Optional[int] = None
        self.queue_wait_rounds = 0
        self.rounds = 0
        self.evictions = 0
        self.resumes = 0
        self.failures = 0   # group-execution failures survived (evicted +
        #                     requeued from the last round boundary)

    @property
    def name(self) -> str:
        return self.request.name

    @property
    def done(self) -> bool:
        return self.status == DONE

    def metrics(self) -> dict:
        return dict(name=self.name, status=self.status, t=self.t,
                    n_steps=self.request.n_steps, rounds=self.rounds,
                    evictions=self.evictions, resumes=self.resumes,
                    failures=self.failures,
                    queue_wait_rounds=self.queue_wait_rounds,
                    n_events=self.stream.n_events,
                    spike_total=self.spike_total,
                    sat_total=self.sat_total,
                    shards=self.eng.n_shards,
                    delivery=self.eng.delivery)
