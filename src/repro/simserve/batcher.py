"""Shape-keyed program cache + the vmapped multi-tenant round.

The service's whole compilation story is one observation about the
engine: the tenant seed enters the computation ONLY through values —
connectivity tables / initial weights (host-built into the plan/state)
and the stimulus PRNG key (`stimulus.stim_key(cfg)`).  Nothing traced
reads `cfg.seed`.  So every config that differs only by seed lowers to
the same jaxpr, and a whole fleet of such tenants can share ONE jitted
round program with the per-tenant data stacked on a free leading batch
axis:

    round(plans[B,...], states[B,...], t0s[B], stim_keys[B])
        -> (states', rasters[B, R, H, N])

`shape_key` captures what the trace semantically depends on: the full
GridConfig with the seed zeroed, the EngineConfig (delivery, shards,
placement, exchange, schedule), and the event-capacity overrides.  One
wrinkle: the REALIZED static capacities (source-table width `s_cap`,
valid-synapse capacity `e_cap`, event fan-out paddings Kf/Ki) depend on
the drawn connectivity, i.e. on the seed.  The batcher therefore
canonicalizes: each group negotiates `GroupCaps` (first tenant's
realized capacities + headroom, rounded), and every admitted tenant's
tables are re-padded to them (`connectivity.repad_shard` — the exact
mechanism `build_all_shards` already uses to unify capacities across
shards; pad entries carry `valid=False`/`-1` and are masked out of every
reduction, so padding is numerics-free).  A tenant that overflows the
group's capacities forces a regroup (scheduler evicts + re-admits — rare
by construction of the headroom, counted in metrics, and bit-exact via
the checkpoint round-trip).

The per-tenant round body is `engine.make_step_fn` /
`event_engine.make_step_fn` verbatim (same phase callables via
`distributed._delivery_phases`, same global-mask exchange, same scan),
with `t0` and the stimulus key promoted from closure constants to traced
arguments.  `jax.vmap` over tenants adds a leading axis to every op but
changes no per-tenant reduction order, so each slot's raster is
bit-identical to the same config run solo through `StepProgram` — the
service's correctness spine, asserted in tests and the CI soak.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (connectivity, distributed, engine, event_engine,
                    observables, stimulus)
from ..core.engine import NEG_TIME
from ..core.event_engine import EventPlan, EventState
from ..core.params import EngineConfig, GridConfig

ShapeKey = Tuple[GridConfig, EngineConfig,
                 Optional[Tuple[int, int]], Optional[int]]


def shape_key(cfg: GridConfig, eng: EngineConfig,
              caps: Optional[Tuple[int, int]] = None,
              cap_ev: Optional[int] = None) -> ShapeKey:
    """Program identity: everything that shapes the traced computation.

    The seed is zeroed out — it reaches the program only through jit
    arguments (plan values, initial weights, stimulus key).  Both configs
    are frozen dataclasses, so the tuple is hashable."""
    return (dataclasses.replace(cfg, seed=0), eng, caps, cap_ev)


# ---------------------------------------------------------------------------
# capacity canonicalization
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupCaps:
    """Canonical static capacities every member of a batch group is
    padded to.  `cap_ev` is the event-ring capacity implied by the padded
    e_cap (or the tenant override, which is part of the shape key)."""
    e_cap: int
    s_cap: int
    kf: int              # event forward-row padding (0 for dense)
    ki: int              # event incoming-row padding (0 for dense)
    cap_ev: int          # event ring capacity (0 for dense)

    def fits(self, other: "GroupCaps") -> bool:
        return (self.e_cap >= other.e_cap and self.s_cap >= other.s_cap
                and self.kf >= other.kf and self.ki >= other.ki)


def _round_up(x: int, m: int) -> int:
    return max(m, -(-x // m) * m)


def measure_caps(spec, planT, state) -> GroupCaps:
    """Realized capacities of one tenant's build."""
    if isinstance(state, EventState):   # NamedTuples ARE tuples: dispatch
        _, eplan = planT                # on the state type, not tuple-ness
        return GroupCaps(e_cap=spec.e_cap, s_cap=spec.s_cap,
                         kf=int(eplan.fwd_rows.shape[-1]),
                         ki=int(eplan.in_rows.shape[-1]),
                         cap_ev=int(state.ev_ring.shape[-1]))
    return GroupCaps(e_cap=spec.e_cap, s_cap=spec.s_cap, kf=0, ki=0,
                     cap_ev=0)


def negotiate(realized: GroupCaps, cap_ev: Optional[int] = None,
              prior: Optional[GroupCaps] = None) -> GroupCaps:
    """Realized capacities -> group capacities with headroom, so sibling
    tenants (different seeds, slightly different realized paddings) fit
    without a regroup.  Deterministic; monotone over `prior` on regroup."""
    e = _round_up(realized.e_cap + realized.e_cap // 8, 16)
    s = _round_up(realized.s_cap + realized.s_cap // 8, 16)
    kf = _round_up(realized.kf + max(2, realized.kf // 4), 4) \
        if realized.kf else 0
    ki = _round_up(realized.ki + max(2, realized.ki // 4), 4) \
        if realized.ki else 0
    if prior is not None:
        e, s = max(e, prior.e_cap), max(s, prior.s_cap)
        kf, ki = max(kf, prior.kf), max(ki, prior.ki)
    if cap_ev is not None:
        cev = cap_ev
    elif realized.cap_ev:
        # same rule event_engine.build_event_plan applies, over padded E
        cev = max(256, _round_up(e // 4, 128))
    else:
        cev = 0
    return GroupCaps(e_cap=e, s_cap=s, kf=kf, ki=ki, cap_ev=cev)


def _pad_rows_to(rows: jnp.ndarray, n_rows: int, k: int) -> jnp.ndarray:
    """Pad [H, R, K] event rows to [H, n_rows, k] with -1."""
    H, R, K = rows.shape
    out = np.full((H, n_rows, k), -1, dtype=np.int32)
    out[:, :R, :K] = np.asarray(rows)
    return jnp.asarray(out)


def build_parts(cfg: GridConfig, eng: EngineConfig,
                caps: Optional[Tuple[int, int]] = None,
                cap_ev: Optional[int] = None,
                pad: Optional[GroupCaps] = None,
                tables=None):
    """(spec, planT, state0) for one tenant.

    planT is the delivery-dependent plan tree every jitted program takes
    as an argument (dense: ShardPlan; event: (ShardPlan, EventPlan)).
    With `pad`, the connectivity tables are re-padded to the group's
    canonical capacities before the plan/state derive from them, so all
    members of a batch group stack exactly."""
    if tables is None:
        tables = connectivity.build_all_shards(cfg, eng)
    if pad is not None:
        tables = [connectivity.repad_shard(t, pad.e_cap, pad.s_cap)
                  for t in tables]
    spec, plan, state = engine.build(cfg, eng, tables=tables)
    if eng.delivery != "event":
        return spec, plan, state
    eplan, cap_default = event_engine.build_event_plan(spec, tables=tables)
    if pad is not None:
        eplan = EventPlan(
            fwd_rows=_pad_rows_to(eplan.fwd_rows, spec.s_cap, pad.kf),
            in_rows=_pad_rows_to(eplan.in_rows, spec.n_local, pad.ki))
    resolved = cap_ev if cap_ev is not None else (
        pad.cap_ev if pad is not None else cap_default)
    estate = event_engine.init_event_state(spec, state, resolved)
    return spec, (plan, eplan), estate


def unpad_state(state, e_real: int):
    """Slice a group-padded state back to its realized synapse capacity
    (padding is a pure suffix never written by the engine), so the
    layout-free checkpoint writer sees the shapes its connectivity
    rebuild produces."""
    if isinstance(state, EventState):
        return state._replace(base=unpad_state(state.base, e_real))
    return state._replace(w=state.w[..., :e_real],
                          last_arr=state.last_arr[..., :e_real],
                          arr_ring=state.arr_ring[..., :e_real])


def pad_state(state, e_pad: int):
    """Inverse of `unpad_state` for checkpoint-loaded states: grow the
    synapse axis to the group capacity with the engine's init fill values
    (w=0, last_arr=never, no pending arrivals)."""
    if isinstance(state, EventState):
        return state._replace(base=pad_state(state.base, e_pad))
    d = e_pad - state.w.shape[-1]
    if d == 0:
        return state
    padf = lambda a, v: jnp.concatenate(
        [a, jnp.full(a.shape[:-1] + (d,), v, a.dtype)], axis=-1)
    return state._replace(w=padf(state.w, 0.0),
                          last_arr=padf(state.last_arr, NEG_TIME),
                          arr_ring=padf(state.arr_ring, False))


def caps_dict(caps: Optional[Tuple[int, int]]) -> Optional[dict]:
    """(c_post, c_src) tuple -> the dict `StepProgram`/phase fns take."""
    if caps is None:
        return None
    return {"c_post": caps[0], "c_src": caps[1]}


def solo_signature(cfg: GridConfig, eng: EngineConfig, n_steps: int,
                   caps: Optional[Tuple[int, int]] = None,
                   cap_ev: Optional[int] = None) -> bytes:
    """Reference signature: the same tenant run alone through
    `StepProgram` (no batching, no padding, no service).  This is the
    right-hand side of the service's correctness contract."""
    from ..core.step_program import StepProgram
    spec, planT, state = build_parts(cfg, eng, caps, cap_ev)
    plan = distributed._base_plan(planT)
    eplan = planT[1] if eng.delivery == "event" else None
    prog = StepProgram.from_parts(spec, plan, eplan, state0=state,
                                  mesh=None, caps=caps_dict(caps),
                                  hier_groups=None)
    _, raster, _ = prog.run(state, 0, n_steps)
    return observables.raster_signature(np.asarray(raster),
                                        np.asarray(plan.gid))


def stim_key_data(cfg: GridConfig) -> np.ndarray:
    """Host-side uint32 key data for one tenant's stimulus key.  The
    batched round wraps a stacked [B, 2] array back into a key array, so
    slot refills are plain array writes."""
    return np.asarray(jax.random.key_data(stimulus.stim_key(cfg)))


# ---------------------------------------------------------------------------
# the compiled round + program cache
# ---------------------------------------------------------------------------


class CompiledRound:
    """One jitted multi-tenant round program for a shape key.

    `traces` counts how many times jax actually traced the batched body;
    it must stay at 1 for any number of same-key tenants, rounds and
    refills (the zero-recompile acceptance criterion).  A group regrow
    (rare) changes argument shapes and retraces the same jitted fn."""

    def __init__(self, spec, caps: Optional[Tuple[int, int]],
                 round_steps: int):
        # normalize the closed-over spec's seed so correctness cannot
        # silently depend on which tenant built the program first
        self.spec = spec._replace(
            cfg=dataclasses.replace(spec.cfg, seed=0))
        self.round_steps = int(round_steps)
        self.traces = 0
        spec_n = self.spec
        cd = caps_dict(caps)

        def one(planT, state, t0, stim_k):
            ph = distributed._delivery_phases(spec_n, stim_k, cd)
            bp = distributed._base_plan(planT)

            def step(st, t):
                st, spiked, tm = jax.vmap(
                    lambda pT, s: ph.pa(pT, s, t))(planT, st)
                glob = engine._global_spike_mask(spec_n, bp, spiked)
                ss = jax.vmap(
                    lambda p: glob.at[p.src_gid].get(
                        mode="fill", fill_value=False)
                    & (p.src_gid >= 0))(bp)
                st = jax.vmap(
                    lambda pT, s, s2: ph.pb(pT, s, s2, t))(planT, st, ss)
                return st, spiked

            ts = t0 + jnp.arange(round_steps, dtype=jnp.int32)
            state, raster = jax.lax.scan(step, state, ts)
            return state, raster

        def batched(plans, states, t0s, stim_key_data):
            self.traces += 1      # fires at trace time only
            ks = jax.random.wrap_key_data(stim_key_data)
            return jax.vmap(one)(plans, states, t0s, ks)

        self.fn = jax.jit(batched)

    def __call__(self, plans, states, t0s, key_data):
        return self.fn(plans, states, t0s, key_data)


class ProgramCache:
    """Shape key -> CompiledRound.  One compile per key, ever."""

    def __init__(self, round_steps: int):
        self.round_steps = int(round_steps)
        self._programs: Dict[ShapeKey, CompiledRound] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: ShapeKey, spec) -> CompiledRound:
        prog = self._programs.get(key)
        if prog is not None:
            # the traced body reads e_cap/s_cap statically off the spec
            # (event compaction fill indices), so a regrouped key with
            # grown capacities needs a fresh program
            if (prog.spec.e_cap, prog.spec.s_cap) == (spec.e_cap,
                                                      spec.s_cap):
                self.hits += 1
                return prog
        self.misses += 1
        prog = CompiledRound(spec, caps=key[2],
                             round_steps=self.round_steps)
        self._programs[key] = prog
        return prog

    @property
    def builds(self) -> int:
        return len(self._programs)

    def trace_counts(self) -> Dict[str, int]:
        return {f"{k[1].delivery}/H{k[1].n_shards}"
                f"/{k[0].grid_x}x{k[0].grid_y}x{k[0].neurons_per_column}":
                p.traces for k, p in self._programs.items()}


# ---------------------------------------------------------------------------
# the live batch group
# ---------------------------------------------------------------------------


class BatchGroup:
    """Live batch of same-shape tenants: stacked device buffers + slots.

    The buffers are [slots, ...]-stacked copies of the delivery plan tree
    and dynamic state, all padded to `caps`; free slots keep whatever
    payload last occupied them (a valid plan of the same shape — its
    output is simply ignored), so the batch width never changes and the
    round program never retraces."""

    def __init__(self, key: ShapeKey, prog: CompiledRound, slots: int,
                 caps: GroupCaps, planT, state):
        self.key = key
        self.prog = prog
        self.slots = int(slots)
        self.caps = caps
        self.sessions = [None] * self.slots
        self.admit_round = [0] * self.slots     # scheduler round of admission
        tile = lambda x: jnp.repeat(x[None], self.slots, axis=0)
        self.plans = jax.tree.map(tile, planT)
        self.states = jax.tree.map(tile, state)
        kd = stim_key_data(key[0])
        self._key_data = np.repeat(kd[None], self.slots, axis=0)

    def free_slot(self) -> Optional[int]:
        for b, s in enumerate(self.sessions):
            if s is None:
                return b
        return None

    def live(self):
        return [(b, s) for b, s in enumerate(self.sessions)
                if s is not None]

    def install(self, b: int, sess, planT, state, round_no: int) -> None:
        upd = lambda full, one: full.at[b].set(one)
        self.plans = jax.tree.map(upd, self.plans, planT)
        self.states = jax.tree.map(upd, self.states, state)
        self._key_data[b] = stim_key_data(sess.request.cfg)
        self.sessions[b] = sess
        self.admit_round[b] = round_no

    def release(self, b: int) -> None:
        self.sessions[b] = None

    def slot_state(self, b: int):
        return jax.tree.map(lambda x: x[b], self.states)

    def round(self) -> np.ndarray:
        """Advance every slot `round_steps` steps; returns the stacked
        raster [slots, R, H, N] (host numpy)."""
        t0s = jnp.asarray(
            [s.t if s is not None else 0 for s in self.sessions],
            jnp.int32)
        self.states, rasters = self.prog(
            self.plans, self.states, t0s, jnp.asarray(self._key_data))
        return np.asarray(rasters)
