"""repro.simserve — multi-tenant SNN simulation-as-a-service.

Many independent tenant simulations (own GridConfig incl. seed, own
engine layout, own step budget) admitted into one process and advanced
in lockstep rounds.  Tenants whose configs differ only by seed share ONE
jitted round program (the seed reaches the computation exclusively
through jit arguments: connectivity/weights in the plan, the stimulus
PRNG key) and run stacked on a free leading batch axis — continuous-
batching-lite, mirroring `repro.serve` for the LM side.

The correctness contract, asserted in tests and the CI soak: every
tenant's streamed raster signature is bit-identical to the same config
run solo through `core.StepProgram`, regardless of batch companions,
slot-refill order, or evict/resume cycles — including resumes into a
different shard layout via the layout-free `core.checkpoint` format.

    python -m repro.simserve demo     # verified mixed fleet
    python -m repro.simserve soak     # overload + forced evict/resume
"""
from .batcher import (BatchGroup, CompiledRound, GroupCaps, ProgramCache,
                      build_parts, measure_caps, negotiate, shape_key,
                      solo_signature)
from .metrics import ServiceMetrics
from .queue import SimService
from .session import (DONE, EVICTED, FAILED, QUEUED, RUNNING, RasterStream,
                      TenantRequest, TenantSession)

__all__ = [
    "BatchGroup", "CompiledRound", "GroupCaps", "ProgramCache",
    "build_parts", "measure_caps", "negotiate", "shape_key",
    "solo_signature", "ServiceMetrics", "SimService", "DONE", "EVICTED",
    "FAILED", "QUEUED", "RUNNING", "RasterStream", "TenantRequest",
    "TenantSession",
]
