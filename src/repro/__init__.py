"""DPSNN-STDP mini-application reproduction (arXiv 1310.8478) on JAX.

Subpackages: `core` (the spiking engine), `dist` (mesh + sharding rules),
`models`/`train`/`optim`/`serve` (the LM substrate), `launch` (entry
points), `configs`, `data`, `kernels`.
"""

__version__ = "0.1.0"
