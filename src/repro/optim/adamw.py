"""Sharded AdamW (built from scratch — no optax in this environment).

Optimizer state is a pytree mirroring params (m, v in fp32); under pjit it
inherits the params' sharding (FSDP over 'data' + TP over 'model'), so
memory scales 1/chips like the weights.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray       # [] int32
    m: Any                  # pytree like params, fp32
    v: Any                  # pytree like params, fp32


def init(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def update(grads, state: AdamWState, params, *, lr,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state).  lr may be a scalar or callable of
    step."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1.0 - b1) * gf
        v2 = b2 * v + (1.0 - b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        pf = p.astype(jnp.float32)
        step_ = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf
        return (pf - lr_t * step_).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
