"""LR schedules: WSD (warmup-stable-decay, the MiniCPM arch's defining
schedule) and cosine."""
from __future__ import annotations

import jax.numpy as jnp


def wsd(peak_lr: float, warmup: int, stable: int, decay: int,
        final_frac: float = 0.1):
    """MiniCPM WSD: linear warmup -> flat -> exponential-ish decay."""
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        flat = jnp.float32(peak_lr)
        t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak_lr * (final_frac ** t)
        return jnp.where(s < warmup, warm,
                         jnp.where(s < warmup + stable, flat, dec))
    return f


def cosine(peak_lr: float, warmup: int, total: int,
           final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * t))
        return jnp.where(s < warmup, warm, peak_lr * cos)
    return f


def constant(lr: float):
    return lambda step: jnp.float32(lr)
