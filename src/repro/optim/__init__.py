from . import adamw, grad_utils, schedules

__all__ = ["adamw", "grad_utils", "schedules"]
