"""Gradient utilities: global-norm clipping and bf16 gradient compression
with error feedback (the distributed-optimization trick for cross-pod
all-reduce: halves DCN bytes; the residual buffer keeps it unbiased over
time)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, residual) -> Tuple[Any, Any]:
    """bf16-quantize grads (for the wire); residual carries the error.

    Returns (compressed bf16 grads, new residual).  The all-reduce across
    the 'pod' axis then moves half the bytes; decompression is a cast."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q = gf.astype(jnp.bfloat16)
        return q, gf - q.astype(jnp.float32)

    out = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return comp, res
