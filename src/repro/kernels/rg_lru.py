"""Pallas TPU kernel: RG-LRU linear-recurrence scan.

    h_t = a_t (.) h_{t-1} + b_t          (elementwise over D)

The jnp path uses `jax.lax.associative_scan` (log-depth, 2x memory); on
TPU the sequential formulation is VMEM-resident: grid = (B tiles, D tiles,
T chunks) with T innermost — the carry h lives in a VMEM scratch across
the sequential grid steps, so HBM traffic is exactly read(a,b) + write(h),
the memory-bound optimum.  Also serves RWKV-ish diagonal recurrences.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, h0_ref, out_ref, carry_ref, *, bt: int,
            t_chunks: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        carry_ref[...] = h0_ref[...]

    def body(i, h):
        a = a_ref[:, i, :]
        b = b_ref[:, i, :]
        h = a * h + b
        out_ref[:, i, :] = h
        return h

    h = jax.lax.fori_loop(0, bt, body, carry_ref[...])
    carry_ref[...] = h


def rg_lru_scan(a, b, h0, *, block_b: int = 8, block_t: int = 128,
                block_d: int = 128, interpret: bool = False):
    """a, b: [B, T, D] fp32; h0: [B, D].  Returns h: [B, T, D]."""
    B, T, D = a.shape
    bb = min(block_b, B)
    bt = min(block_t, T)
    bd = min(block_d, D)
    assert B % bb == 0 and T % bt == 0 and D % bd == 0, (B, T, D)
    grid = (B // bb, D // bd, T // bt)

    from jax.experimental.pallas import tpu as pltpu
    kern = functools.partial(_kernel, bt=bt, t_chunks=T // bt)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bt, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((bb, bt, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((bb, bd), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, bt, bd), lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bd), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
