"""Pallas TPU kernel: fused Izhikevich neuron update.

Fuses the two membrane half-steps, the recovery-variable step, spike
detection, and the post-spike reset into a single VMEM pass (the jnp path
materializes ~8 intermediates in HBM).  Elementwise, VPU-only; tiles are
(8, 128)-aligned fp32.

Layout: the ops wrapper reshapes the [N] neuron arrays to [N/128, 128]
(padded), so the kernel sees 2-D refs as the TPU vector unit wants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, u_ref, i_ref, a_ref, b_ref, c_ref, d_ref,
            vout_ref, uout_ref, spk_ref, *, v_peak: float, dt: float,
            substeps: int):
    v = v_ref[...]
    u = u_ref[...]
    cur = i_ref[...]
    a, b, c, d = a_ref[...], b_ref[...], c_ref[...], d_ref[...]

    h = jnp.float32(dt / substeps)
    for _ in range(substeps):
        v = v + h * (0.04 * v * v + 5.0 * v + 140.0 - u + cur)
    u = u + jnp.float32(dt) * a * (b * v - u)

    spiked = v >= jnp.float32(v_peak)
    vout_ref[...] = jnp.where(spiked, c, v)
    uout_ref[...] = jnp.where(spiked, u + d, u)
    spk_ref[...] = spiked


def izhikevich_update(v, u, current, a, b, c, d, *, v_peak: float,
                      dt: float = 1.0, substeps: int = 2,
                      block_rows: int = 8, interpret: bool = False):
    """All inputs [R, 128] fp32; returns (v', u', spiked)."""
    R = v.shape[0]
    grid = (pl.cdiv(R, block_rows),)
    spec = pl.BlockSpec((block_rows, 128), lambda i: (i, 0))
    import functools
    kern = functools.partial(_kernel, v_peak=v_peak, dt=dt,
                             substeps=substeps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=(spec, spec, spec),
        out_shape=(jax.ShapeDtypeStruct(v.shape, jnp.float32),
                   jax.ShapeDtypeStruct(v.shape, jnp.float32),
                   jax.ShapeDtypeStruct(v.shape, jnp.bool_)),
        interpret=interpret,
    )(v, u, current, a, b, c, d)
