"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are also the CPU-runtime implementations: ops.py dispatches to them
when `use_pallas=False` (this container) and to the kernels on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def izhikevich_update(v, u, current, a, b, c, d, *, v_peak: float,
                      dt: float = 1.0, substeps: int = 2):
    """Oracle for kernels.izhikevich.  Any shape, fp32."""
    h = jnp.float32(dt / substeps)
    for _ in range(substeps):
        v = v + h * (0.04 * v * v + 5.0 * v + 140.0 - u + current)
    u = u + jnp.float32(dt) * a * (b * v - u)
    spiked = v >= jnp.float32(v_peak)
    v = jnp.where(spiked, c, v)
    u = jnp.where(spiked, u + d, u)
    return v, u, spiked


def stdp_arrival(arr, w, last_post_g, last_arr, plastic, t, *,
                 a_minus, tau_minus, w_min, w_max, neg_time):
    """Oracle for kernels.stdp.stdp_arrival.  Any shape."""
    tf = jnp.float32(t) if jnp.ndim(t) == 0 else t.reshape(())
    ltd = jnp.float32(a_minus) * jnp.exp(
        (last_post_g - tf) / jnp.float32(tau_minus))
    apply = arr & plastic & (last_post_g > jnp.float32(neg_time / 2))
    w_out = jnp.where(apply, jnp.clip(w - ltd, w_min, w_max), w)
    la_out = jnp.where(arr, tf, last_arr)
    contrib = jnp.where(arr, w, 0.0)
    return w_out, la_out, contrib


def stdp_ltp(post_g, w, last_arr, plastic, valid, t, *,
             a_plus, tau_plus, w_min, w_max, neg_time):
    """Oracle for kernels.stdp.stdp_ltp."""
    tf = jnp.float32(t) if jnp.ndim(t) == 0 else t.reshape(())
    ltp = jnp.float32(a_plus) * jnp.exp(
        (last_arr - tf) / jnp.float32(tau_plus))
    apply = post_g & plastic & valid & (last_arr > jnp.float32(neg_time / 2))
    return jnp.where(apply, jnp.clip(w + ltp, w_min, w_max), w)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None):
    """Oracle for kernels.flash_attention.  q [BH,T,D], k/v [BH,S,D]."""
    bh, t, d = q.shape
    s_len = k.shape[1]
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(t)[:, None] + (s_len - t)
    k_pos = jnp.arange(s_len)[None, :]
    mask = jnp.ones((t, s_len), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1.0e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def rg_lru_scan(a, b, h0):
    """Oracle for kernels.rg_lru: h_t = a_t * h_{t-1} + b_t (sequential
    semantics; implemented with an associative scan)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h
