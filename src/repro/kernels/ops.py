"""Public kernel entry points with backend dispatch + shape plumbing.

`use_pallas=None` -> auto: Pallas on TPU, jnp oracle elsewhere.  An
explicit `use_pallas=True` off-TPU also falls back to the oracle (Pallas
only supports interpret mode on CPU, and the interpret path is a test
harness, ~100x slower) — so `EngineConfig(use_pallas=True)` is portable
and rasters stay bit-identical across backend dispatch on CPU
(tests/test_profiles.py).  Because that fallback silently changes which
code ran, the first explicit-True-off-TPU resolution emits a one-time
UserWarning naming the backend it fell back to; the numbers are still
correct (oracle == kernel bit-wise on the covered shapes), the warning
just keeps "I benchmarked the Pallas kernel" honest.  The interpret flag
runs the Pallas kernel body in Python on CPU (used by the kernel test
suite to validate against ref.py).
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import izhikevich as _izh
from . import ref
from . import stdp as _stdp

LANES = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


_warned_fallback = False


def _resolve(use_pallas: Optional[bool]) -> bool:
    # requested-or-auto, gated on the backend actually supporting compiled
    # Pallas: forcing Pallas on CPU raises "Only interpret mode is
    # supported on CPU backend" deep inside jit, so fall back here instead
    # — loudly (once): an explicit True that quietly ran the oracle would
    # let kernel benchmarks misreport what executed.
    if use_pallas is None:
        return _on_tpu()
    if use_pallas and not _on_tpu():
        global _warned_fallback
        if not _warned_fallback:
            _warned_fallback = True
            warnings.warn(
                f"use_pallas=True requested but the default backend is "
                f"{jax.default_backend()!r}, not TPU: falling back to the "
                f"jnp oracle (bit-identical results; compiled Pallas "
                f"kernels need a TPU).  This warning is emitted once.",
                UserWarning, stacklevel=3)
        return False
    return use_pallas


def _pad_to_2d(x, rows_mult: int = 8):
    """[N] -> ([R, 128], unpad_fn) with R a multiple of rows_mult."""
    n = x.shape[0]
    r = -(-n // LANES)
    r = -(-r // rows_mult) * rows_mult
    pad = r * LANES - n
    x2 = jnp.pad(x, (0, pad)).reshape(r, LANES)
    return x2, lambda y: y.reshape(-1)[:n]


def izhikevich_update(v, u, current, a, b, c, d, *, v_peak, dt=1.0,
                      substeps=2, use_pallas: Optional[bool] = None,
                      interpret: bool = False):
    """[N] fp32 arrays -> (v', u', spiked)."""
    if not _resolve(use_pallas) and not interpret:
        return ref.izhikevich_update(v, u, current, a, b, c, d,
                                     v_peak=v_peak, dt=dt, substeps=substeps)
    args, unpads = zip(*[_pad_to_2d(x) for x in (v, u, current, a, b, c, d)])
    v2, u2, s2 = _izh.izhikevich_update(*args, v_peak=v_peak, dt=dt,
                                        substeps=substeps,
                                        interpret=interpret)
    up = unpads[0]
    return up(v2), up(u2), up(s2)


def stdp_arrival(arr, w, last_post_g, last_arr, plastic, t, *, a_minus,
                 tau_minus, w_min, w_max, neg_time,
                 use_pallas: Optional[bool] = None, interpret: bool = False):
    """[E] arrays + scalar t -> (w', last_arr', contrib)."""
    if not _resolve(use_pallas) and not interpret:
        return ref.stdp_arrival(arr, w, last_post_g, last_arr, plastic, t,
                                a_minus=a_minus, tau_minus=tau_minus,
                                w_min=w_min, w_max=w_max, neg_time=neg_time)
    args, unpads = zip(*[_pad_to_2d(x) for x in
                         (arr, w, last_post_g, last_arr, plastic)])
    t1 = jnp.asarray(t, jnp.float32).reshape(1)
    w2, la2, c2 = _stdp.stdp_arrival(*args, t1, a_minus=a_minus,
                                     tau_minus=tau_minus, w_min=w_min,
                                     w_max=w_max, neg_time=neg_time,
                                     interpret=interpret)
    up = unpads[1]
    return up(w2), up(la2), up(c2)


def stdp_ltp(post_g, w, last_arr, plastic, valid, t, *, a_plus, tau_plus,
             w_min, w_max, neg_time, use_pallas: Optional[bool] = None,
             interpret: bool = False):
    """[E] arrays + scalar t -> w'."""
    if not _resolve(use_pallas) and not interpret:
        return ref.stdp_ltp(post_g, w, last_arr, plastic, valid, t,
                            a_plus=a_plus, tau_plus=tau_plus, w_min=w_min,
                            w_max=w_max, neg_time=neg_time)
    args, unpads = zip(*[_pad_to_2d(x) for x in
                         (post_g, w, last_arr, plastic, valid)])
    t1 = jnp.asarray(t, jnp.float32).reshape(1)
    w2 = _stdp.stdp_ltp(*args, t1, a_plus=a_plus, tau_plus=tau_plus,
                        w_min=w_min, w_max=w_max, neg_time=neg_time,
                        interpret=interpret)
    return unpads[1](w2)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              block_q: int = 128, block_k: int = 128,
              use_pallas: Optional[bool] = None, interpret: bool = False):
    """q [BH,T,D], k/v [BH,S,D].  GQA: repeat kv heads before calling."""
    if not _resolve(use_pallas) and not interpret:
        return ref.attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def rg_lru_scan(a, b, h0, *, use_pallas: Optional[bool] = None,
                interpret: bool = False):
    """Linear recurrence h_t = a_t*h_{t-1} + b_t.  a, b: [B,T,D]; h0 [B,D].

    TPU path: sequential VMEM-resident Pallas scan (kernels/rg_lru.py);
    otherwise the associative-scan oracle."""
    from . import rg_lru as _rg
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), a.dtype)
    if not _resolve(use_pallas) and not interpret:
        return ref.rg_lru_scan(a, b, h0)
    B, T, D = a.shape
    # pad D to the 128-lane boundary; pick dividing blocks for B and T
    padD = (-D) % LANES
    if padD:
        pad3 = ((0, 0), (0, 0), (0, padD))
        a = jnp.pad(a, pad3)
        # padded lanes must stay finite: a=0, b=0 -> h=0
        b = jnp.pad(b, pad3)
        h0 = jnp.pad(h0, ((0, 0), (0, padD)))

    def div_block(n, target):
        c = min(target, n)
        while n % c:
            c -= 1
        return c

    out = _rg.rg_lru_scan(a, b, h0, block_b=div_block(B, 8),
                          block_t=div_block(T, 128),
                          block_d=LANES, interpret=interpret)
    return out[..., :D] if padD else out
