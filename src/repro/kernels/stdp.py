"""Pallas TPU kernels: fused STDP passes over the synapse array.

Two kernels, both elementwise over the flat [E] synapse dimension (tiled
(8, 128) fp32).  The companion gathers (last_post[tgt], spiked[tgt],
spiked_src[src]) are XLA HBM gathers — cheap and already fused by XLA; the
win here is collapsing the 6-8 elementwise HBM round-trips of the jnp path
into one VMEM pass each (see EXPERIMENTS.md §Perf for the roofline math).

  arrival kernel (step phase 3+2): given this step's arrival flags,
      apply LTD (nearest post spike), refresh last_arrival, and emit the
      per-synapse current contribution to be segment-summed by target.

  ltp kernel (step phase 6): given post-spike flags gathered onto
      synapses, apply LTP against last_arrival.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _arrival_kernel(arr_ref, w_ref, lp_ref, la_in_ref, plastic_ref, t_ref,
                     wout_ref, la_ref, contrib_ref, *,
                     a_minus: float, tau_minus: float, w_min: float,
                     w_max: float, neg_time: float):
    arr = arr_ref[...]
    w = w_ref[...]
    lp = lp_ref[...]
    t = t_ref[0]

    ltd = jnp.float32(a_minus) * jnp.exp((lp - t) / jnp.float32(tau_minus))
    apply = arr & plastic_ref[...] & (lp > jnp.float32(neg_time / 2))
    wout_ref[...] = jnp.where(
        apply, jnp.clip(w - ltd, jnp.float32(w_min), jnp.float32(w_max)), w)
    la_ref[...] = jnp.where(arr, t, la_in_ref[...])
    contrib_ref[...] = jnp.where(arr, w, 0.0)


def _ltp_kernel(post_ref, w_ref, la_ref, plastic_ref, valid_ref, t_ref,
                wout_ref, *, a_plus: float, tau_plus: float, w_min: float,
                w_max: float, neg_time: float):
    post = post_ref[...]
    w = w_ref[...]
    la = la_ref[...]
    t = t_ref[0]

    ltp = jnp.float32(a_plus) * jnp.exp((la - t) / jnp.float32(tau_plus))
    apply = post & plastic_ref[...] & valid_ref[...] \
        & (la > jnp.float32(neg_time / 2))
    wout_ref[...] = jnp.where(
        apply, jnp.clip(w + ltp, jnp.float32(w_min), jnp.float32(w_max)), w)


def stdp_arrival(arr, w, last_post_g, last_arr, plastic, t, *,
                 a_minus, tau_minus, w_min, w_max, neg_time,
                 block_rows: int = 8, interpret: bool = False):
    """All array inputs [R, 128]; t is a [1] fp32 array.

    Returns (w', last_arr', contrib)."""
    R = w.shape[0]
    grid = (pl.cdiv(R, block_rows),)
    spec = pl.BlockSpec((block_rows, 128), lambda i: (i, 0))
    tspec = pl.BlockSpec((1,), lambda i: (0,))
    kern = functools.partial(_arrival_kernel, a_minus=a_minus,
                             tau_minus=tau_minus, w_min=w_min, w_max=w_max,
                             neg_time=neg_time)
    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[spec, spec, spec, spec, spec, tspec],
        out_specs=(spec, spec, spec),
        out_shape=(jax.ShapeDtypeStruct(w.shape, jnp.float32),
                   jax.ShapeDtypeStruct(w.shape, jnp.float32),
                   jax.ShapeDtypeStruct(w.shape, jnp.float32)),
        interpret=interpret,
    )(arr, w, last_post_g, last_arr, plastic, t)


def stdp_ltp(post_g, w, last_arr, plastic, valid, t, *,
             a_plus, tau_plus, w_min, w_max, neg_time,
             block_rows: int = 8, interpret: bool = False):
    """All array inputs [R, 128]; t is a [1] fp32 array.  Returns w'."""
    R = w.shape[0]
    grid = (pl.cdiv(R, block_rows),)
    spec = pl.BlockSpec((block_rows, 128), lambda i: (i, 0))
    tspec = pl.BlockSpec((1,), lambda i: (0,))
    kern = functools.partial(_ltp_kernel, a_plus=a_plus, tau_plus=tau_plus,
                             w_min=w_min, w_max=w_max, neg_time=neg_time)
    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[spec, spec, spec, spec, spec, tspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(w.shape, jnp.float32),
        interpret=interpret,
    )(post_g, w, last_arr, plastic, valid, t)
