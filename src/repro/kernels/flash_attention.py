"""Pallas TPU kernel: blockwise (flash) attention forward.

TPU-native design notes (vs the CUDA FlashAttention the idea comes from):
  - grid = (batch*heads, q_blocks, kv_blocks); TPU executes the grid
    sequentially per core, so the online-softmax running state (m, l, acc)
    lives in VMEM scratch carried across the innermost kv_blocks axis.
  - block shapes default to (128, head_dim) — MXU-aligned (128 lanes).
  - causal/sliding-window masking is applied per block; fully-masked blocks
    still iterate (TPU grids are static) but skip the matmuls under
    `pl.when` — the roofline win of skipping ~half the blocks is claimed by
    the hillclimb pass, not silently assumed.

Supports: causal or bidirectional, optional sliding window (Gemma-3 /
RecurrentGemma local layers), optional logit soft-capping (Gemma family),
GQA via head repetition in ops.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 softcap: Optional[float], q_offset: int, bq: int, bk: int,
                 kv_blocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(1)
    # absolute token positions of this q/k block
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level early-out test (static shapes; compute gated by pl.when)
    need = True
    if causal:
        first_q = i * bq + q_offset
        need = jnp.asarray(j * bk <= first_q + bq - 1)
    if window is not None:
        last_k_needed = None  # window is relative to query position
        need = jnp.logical_and(
            need, (j + 1) * bk - 1 >= i * bq + q_offset - (window - 1)) \
            if causal else need

    @pl.when(jnp.asarray(need))
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * jnp.float32(scale)
        if softcap is not None:
            s = jnp.float32(softcap) * jnp.tanh(s / jnp.float32(softcap))
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * correction + p.sum(axis=-1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * correction[:, None] + pv
        m_ref[...] = m_cur

    @pl.when(j == kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [BH, T, D], k/v: [BH, S, D] (same head count; GQA repeat upstream).

    Causal masking aligns the *end* of q to the end of k (decode-style
    offset q_offset = S - T).
    """
    bh, t, d = q.shape
    s_len = k.shape[1]
    scale = (d ** -0.5) if scale is None else scale
    bq = min(block_q, t)
    bk = min(block_k, s_len)
    assert t % bq == 0 and s_len % bk == 0, (t, bq, s_len, bk)
    grid = (bh, t // bq, s_len // bk)

    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=s_len - t, bq=bq, bk=bk,
        kv_blocks=s_len // bk)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=_scratch(bq, d),
        interpret=interpret,
    )(q, k, v)


def _scratch(bq, d):
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32)]
