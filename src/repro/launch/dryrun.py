"""Multi-pod dry run (deliverable e).

For every (architecture x input shape) cell, lower + compile the production
step on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, print
memory_analysis / cost_analysis, and extract per-device collective bytes
from the optimized HLO for the roofline (EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --snn          # the paper's own engine
Results are appended as JSON lines to results/dryrun/<cell>.json.
"""
import os

if __name__ == "__main__":
    # Only the CLI entry forces 512 host devices; importing this module
    # (tests, smaller meshes) must leave jax device state alone.  This runs
    # before ANY jax import below: jax locks the count at first init.
    # `repro._flags` is deliberately jax-free so this import is safe here.
    from repro._flags import force_host_device_count
    os.environ["XLA_FLAGS"] = force_host_device_count(512)

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import valid_cells
from repro.dist import compat as dist_compat
from repro.dist import sharding as shd
from repro.launch import input_specs as ispec
from repro.launch.mesh import make_production_mesh

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "results", "dryrun")

# TPU v5e-ish hardware constants for the roofline terms
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~ per-chip effective)

_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}


def _shapes_bytes(sig: str) -> int:
    """Sum bytes over every 'dtype[a,b,c]' token in `sig` (handles tuple
    results and layout annotations)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_LINE_RE = re.compile(
    r"=\s+(?P<types>.*?)\s"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def collective_bytes(hlo_text: str):
    """Per-device bytes moved through each collective kind, from the
    optimized (post-SPMD) HLO.  Proxy = result-shape bytes of each
    collective op ('-done' halves of async pairs are excluded; ring
    all-reduce moves ~2x its payload on the wire — noted in EXPERIMENTS.md
    methodology)."""
    out = {k: 0 for k in _KINDS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if m:
            out[m.group("kind")] += _shapes_bytes(m.group("types"))
    out["total"] = sum(out[k] for k in _KINDS)
    return out


def _xla_cost_dict(compiled) -> dict:
    """`compiled.cost_analysis()` returns a per-partition list of dicts on
    older jax and a plain dict on newer; normalize to one dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rec = dict(arch=arch, shape=shape_name, multi_pod=multi_pod,
               chips=n_chips)
    t0 = time.time()
    with shd.use_mesh(mesh):
        fn, args, kind = ispec.cell_specs(arch, shape_name, mesh)
        lowered = jax.jit(fn).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = dict(
        argument_bytes=int(mem.argument_size_in_bytes),
        output_bytes=int(mem.output_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        alias_bytes=int(mem.alias_size_in_bytes),
        code_bytes=int(mem.generated_code_size_in_bytes))
    per_device_hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                      + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec["memory"]["per_device_total"] = int(per_device_hbm)

    cost = _xla_cost_dict(compiled)
    rec["xla_cost"] = dict(
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_accessed_per_device=float(cost.get("bytes accessed", 0.0)))

    # trip-count-aware walk of the optimized HLO: XLA's cost_analysis
    # counts while bodies once; ours multiplies by the recovered trip
    # counts (launch/hlo_cost.py)
    from repro.launch import hlo_cost
    hlo = compiled.as_text()
    parsed = hlo_cost.analyze(hlo)
    rec["cost"] = dict(flops_per_device=parsed["flops"],
                       bytes_accessed_per_device=parsed["bytes"])
    rec["collectives"] = {k: int(v) for k, v in
                          parsed["collectives"].items()}

    rec["roofline"] = dict(
        compute_s=parsed["flops"] / PEAK_FLOPS,
        memory_s=parsed["bytes"] / HBM_BW,
        collective_s=parsed["collectives"]["total"] / ICI_BW,
    )
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=rec["roofline"].get)
    rec["roofline"]["dominant"] = dom
    rec["kind"] = kind
    return rec


def run_snn(multi_pod: bool, exchange: str = "halo") -> dict:
    """Dry-run the paper's own engine at production scale: one neural
    column per chip (512 columns = 512k neurons, ~102M synapses)."""
    from repro.core import EngineConfig, GridConfig
    from repro.core import distributed as D

    mesh = make_production_mesh(multi_pod=multi_pod)
    n = mesh.size
    flat = dist_compat.make_mesh((n,), ("cells",))
    gx = 32 if multi_pod else 16
    gy = n // gx
    cfg = GridConfig(grid_x=gx, grid_y=gy)
    eng = EngineConfig(n_shards=n, exchange=exchange)

    rec = dict(arch="dpsnn-stdp", shape=f"grid_{gx}x{gy}_{exchange}",
               multi_pod=multi_pod, chips=n, kind="snn")
    t0 = time.time()
    # abstract plan/state: shapes from a single representative shard
    spec, plan1, state1 = _snn_abstract(cfg, eng)
    runner_args, lowered = _snn_lower(spec, flat, plan1, state1)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    mem = compiled.memory_analysis()
    rec["memory"] = dict(
        argument_bytes=int(mem.argument_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes))
    cost = _xla_cost_dict(compiled)
    rec["xla_cost"] = dict(flops_per_device=float(cost.get("flops", 0.0)),
                           bytes_accessed_per_device=float(
                               cost.get("bytes accessed", 0.0)))
    from repro.launch import hlo_cost
    parsed = hlo_cost.analyze(compiled.as_text())
    n_steps = 100  # the lowered scan length; report per-step terms
    rec["cost"] = dict(flops_per_device=parsed["flops"] / n_steps,
                       bytes_accessed_per_device=parsed["bytes"] / n_steps)
    rec["collectives"] = {k: int(v / n_steps) for k, v in
                          parsed["collectives"].items()}
    rec["roofline"] = dict(
        compute_s=rec["cost"]["flops_per_device"] / PEAK_FLOPS,
        memory_s=rec["cost"]["bytes_accessed_per_device"] / HBM_BW,
        collective_s=rec["collectives"]["total"] / ICI_BW)
    rec["roofline"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"),
        key=rec["roofline"].get)
    rec["per_step"] = True
    return rec


def _snn_abstract(cfg, eng):
    """Build ONE shard to get exact static shapes, then build abstract
    stacked plan/state (no 512-shard host build)."""
    from repro.core import connectivity as C

    one = C.build_shard(cfg, eng, 0)
    e_cap = C._round_up(int(one.n_valid * 1.08), 128)
    s_cap = C._round_up(one.src_gid.shape[0], 8)
    n_cap = -(-cfg.n_neurons // eng.n_shards)
    H = eng.n_shards
    D_ = cfg.n_delay_slots

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct((H,) + shape, dtype)

    from repro.core.engine import ShardPlan, ShardState, SimSpec
    from repro.core.params import DEFAULT_IZH, DEFAULT_STDP
    c_cap = 1 if cfg.n_columns <= H else -(-cfg.n_columns // H)
    plan = ShardPlan(
        src_gid=sds((s_cap,), jnp.int32), syn_src=sds((e_cap,), jnp.int32),
        syn_tgt=sds((e_cap,), jnp.int32), syn_delay=sds((e_cap,), jnp.int32),
        syn_plastic=sds((e_cap,), bool), syn_valid=sds((e_cap,), bool),
        exc_mask=sds((n_cap,), bool), neuron_valid=sds((n_cap,), bool),
        gid=sds((n_cap,), jnp.int32), columns=sds((c_cap,), jnp.int32),
        shard_id=sds((), jnp.int32))
    state = ShardState(
        v=sds((n_cap,), jnp.float32), u=sds((n_cap,), jnp.float32),
        last_post=sds((n_cap,), jnp.float32), w=sds((e_cap,), jnp.float32),
        last_arr=sds((e_cap,), jnp.float32),
        arr_ring=sds((D_, e_cap), bool))
    spec = SimSpec(cfg=cfg, eng=eng, izh=DEFAULT_IZH, stdp=DEFAULT_STDP,
                   n_local=n_cap, e_cap=e_cap, s_cap=s_cap,
                   n_total=cfg.n_neurons)
    return spec, plan, state


def _snn_lower(spec, mesh, plan_abs, state_abs):
    from repro.core import distributed as D
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = NamedSharding(mesh, P("cells"))
    plan_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        plan_abs)
    state_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state_abs)

    # mirror distributed.make_run_program (StepProgram's shard_map body)
    # but lower with abstract plan as an ARGUMENT
    from repro.core import engine, stimulus
    spec_ = spec
    stim_k = stimulus.stim_key(spec.cfg)
    H = spec.eng.n_shards
    # halo offsets for a regular grid: derived analytically (3-ring halo)
    offs = _analytic_halo_offsets(spec.cfg, H)

    def shard_body(plan_s, state_s, ts):
        plan_1 = jax.tree.map(lambda x: x[0], plan_s)
        state_1 = jax.tree.map(lambda x: x[0], state_s)
        # loop-invariant: gathered gid table for the allgather exchange
        gid_all = jax.lax.all_gather(plan_1.gid, "cells") \
            if spec.eng.exchange == "allgather" else None

        def step(state, t):
            state, spiked, tm = engine.phase_a(spec_, plan_1, state, t,
                                               stim_k)
            if spec.eng.exchange == "halo":
                spiked_src = D._spiked_src_halo(spec_, offs, plan_1,
                                                spiked)
            else:
                spiked_src = D._spiked_src_allgather(spec_, gid_all,
                                                     spiked,
                                                     plan_1.src_gid)
            state = engine.phase_b(spec_, plan_1, state, spiked_src, t)
            return state, tm.spikes

        state_1, spikes = jax.lax.scan(step, state_1, ts)
        return (jax.tree.map(lambda x: x[None], state_1), spikes[:, None])

    from repro.core.engine import ShardState
    pspec = P("cells")
    plan_specs = jax.tree.map(lambda _: pspec, plan_abs)
    state_specs = ShardState(*([pspec] * len(ShardState._fields)))
    from repro.dist import compat as dist_compat
    smapped = dist_compat.shard_map(
        shard_body, mesh,
        in_specs=(plan_specs, state_specs, P()),
        out_specs=(state_specs, P(None, "cells")))
    ts = jax.ShapeDtypeStruct((100,), jnp.int32)
    lowered = jax.jit(smapped).lower(plan_abs, state_abs, ts)
    return None, lowered


def _analytic_halo_offsets(cfg, H):
    """Static halo offsets for one-column-per-shard regular grids."""
    offs = set()
    gx, gy = cfg.grid_x, cfg.grid_y
    for dy in range(-3, 4):
        for dx in range(-3, 4):
            for cy in (0, gy // 2):
                for cx in (0, gx // 2):
                    c0 = cy * gx + cx
                    c1 = ((cy + dy) % gy) * gx + (cx + dx) % gx
                    offs.add((c0 - c1) % H)
    return sorted(offs)


def save_record(rec: dict):
    os.makedirs(RESULT_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__" \
           f"{'mp' if rec['multi_pod'] else 'sp'}.json"
    with open(os.path.join(RESULT_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--snn", action="store_true")
    ap.add_argument("--snn-exchange", default="halo",
                    choices=["halo", "allgather"])
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    args = ap.parse_args()

    pods = [False, True]
    if args.single_pod_only:
        pods = [False]
    if args.multi_pod_only:
        pods = [True]

    cells = []
    if args.snn:
        for mp in pods:
            rec = run_snn(mp, exchange=args.snn_exchange)
            save_record(rec)
            print(json.dumps(rec))
        return
    if args.all:
        cells = valid_cells()
    else:
        assert args.arch and args.shape, "--arch & --shape, or --all/--snn"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mp in pods:
            tag = f"{arch} x {shape} ({'2x16x16' if mp else '16x16'})"
            try:
                rec = run_cell(arch, shape, mp)
                save_record(rec)
                r = rec["roofline"]
                print(f"[dryrun] OK  {tag}: compile {rec['compile_s']}s "
                      f"mem/dev {rec['memory']['per_device_total']/1e9:.2f}GB "
                      f"terms c={r['compute_s']:.4f}s m={r['memory_s']:.4f}s "
                      f"coll={r['collective_s']:.4f}s dom={r['dominant']}",
                      flush=True)
            except Exception as e:
                failures.append(tag)
                print(f"[dryrun] FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
