"""Simulation-service launcher: multi-tenant SNN serving.

  python -m repro.launch.simserve demo
  python -m repro.launch.simserve soak --tenants 8 --reshard

Thin alias for `python -m repro.simserve` (same CLI), kept under
`repro.launch` so every runnable entry point of the repo lives in one
namespace; see `repro/simserve/cli.py` for the flags.
"""
from __future__ import annotations

import sys

from repro.simserve.cli import main

if __name__ == "__main__":
    sys.exit(main())
