"""Production training launcher.

  python -m repro.launch.train --arch <id> [--smoke] --steps N
      [--batch B --seq T] [--ckpt-dir DIR] [--microbatch M]
      [--compress-grads]

On a real TPU slice this runs under the production mesh with the sharding
rules bound; on CPU (this container) use --smoke for the reduced config.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.data import pipeline
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import schedules
from repro.train import step as step_mod
from repro.train.train_state import create
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--production-mesh", action="store_true",
                    help="bind the 16x16 production mesh (TPU slice)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    sched = schedules.wsd(args.lr, warmup=min(100, args.steps // 10 + 1),
                          stable=args.steps, decay=max(args.steps // 10, 1))
    step = step_mod.make_train_step(cfg, lr_schedule=sched,
                                    microbatch=args.microbatch,
                                    compress_grads=args.compress_grads)

    def build_and_run():
        params = lm.init_params(cfg, jax.random.key(0))
        print(f"[train] {cfg.name}: {lm.param_count(params)/1e6:.1f}M "
              "params")
        state = create(params, use_error_feedback=args.compress_grads)
        tr = Trainer(step, state, ckpt_dir=args.ckpt_dir)
        start = tr.maybe_resume()
        data = iter(pipeline.prefetch(iter(pipeline.Batcher(
            cfg, args.batch, args.seq, seed=1, start_index=start))))
        print(tr.run(data, args.steps - start))

    if args.production_mesh:
        mesh = make_production_mesh()
        with shd.use_mesh(mesh):
            build_and_run()
    else:
        build_and_run()


if __name__ == "__main__":
    main()
