"""Mesh constructors — moved to `repro.dist.mesh`; re-exported here so
launch scripts and tests keep a stable import path."""
from __future__ import annotations

from repro.dist.mesh import make_production_mesh, make_snn_mesh

__all__ = ["make_production_mesh", "make_snn_mesh"]
