"""Trip-count-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, so any
program built around `lax.scan` (our layer stack, blockwise attention,
recurrent mixers) under-reports FLOPs / bytes / collective traffic by the
trip count.  This module re-walks the optimized HLO text:

  - splits it into named computations,
  - finds `while` ops and recovers trip counts from the loop-condition
    `compare(iv, constant)` pattern,
  - attributes dot/convolution FLOPs, collective payload bytes, and a
    bytes-touched proxy to each computation,
  - recursively accumulates callee costs (fusion/call/while/conditional),
    multiplying while bodies by their trip counts.

The bytes proxy counts operand + result sizes of *materializing* ops
(fusion results, dots, copies, collectives, dynamic-slice/update) — i.e.
HBM traffic at fusion granularity, which is what the memory roofline term
wants.  Everything is per-device (the SPMD module is the per-device
program).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"\b(\w+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_OP_RE = re.compile(r"=\s*((?:\([^)]*\)|[\w\[\]{}, ])*?)\s*([\w\-]+)\(")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose results actually hit HBM in scheduled HLO (reshape/bitcast/
# broadcast/iota are layout-free or fused and excluded from the proxy)
_MATERIALIZING = ("fusion", "dot", "convolution", "copy", "dynamic-slice",
                  "dynamic-update-slice", "gather", "scatter", "reduce",
                  "sort", "concatenate", "select-and-scatter",
                  "custom-call") + _COLLECTIVES


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _sig_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """Header lines end with '{' and start with '%name' or 'ENTRY %name';
    parameter lists may contain arbitrarily nested tuple types, so the name
    is simply the first token up to whitespace/'('."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    body: List[str] = []
    for line in hlo.splitlines():
        st = line.strip()
        if cur is None:
            if st.endswith("{") and (st.startswith("%")
                                     or st.startswith("ENTRY")):
                tok = st.split()[1] if st.startswith("ENTRY") else \
                    st.split()[0]
                name = tok.lstrip("%").split("(")[0].rstrip()
                cur = name
                body = []
        else:
            if st == "}":
                comps[cur] = body
                cur = None
            else:
                body.append(st)
    return comps


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\w+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _symbols(body: List[str]) -> Dict[str, Tuple[str, List[int]]]:
    """instruction name -> (dtype, result dims) (array results only)."""
    syms = {}
    for line in body:
        m = _DEF_RE.match(line)
        if m:
            syms[m.group(1)] = (m.group(2),
                                [int(d) for d in m.group(3).split(",")
                                 if d])
    return syms


def _operand_bytes(line: str, op: str, syms) -> int:
    inside = line.split(op + "(", 1)[1].split(")")[0]
    total = 0
    for name in _OPERAND_RE.findall(inside):
        ent = syms.get(name)
        if ent:
            dt, dims = ent
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _dot_flops(line: str, syms: Dict[str, List[int]]) -> float:
    """2 * prod(result_dims) * prod(lhs contracting dims).

    Optimized HLO prints dot operands without inline types; shapes are
    resolved through the computation's symbol table."""
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    res_elems = _shape_elems(m.group(3))
    inside = line.split("dot(", 1)[1].split(")")[0]
    ops = _OPERAND_RE.findall(inside)
    lhs_dims = syms.get(ops[0], ("f32", []))[1] if ops else []
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    k = 1
    if mc and lhs_dims:
        for idx in mc.group(1).split(","):
            if idx:
                k *= lhs_dims[int(idx)]
    return 2.0 * res_elems * k


def _trip_count(cond_body: List[str]) -> int:
    """Loop conditions compare the induction variable against a constant."""
    consts = {}
    for line in cond_body:
        m = re.match(r"%?([\w\.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)",
                     line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_body:
        if "compare(" in line:
            inside = line.split("compare(", 1)[1]
            for name, val in consts.items():
                if name in inside:
                    return max(val, 1)
    # fallback: largest scalar constant in the condition
    return max(consts.values(), default=1)


class HloCost:
    def __init__(self, hlo: str):
        self.comps = split_computations(hlo)
        self.entry = self._find_entry(hlo)
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def _find_entry(self, hlo: str) -> str:
        for line in hlo.splitlines():
            st = line.strip()
            if st.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w\.\-]+)", st)
                if m:
                    return m.group(1)
        return next(iter(self.comps))

    def cost(self, comp: Optional[str] = None):
        """(flops, bytes, {collective_kind: bytes}) per device, recursive."""
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = (0.0, 0.0, {})      # cycle guard
        flops = 0.0
        nbytes = 0.0
        coll: Dict[str, float] = {}
        body = self.comps.get(comp, [])
        syms = _symbols(body)
        for line in body:
            mo = _OP_RE.search(line)
            if not mo:
                continue
            sig, op = mo.groups()
            if op == "dot":
                flops += _dot_flops(line, syms)
                nbytes += _sig_bytes(line.split("dot(")[0]) \
                    + _operand_bytes(line, "dot", syms)
            elif op in _COLLECTIVES or (op.endswith("-start")
                                        and op[:-6] in _COLLECTIVES):
                kind = op[:-6] if op.endswith("-start") else op
                b = _sig_bytes(sig)
                coll[kind] = coll.get(kind, 0.0) + b
                nbytes += b
            elif op == "while":
                mb = _CALL_ATTR.search(line)
                mc = _COND_ATTR.search(line)
                if mb:
                    trips = _trip_count(self.comps.get(
                        mc.group(1), [])) if mc else 1
                    f2, b2, c2 = self.cost(mb.group(1))
                    flops += f2 * trips
                    nbytes += b2 * trips
                    for k, v in c2.items():
                        coll[k] = coll.get(k, 0.0) + v * trips
            elif op in ("fusion", "call", "conditional", "custom-call",
                        "reduce", "sort", "scatter", "map",
                        "select-and-scatter", "async-start"):
                callee_bytes = 0.0
                for callee in _CALL_ATTR.findall(line):
                    f2, b2, c2 = self.cost(callee)
                    flops += f2
                    nbytes += b2
                    callee_bytes += b2
                    for k, v in c2.items():
                        coll[k] = coll.get(k, 0.0) + v
                if op in ("fusion", "custom-call", "reduce", "scatter",
                          "sort") and callee_bytes == 0.0:
                    # pure-elementwise fusion: traffic happens at the
                    # fusion boundary (result + operands).  Fusions that
                    # self-account internally (dynamic-update-slice /
                    # dynamic-slice / gather / dot inside) already counted
                    # the true slice-level traffic — adding the full
                    # in-place-aliased buffers here would overcount ~30x.
                    nbytes += _sig_bytes(sig) \
                        + _operand_bytes(line, op, syms)
            elif op in ("dynamic-slice", "gather"):
                # reads only result-size worth of the (possibly huge)
                # operand: read + write = 2x result
                nbytes += 2 * _sig_bytes(sig)
            elif op == "dynamic-update-slice":
                # in-place: reads + writes only the update slice
                inside = line.split(op + "(", 1)[1].split(")")[0]
                ops_ = _OPERAND_RE.findall(inside)
                upd = syms.get(ops_[1]) if len(ops_) > 1 else None
                if upd:
                    dt, dims = upd
                    n = 1
                    for d in dims:
                        n *= d
                    nbytes += 2 * n * _DTYPE_BYTES.get(dt, 4)
            elif op in _MATERIALIZING:
                nbytes += _sig_bytes(sig) + _operand_bytes(line, op, syms)
        out = (flops, nbytes, coll)
        self._memo[comp] = out
        return out


def analyze(hlo_text: str) -> dict:
    hc = HloCost(hlo_text)
    flops, nbytes, coll = hc.cost()
    coll_total = sum(coll.values())
    return dict(flops=flops, bytes=nbytes,
                collectives={**coll, "total": coll_total})
