"""Entry points: train, dryrun, snn, serve, simserve (run via
`python -m`).

No launcher is imported eagerly — several set environment variables that
must precede jax initialization when run as scripts.
"""
