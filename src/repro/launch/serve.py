"""Serving launcher: batched requests against a (smoke or full) config.

  python -m repro.launch.serve --arch <id> --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=128)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    params = lm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch=args.batch, s_max=args.s_max)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(4, 24))).astype(np.int32),
        max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    done = eng.run(reqs)
    wall = time.time() - t0
    n = sum(r.out.shape[0] for r in done)
    print(f"[serve] {len(done)} requests, {n} tokens, {n/wall:.1f} tok/s")


if __name__ == "__main__":
    main()
