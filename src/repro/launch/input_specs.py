"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation: params / optimizer state / caches / batches are all
abstract, with NamedShardings attached, so `jit(step).lower(**specs)` and
`.compile()` exercise the full production partitioning on placeholder
devices."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs import get_config, shape_by_name
from ..configs.base import ModelConfig, ShapeConfig
from ..dist import sharding as shd
from ..models import lm
from ..optim import adamw
from ..train import step as step_mod
from ..train.train_state import TrainState


def _abstract(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def abstract_params(cfg: ModelConfig, mesh):
    shapes = jax.eval_shape(
        lambda k: lm.init_params(cfg, k), jax.random.key(0))
    shardings = shd.tree_shardings(shapes, mesh, shd.infer_param_spec)
    return _abstract(shapes, shardings)


def abstract_train_state(cfg: ModelConfig, mesh) -> TrainState:
    params = abstract_params(cfg, mesh)

    def like_f32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                    sharding=p.sharding)

    m = jax.tree.map(like_f32, params)
    v = jax.tree.map(like_f32, params)
    step = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=shd.NamedSharding(mesh, shd.P()))
    return TrainState(params=params,
                      opt=adamw.AdamWState(step=step, m=m, v=v),
                      step=step, ef_residual=None)


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig, mesh
                   ) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}

    def tok(name, b, t):
        out[name] = jax.ShapeDtypeStruct(
            (b, t), jnp.int32, sharding=shd.NamedSharding(
                mesh, shd.infer_batch_spec(name, (b, t), mesh)))

    def emb(name, b, t):
        out[name] = jax.ShapeDtypeStruct(
            (b, t, cfg.d_model), jnp.bfloat16, sharding=shd.NamedSharding(
                mesh, shd.infer_batch_spec(name, (b, t, cfg.d_model),
                                           mesh)))

    if cfg.modality == "vlm":
        emb("embeds", B, T)
        tok("tokens", B, T)       # labels path still needs token ids
    else:
        tok("tokens", B, T)
    if cfg.family == "encdec":
        emb("enc_embeds", B, max(T // 2, 8))
    tok("labels", B, T)
    return out


def abstract_cache(cfg: ModelConfig, batch: int, s_max: int, mesh):
    shapes = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, batch, s_max))
    shardings = shd.tree_shardings(shapes, mesh, shd.infer_cache_spec)
    return _abstract(shapes, shardings)


# ---------------------------------------------------------------------------
# the three step kinds
# ---------------------------------------------------------------------------


def make_train_fn(cfg: ModelConfig, global_batch: int = 256):
    from ..optim import schedules
    # wide models accumulate gradients over 4 microbatches: activation
    # memory scales with the microbatch while the optimizer math is
    # unchanged (verified vs full-batch in tests/test_substrate.py)
    micro = global_batch // 4 if (cfg.d_model >= 2304 or cfg.moe
                                  or cfg.family == "encdec") else None
    return step_mod.make_train_step(
        cfg, lr_schedule=schedules.wsd(3e-4, 100, 10_000, 1_000),
        grad_clip=1.0, microbatch=micro)


def make_prefill_fn(cfg: ModelConfig):
    def prefill(params, cache, batch):
        """Process the whole prompt, fill caches, return last-token logits
        (full-sequence logits are never materialized)."""
        logits, cache = lm.prefill(cfg, params, cache, batch)
        return logits, cache
    return prefill


def make_decode_fn(cfg: ModelConfig):
    def decode(params, cache, tokens):
        return lm.decode_step(cfg, params, cache, tokens)
    return decode


def cell_specs(arch: str, shape_name: str, mesh) -> Tuple[Any, tuple, str]:
    """Returns (fn, arg_specs, kind) for one dry-run cell."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    B, T = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        state = abstract_train_state(cfg, mesh)
        batch = abstract_batch(cfg, shape, mesh)
        return make_train_fn(cfg, B), (state, batch), "train"

    if shape.kind == "prefill":
        params = abstract_params(cfg, mesh)
        cache = abstract_cache(cfg, B, T, mesh)
        batch = abstract_batch(cfg, shape, mesh)
        batch.pop("labels")
        return make_prefill_fn(cfg), (params, cache, batch), "prefill"

    # decode: one new token against a seq_len-deep cache
    params = abstract_params(cfg, mesh)
    cache = abstract_cache(cfg, B, T, mesh)
    tokens = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32, sharding=shd.NamedSharding(
            mesh, shd.infer_batch_spec("tokens", (B, 1), mesh)))
    return make_decode_fn(cfg), (params, cache, tokens), "decode"
