"""DPSNN simulation launcher (the paper's workload).

  python -m repro.launch.snn --grid 4x4 --steps 500 [--shards 4]
      [--exchange halo|allgather] [--placement block|scatter]
      [--ckpt-dir DIR]

With --shards > 1 this process must be started with
XLA_FLAGS=--xla_force_host_platform_device_count=<H> (or run on a real
multi-device platform).
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.core import (EngineConfig, GridConfig, build, checkpoint,
                        observables, run)
from repro.core import distributed as D


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", default="2x2")
    ap.add_argument("--neurons-per-column", type=int, default=1000)
    ap.add_argument("--synapses", type=int, default=200)
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--exchange", default="allgather",
                    choices=["allgather", "halo"])
    ap.add_argument("--delivery", default="dense",
                    choices=["dense", "event"])
    ap.add_argument("--placement", default="block",
                    choices=["block", "scatter"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    gx, gy = (int(v) for v in args.grid.split("x"))
    cfg = GridConfig(grid_x=gx, grid_y=gy,
                     neurons_per_column=args.neurons_per_column,
                     synapses_per_neuron=args.synapses)
    eng = EngineConfig(n_shards=args.shards, exchange=args.exchange,
                       placement=args.placement, delivery=args.delivery)
    print(f"[snn] {cfg.n_neurons} neurons / {cfg.n_synapses} synapses on "
          f"{args.shards} shards ({args.exchange}, {args.placement})")

    if args.delivery == "event":
        assert args.shards == 1, "event backend: single-process CLI path"
        from repro.core import event_engine as EV
        import jax as _jax
        spec, plan, eplan, estate = EV.build(cfg, eng)
        estate, raster = _jax.jit(
            lambda s: EV.run(spec, plan, eplan, s, 0, args.steps))(estate)
        rate = observables.mean_rate_hz(np.asarray(raster), cfg.n_neurons)
        print(f"[snn] (event backend) rate {rate:.1f} Hz, saturated "
              f"{int(np.asarray(estate.sat).sum())}")
        return

    spec, plan, state = build(cfg, eng)
    t0 = 0
    if args.ckpt_dir:
        latest = checkpoint.latest(args.ckpt_dir)
        if latest:
            state, t0 = checkpoint.load(latest, spec, plan)
            print(f"[snn] resumed at t={t0} from {latest}")

    if args.shards > 1:
        assert len(jax.devices()) >= args.shards, \
            "set XLA_FLAGS=--xla_force_host_platform_device_count"
        mesh = D.make_mesh(args.shards)
        plan_d = D.shard_put(mesh, plan)
        state_d = D.shard_put(mesh, state)
        runner = D.make_sharded_run(spec, plan_d, mesh)
        chunk = args.ckpt_every or args.steps
        t = t0
        while t < t0 + args.steps:
            n = min(chunk, t0 + args.steps - t)
            state_d, raster, tm = runner(state_d, t, n)
            t += n
            if args.ckpt_dir:
                checkpoint.save(os.path.join(args.ckpt_dir,
                                             f"ckpt_{t}.npz"),
                                spec, plan,
                                jax.tree.map(np.asarray, state_d), t)
        state, raster = state_d, raster
    else:
        chunk = args.ckpt_every or args.steps
        t = t0
        while t < t0 + args.steps:
            n = min(chunk, t0 + args.steps - t)
            state, raster, tm = run(spec, plan, state, t, n)
            t += n
            if args.ckpt_dir:
                checkpoint.save(os.path.join(args.ckpt_dir,
                                             f"ckpt_{t}.npz"),
                                spec, plan, state, t)

    rate = observables.mean_rate_hz(np.asarray(raster), cfg.n_neurons)
    print(f"[snn] final-window rate {rate:.1f} Hz; done at t={t} ms")


if __name__ == "__main__":
    main()
