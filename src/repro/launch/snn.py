"""DPSNN simulation launcher (the paper's workload).

  python -m repro.launch.snn --grid 4x4 --steps 500 [--shards 4]
      [--exchange halo|allgather|hier] [--exchange-schedule sync|pipelined]
      [--placement block|scatter] [--delivery dense|event]
      [--profile ring3|gaussian:sigma=1.5|...] [--ckpt-dir DIR]

`--delivery event` runs the paper's event-driven synaptic formulation
(O(spikes x fan-out) per step) instead of the dense O(E) masked one; both
support every layout knob — shard counts, exchange modes, placements,
cluster jobs, checkpointing.

With --shards > 1 this process must be started with
XLA_FLAGS=--xla_force_host_platform_device_count=<H> (or run on a real
multi-device platform).  Under `repro.cluster.local` (the REPRO_CLUSTER_*
env variables set), the same launcher becomes one worker of a
multi-process job: `--shards` then counts GLOBAL shards across all
processes, rasters are gathered for the rate report, and only process 0
writes checkpoints.
"""
from __future__ import annotations

import argparse
import os

from repro.cluster import runtime as cluster_runtime

# Joining a cluster job must precede ANY jax computation — repro.core
# builds module-level constants (engine.NEG_TIME) at import.  No-op
# outside a cluster job (REPRO_CLUSTER_* absent).
cluster_runtime.ensure_initialized()

import jax
import numpy as np

from repro.core import (EngineConfig, GridConfig, StepProgram, checkpoint,
                        observables, profiles)
from repro.core import distributed as D


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", default="2x2")
    ap.add_argument("--neurons-per-column", type=int, default=1000)
    ap.add_argument("--synapses", type=int, default=200)
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--exchange", default="allgather",
                    choices=["allgather", "halo", "hier"])
    ap.add_argument("--exchange-schedule", default="sync",
                    choices=["sync", "pipelined"],
                    help="'pipelined' issues the spike exchange before the "
                         "LTP half of phase A and delivers one loop "
                         "iteration later (bit-identical outputs)")
    ap.add_argument("--delivery", default="dense",
                    choices=["dense", "event"])
    ap.add_argument("--placement", default="block",
                    choices=["block", "scatter"])
    ap.add_argument("--profile", default="ring3",
                    help="lateral-connectivity profile spec "
                         "(repro.core.profiles): ring3 | ringN | "
                         "ring:max_ring=N | gaussian:sigma=S | "
                         "exponential:lambda=L")
    ap.add_argument("--connectivity-mode", default="materialized",
                    help="synapse-table residency: 'materialized' (full "
                         "tables live) or 'streamed:chunk=K' (regenerate "
                         "per-chunk tables inside the step; O(chunk) live "
                         "bytes, bit-identical rasters AND weights; "
                         "requires --delivery dense)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    gx, gy = (int(v) for v in args.grid.split("x"))
    cfg = GridConfig(grid_x=gx, grid_y=gy,
                     neurons_per_column=args.neurons_per_column,
                     synapses_per_neuron=args.synapses,
                     connectivity=args.profile)
    eng = EngineConfig(n_shards=args.shards, exchange=args.exchange,
                       exchange_schedule=args.exchange_schedule,
                       placement=args.placement, delivery=args.delivery,
                       connectivity=args.connectivity_mode)
    prof = profiles.from_config(cfg)       # fail fast on a bad spec
    if cluster_runtime.is_primary():
        procs = (f", {jax.process_count()} processes"
                 if cluster_runtime.is_distributed() else "")
        print(f"[snn] {cfg.n_neurons} neurons / {cfg.n_synapses} synapses "
              f"on {args.shards} shards ({args.exchange}, "
              f"{args.placement}, {prof.spec()} reach={prof.reach()}"
              f"{procs})")

    # Build: one StepProgram per process covers both delivery backends,
    # every exchange wire and both schedules; the run loop, checkpoint,
    # sharding and cluster gather are backend-generic from here on.
    event = args.delivery == "event"
    sharded = args.shards > 1
    if sharded:
        # jax.devices() is global: across every process of a cluster job
        assert len(jax.devices()) >= args.shards, \
            "set XLA_FLAGS=--xla_force_host_platform_device_count " \
            "or launch more processes (repro.cluster.local)"
    sp = StepProgram(cfg, eng,
                     mesh=D.make_mesh(args.shards) if sharded else None)
    spec, plan, state = sp.spec, sp.plan, sp.init_state()
    t0 = 0
    if args.ckpt_dir:
        latest = checkpoint.latest(args.ckpt_dir)
        if latest:
            state, t0 = sp.load(latest)
            if cluster_runtime.is_primary():
                print(f"[snn] resumed at t={t0} from {latest}")

    if sharded:
        state_d = sp.place(state)
        chunk = args.ckpt_every or args.steps
        t = t0
        while t < t0 + args.steps:
            n = min(chunk, t0 + args.steps - t)
            state_d, raster, tm = sp.run(state_d, t, n)
            t += n
            if args.ckpt_dir:
                # gather is a collective (all processes), the write is not
                state_h = cluster_runtime.gather(state_d)
                if cluster_runtime.is_primary():
                    checkpoint.save(os.path.join(args.ckpt_dir,
                                                 f"ckpt_{t}.npz"),
                                    spec, plan, state_h, t)
        state, raster = state_d, raster
    else:
        chunk = args.ckpt_every or args.steps
        t = t0
        while t < t0 + args.steps:
            n = min(chunk, t0 + args.steps - t)
            state, raster, tm = sp.run(state, t, n)
            t += n
            # primary-only for the same reason as the sharded branch: a
            # cluster job with --shards 1 runs one replica per process,
            # and they must not race on the checkpoint path
            if args.ckpt_dir and cluster_runtime.is_primary():
                checkpoint.save(os.path.join(args.ckpt_dir,
                                             f"ckpt_{t}.npz"),
                                spec, plan, state, t)

    raster_h = cluster_runtime.gather(raster)
    rate = observables.mean_rate_hz(np.asarray(raster_h), cfg.n_neurons)
    sat = None
    if event:
        # sharded state spans processes -> gather assembles each global
        # shard once (a collective; every process participates).  In the
        # replica case (--shards 1, one copy per process) every replica
        # holds the identical counter, and gathering would stack P copies
        # and over-count the sum P-fold — read it locally instead.
        sat_arr = cluster_runtime.gather(state.sat) if args.shards > 1 \
            else state.sat
        sat = int(np.asarray(sat_arr).sum())
    if cluster_runtime.is_primary():
        tail = f", saturated {sat}" if event else ""
        print(f"[snn] final-window rate {rate:.1f} Hz; done at t={t} ms"
              f"{tail}")


if __name__ == "__main__":
    main()
