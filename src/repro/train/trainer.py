"""Fault-tolerant training loop: resume-from-latest, periodic atomic
checkpoints, NaN-loss guard, and a simple preemption hook.

Straggler note: under SPMD there is no per-step straggler drift to mitigate
in-band (the collective is the barrier, as in the SNN engine); the
mitigations that matter are (a) restart-from-checkpoint on node loss and
(b) the elastic reshard (core.checkpoint / train_state are layout-free)."""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from . import train_state as ts_mod
from .train_state import TrainState


class Trainer:
    def __init__(self, step_fn: Callable, state: TrainState,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
                 log_every: int = 10, log_fn=print):
        self.step_fn = jax.jit(step_fn)
        self.state = state
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self.history = []

    def maybe_resume(self) -> int:
        if not self.ckpt_dir:
            return 0
        path = ts_mod.latest(self.ckpt_dir)
        if path:
            self.state = ts_mod.load(path, self.state)
            self.log(f"[trainer] resumed from {path} "
                     f"(step {int(self.state.step)})")
        return int(self.state.step)

    def checkpoint(self):
        if not self.ckpt_dir:
            return
        step = int(self.state.step)
        path = os.path.join(self.ckpt_dir, f"lm_{step}.npz")
        ts_mod.save(path, self.state)

    def run(self, data: Iterator, n_steps: int) -> Dict:
        t0 = time.time()
        last = t0
        for i in range(n_steps):
            batch = next(data)
            self.state, metrics = self.step_fn(self.state, batch)
            step = int(self.state.step)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                # NaN guard: restore last checkpoint rather than corrupting
                self.log(f"[trainer] non-finite loss at step {step}; "
                         "restoring last checkpoint")
                resumed = self.maybe_resume()
                if resumed == 0:
                    raise FloatingPointError("non-finite loss, no ckpt")
                continue
            self.history.append(loss)
            if step % self.log_every == 0:
                now = time.time()
                self.log(f"[trainer] step {step} loss {loss:.4f} "
                         f"({(now - last):.2f}s)")
                last = now
            if self.ckpt_every and step % self.ckpt_every == 0:
                self.checkpoint()
        self.checkpoint()
        return {"steps": int(self.state.step),
                "final_loss": self.history[-1] if self.history else None,
                "wall_s": time.time() - t0}
