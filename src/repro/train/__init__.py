from . import step, trainer, train_state

__all__ = ["step", "trainer", "train_state"]
