"""Train state + layout-free LM checkpointing (same crash-safe atomic-rename
discipline as the SNN engine's core.checkpoint)."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jnp.ndarray          # [] int32 (global step, == opt.step)
    ef_residual: Optional[Any] = None   # error-feedback buffer (optional)


def create(params, use_error_feedback: bool = False) -> TrainState:
    from ..optim import grad_utils
    ef = grad_utils.init_error_feedback(params) if use_error_feedback \
        else None
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32), ef_residual=ef)


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, state: TrainState, extra: Optional[dict] = None) -> str:
    leaves, _ = _flatten(state)
    payload, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        dtypes.append(str(a.dtype))
        if a.dtype.kind not in "fiub" or str(a.dtype) == "bfloat16":
            a = a.view(np.uint16) if a.itemsize == 2 else a.view(np.uint8)
        payload[f"leaf_{i}"] = a
    meta = dict(n_leaves=len(leaves), step=int(state.step),
                dtypes=dtypes, extra=extra or {})
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, meta=json.dumps(meta), **payload)
    os.replace(tmp, path)
    return path


def load(path: str, template: TrainState) -> TrainState:
    import ml_dtypes
    z = np.load(path, allow_pickle=False)
    meta = json.loads(str(z["meta"]))
    leaves, treedef = _flatten(template)
    new = []
    for i in range(len(leaves)):
        a = z[f"leaf_{i}"]
        want = meta["dtypes"][i]
        if str(a.dtype) != want:
            a = a.view(np.dtype(getattr(ml_dtypes, want, want)))
        new.append(jnp.asarray(a))
    for a, b in zip(leaves, new):
        assert a.shape == b.shape, (a.shape, b.shape)
    return jax.tree.unflatten(treedef, new)


def latest(directory: str, prefix: str = "lm_") -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    cands = [f for f in os.listdir(directory)
             if f.startswith(prefix) and f.endswith(".npz")]
    if not cands:
        return None
    return os.path.join(directory,
                        max(cands, key=lambda f: int(f[len(prefix):-4])))
