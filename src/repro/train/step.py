"""Train-step factory: loss -> grad -> (clip, compress) -> AdamW, with
optional gradient (micro-batch) accumulation and remat policy.

The returned step is a single jit-able function suitable both for real
execution and for the multi-pod dry-run (lower/compile on ShapeDtypeStructs).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import lm
from ..optim import adamw, grad_utils
from .train_state import TrainState


def make_loss_fn(cfg: ModelConfig, remat: bool = True):
    # Remat lives at the right granularities already: per scanned unit
    # (transformer.apply_stack) and per blockwise-attention call
    # (models.attention).  An extra whole-forward checkpoint here would
    # force a full duplicate recompute for zero memory win.
    del remat

    def loss(params, batch):
        l, metrics = lm.loss_fn(cfg, params, batch)
        return l, metrics

    return loss


def make_train_step(cfg: ModelConfig, *, lr_schedule: Callable,
                    grad_clip: float = 1.0, weight_decay: float = 0.1,
                    microbatch: Optional[int] = None,
                    compress_grads: bool = False, remat: bool = True):
    """Returns step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(cfg, remat)

    def grads_of(params, batch):
        (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return l, metrics, grads

    def accumulate(params, batch):
        """Split the global batch into microbatches, averaging grads
        sequentially (activation-memory bound -> compute-bound trade)."""
        n = microbatch
        B = batch["labels"].shape[0]
        assert B % n == 0, (B, n)
        k = B // n

        def mb(i):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, i * n, n, 0),
                batch)

        def body(i, carry):
            acc, lsum = carry
            l, _, g = grads_of(params, mb(i))
            acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / k, acc, g)
            return acc, lsum + l / k

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        grads, l = jax.lax.fori_loop(0, k, body, (zeros, jnp.float32(0.0)))
        return l, {"xent": l, "aux": jnp.float32(0.0)}, grads

    def step(state: TrainState, batch) -> tuple:
        if microbatch:
            l, metrics, grads = accumulate(state.params, batch)
        else:
            l, metrics, grads = grads_of(state.params, batch)

        grads, gnorm = grad_utils.clip_by_global_norm(grads, grad_clip)
        ef = state.ef_residual
        if compress_grads and ef is not None:
            grads, ef = grad_utils.compress_with_feedback(grads, ef)
        new_params, opt = adamw.update(grads, state.opt, state.params,
                                       lr=lr_schedule,
                                       weight_decay=weight_decay)
        new_state = TrainState(params=new_params, opt=opt,
                               step=state.step + 1, ef_residual=ef)
        metrics = dict(metrics, loss=l, grad_norm=gnorm,
                       lr=lr_schedule(opt.step))
        return new_state, metrics

    return step
