"""Sharded, prefetching input pipeline.

Batches are produced host-side (numpy, deterministic per batch_index),
device_put with the activation sharding, and prefetched one step ahead on a
background thread so host generation overlaps device compute."""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import jax
import numpy as np

from ..configs.base import ModelConfig
from . import synthetic


class Batcher:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, sharding=None, start_index: int = 0):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed = seed
        self.index = start_index           # restart-safe: index is state
        self.sharding = sharding

    def make(self, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        toks = synthetic.batch_tokens(self.seed, index, self.batch,
                                      self.seq, cfg.vocab_size)
        b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.modality in ("vlm",):
            b["embeds"] = synthetic.batch_embeds(self.seed, index,
                                                 self.batch, self.seq,
                                                 cfg.d_model)
        if cfg.family == "encdec":
            b["enc_embeds"] = synthetic.batch_embeds(
                self.seed, index, self.batch, max(self.seq // 2, 8),
                cfg.d_model)
        return b

    def put(self, b):
        if self.sharding is None:
            return jax.tree.map(jax.numpy.asarray, b)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), b,
            {k: self.sharding.get(k) for k in b} if isinstance(
                self.sharding, dict) else
            {k: self.sharding for k in b})

    def __iter__(self) -> Iterator:
        while True:
            b = self.put(self.make(self.index))
            self.index += 1
            yield b


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for x in it:
                q.put(x)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        x = q.get()
        if x is stop:
            return
        yield x
