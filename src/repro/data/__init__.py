from . import pipeline, synthetic

__all__ = ["pipeline", "synthetic"]
