"""Deterministic synthetic data: a counter-based token stream (same
construction idea as the SNN connectivity — any worker can materialize any
batch index without coordination, which is what makes the input pipeline
trivially elastic/restartable).

The stream is a Zipf-ish unigram mixture with short-range Markov structure,
so cross-entropy has learnable signal (quickstart trains visibly below the
unigram entropy) while requiring no external data."""
from __future__ import annotations

import numpy as np


def batch_tokens(seed: int, batch_index: int, batch: int, seq: int,
                 vocab: int) -> np.ndarray:
    """[batch, seq+1] int32; column t+1 is the label for column t."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, batch_index]))
    # Zipf unigram over a small active vocab (keeps tiny smokes learnable)
    active = min(vocab, 4096)
    ranks = np.arange(1, active + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(active, size=(batch, seq + 1), p=probs)
    # Markov bigram structure: with p=0.5, next token = f(prev)
    follow = (np.arange(active) * 31 + 7) % active
    mask = rng.random((batch, seq)) < 0.5
    for t in range(seq):
        nxt = follow[toks[:, t]]
        toks[:, t + 1] = np.where(mask[:, t], nxt, toks[:, t + 1])
    return toks.astype(np.int32)


def batch_embeds(seed: int, batch_index: int, batch: int, seq: int,
                 d_model: int) -> np.ndarray:
    """Frontend-stub embeddings for vlm/audio modalities ([B, T, d])."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, batch_index,
                                                        7]))
    return rng.standard_normal((batch, seq, d_model), dtype=np.float32)
