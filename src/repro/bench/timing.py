"""Honest wall-clock measurement for jit-compiled functions.

All timings follow the same discipline:

  1. one untimed warmup call (pays compilation + first-touch transfers),
  2. `jax.block_until_ready` on the result inside every timed region
     (async dispatch otherwise returns before the device finishes),
  3. median of k repetitions with the (max - min) / median spread, so a
     single preempted rep cannot masquerade as a regression.

The paper's normalized metric — wall seconds per synapse per simulated
second per Hz of activity (Table 1's size-independence check) — lives here
too so every suite computes it the same way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class Timing:
    """Median-of-k wall-clock sample (seconds)."""

    reps_s: tuple

    @property
    def median_s(self) -> float:
        xs = sorted(self.reps_s)
        n = len(xs)
        mid = n // 2
        return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])

    @property
    def min_s(self) -> float:
        return min(self.reps_s)

    @property
    def max_s(self) -> float:
        return max(self.reps_s)

    @property
    def spread(self) -> float:
        """(max - min) / median — jitter indicator, not a metric to gate on."""
        m = self.median_s
        return (self.max_s - self.min_s) / m if m > 0 else 0.0


def time_fn(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> Timing:
    """Time `fn(*args)` honestly: warmup runs (compile), then `reps` timed
    calls, each blocked on its full output tree."""
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return Timing(reps_s=tuple(samples))


class Timer:
    """`with Timer() as t: ...` then `t.s` — single-shot wall clock."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0


def steps_per_sec(wall_s: float, n_steps: int) -> float:
    return n_steps / wall_s if wall_s > 0 else 0.0


def norm_seconds(wall_s: float, n_synapses: int, n_steps: int,
                 rate_hz: float, dt_ms: float = 1.0) -> float:
    """The paper's Table 1 metric: wall seconds per synapse per simulated
    second, divided by the mean firing rate (size-independent when the
    engine scales linearly in synaptic events)."""
    sim_seconds = n_steps * dt_ms / 1000.0
    return wall_s / (n_synapses * sim_seconds * max(rate_hz, 1e-9))


def summarize(samples: Sequence[float]) -> dict:
    """Round-tripable dict view of a list of per-rep seconds."""
    t = Timing(reps_s=tuple(samples))
    return dict(median_s=round(t.median_s, 6), min_s=round(t.min_s, 6),
                max_s=round(t.max_s, 6), spread=round(t.spread, 4),
                reps=len(samples))
