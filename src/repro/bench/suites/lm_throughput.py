"""LM-side micro-benchmarks: train tokens/s and decode tokens/s on CPU for
a reduced config (the framework half of the system; TPU projections come
from the roofline, not from CPU wall-time).  Wall-clock only — the final
loss is floating-point and version-sensitive, so it is reported but not
gated."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import pipeline
from repro.models import lm
from repro.optim import schedules
from repro.train import step as step_mod
from repro.train.train_state import create
from .. import report as R
from .. import timing


def bench(arch: str = "qwen3-0.6b", steps: int = 10, batch: int = 8,
          seq: int = 128, quick: bool = False):
    if quick:
        steps, batch, seq = 5, 4, 64
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    state = create(params)
    step = jax.jit(step_mod.make_train_step(
        cfg, lr_schedule=schedules.cosine(3e-4, 10, 1000)))
    data = iter(pipeline.Batcher(cfg, batch, seq, seed=1))

    b = next(data)
    state, m = step(state, b)                   # compile
    jax.block_until_ready(m["loss"])
    with timing.Timer() as tw:
        for _ in range(steps):
            state, m = step(state, next(data))
        jax.block_until_ready(m["loss"])
    row = dict(kind="train", arch=arch, steps=steps,
               tokens_per_s=int(steps * batch * seq / tw.s),
               wall_s=round(tw.s, 2), final_loss=round(float(m["loss"]), 3))
    print("[lm]", json.dumps(row), flush=True)

    # decode throughput
    cache = lm.init_cache(cfg, batch, 64)
    dstep = jax.jit(lambda c, t: lm.decode_step(cfg, params, c, t))
    tok = jnp.ones((batch, 1), jnp.int32)
    _, cache = dstep(cache, tok)               # compile
    n = 20 if quick else 50
    with timing.Timer() as td:
        for _ in range(n):
            lg, cache = dstep(cache, tok)
            tok = lg.argmax(-1).astype(jnp.int32)
        jax.block_until_ready(tok)
    row2 = dict(kind="decode", arch=arch,
                tokens_per_s=int(n * batch / td.s), wall_s=round(td.s, 2))
    print("[lm]", json.dumps(row2), flush=True)
    return [row, row2]


def run_suite(quick: bool = False) -> dict:
    rows = bench(quick=quick)
    wall = dict(train_tokens_per_s=rows[0]["tokens_per_s"],
                train_wall_s=rows[0]["wall_s"],
                decode_tokens_per_s=rows[1]["tokens_per_s"],
                decode_wall_s=rows[1]["wall_s"])
    config = dict(quick=quick, arch=rows[0]["arch"])
    return R.make_report("lm_throughput", config, {}, wall,
                         extra=dict(rows=rows))
