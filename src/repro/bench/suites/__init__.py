"""Benchmark suites (one per paper table/figure + beyond-paper ablations).

Each module exposes `run(quick: bool) -> report dict` (consumed by
`repro.bench.registry`) plus the legacy `bench(...)`-style callables that
the thin `benchmarks/*.py` entry scripts keep re-exporting.
"""
