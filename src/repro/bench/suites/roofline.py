"""Roofline report: reads results/dryrun/*.json (written by
repro.launch.dryrun) and renders the per-(arch x shape x mesh) three-term
table for EXPERIMENTS.md §Roofline, including MODEL_FLOPS / HLO_FLOPs
usefulness ratios.  Analytic, not wall-clock — nothing to gate, so the
report carries rows in `extra` only (and is skipped when no dry-run
results exist)."""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config, shape_by_name
from .. import report as R


def _result_dir() -> str:
    cwd_dir = os.path.join("results", "dryrun")
    if os.path.isdir(cwd_dir):
        return cwd_dir
    from repro.launch import dryrun
    return dryrun.RESULT_DIR


def model_params(cfg) -> tuple:
    """(total, active) parameter counts from the config (analytic)."""
    d, H, Hkv, dh, f, V = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.head_dim, cfg.d_ff, cfg.vocab_size)
    tot = act = V * d * (1 if cfg.tie_embeddings else 2)
    for (mixer, mlp) in cfg.layers:
        if mixer in ("ga", "la", "bi", "xa"):
            a = d * H * dh + 2 * d * Hkv * dh + H * dh * d
            a *= 2 if mixer == "xa" else 1
        elif mixer == "rg":
            dr = cfg.rg_lru_width or d
            a = 2 * d * dr + 2 * dr * dr + dr * d
        else:
            a = 5 * d * d
        tot += a
        act += a
        if mlp == "dense":
            m = d * f * (3 if cfg.act == "swiglu" else 2)
            tot += m
            act += m
        elif mlp == "moe":
            mo = cfg.moe
            per = mo.d_ff_expert * d * (3 if cfg.act == "swiglu" else 2)
            tot += mo.n_experts * per
            act += mo.top_k * per
            if mo.shared_expert:
                tot += per
                act += per
        elif mlp == "cmix":
            m = d * f * 2 + d * d
            tot += m
            act += m
    if cfg.family == "encdec":
        a = (d * H * dh + 2 * d * Hkv * dh + H * dh * d
             + d * f * (3 if cfg.act == "swiglu" else 2))
        tot += cfg.n_encoder_layers * a
        act += cfg.n_encoder_layers * a
    return tot, act


def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*D for train; 2*N_active per generated token for decode;
    2*N_active*T for prefill."""
    cfg = get_config(arch)
    sh = shape_by_name(shape_name)
    _, act = model_params(cfg)
    tokens = sh.global_batch * sh.seq_len
    if sh.kind == "train":
        return 6.0 * act * tokens
    if sh.kind == "prefill":
        return 2.0 * act * tokens
    return 2.0 * act * sh.global_batch          # decode: 1 new token/seq


def load_records():
    recs = []
    for f in sorted(glob.glob(os.path.join(_result_dir(), "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def report(single_pod_only: bool = False):
    rows = []
    for r in load_records():
        if single_pod_only and r.get("multi_pod"):
            continue
        rl = r.get("roofline", {})
        chips = r["chips"]
        arch, shape = r["arch"], r["shape"]
        try:
            mf = model_flops(arch, shape)
        except Exception:
            mf = None
        hlo_total = r["cost"]["flops_per_device"] * chips
        useful = (mf / hlo_total) if (mf and hlo_total) else None
        dom = rl.get("dominant", "?")
        bound_s = max(rl.get("compute_s", 0), rl.get("memory_s", 0),
                      rl.get("collective_s", 0))
        frac = (rl.get("compute_s", 0) / bound_s) if bound_s else 0
        row = dict(arch=arch, shape=shape,
                   mesh="2x16x16" if r["multi_pod"] else "16x16",
                   compute_s=rl.get("compute_s"),
                   memory_s=rl.get("memory_s"),
                   collective_s=rl.get("collective_s"),
                   dominant=dom,
                   mem_gb_per_dev=round(
                       r["memory"].get("per_device_total", 0) / 1e9, 2)
                   if "per_device_total" in r.get("memory", {}) else None,
                   model_flops=mf, hlo_flops_total=hlo_total,
                   useful_flop_frac=round(useful, 3) if useful else None,
                   roofline_frac=round(frac, 3))
        rows.append(row)
        print("[roofline]", json.dumps(row), flush=True)
    return rows


def run_suite(quick: bool = False) -> dict:
    rows = report()
    config = dict(quick=quick, n_records=len(rows))
    return R.make_report("roofline", config, {}, {}, extra=dict(rows=rows))
