"""Beyond-paper ablation: dense O(E) masked delivery vs event-driven
O(spikes x fan) delivery, across activity regimes.

The paper's model is event-driven (on a CPU cluster that is the only
sensible choice); the dense formulation is the TPU-idiomatic one.  This
benchmark measures the CPU wall-clock crossover by varying the thalamic
drive (lower stim -> sparser activity -> event backend advantage grows),
and gates that both backends keep producing identical rasters.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.core import EngineConfig, GridConfig, observables
from repro.core import engine as E
from repro.core import event_engine as EV
from .. import report as R
from .. import timing


def bench(quick: bool = False):
    npc = 250 if quick else 500
    steps = 100 if quick else 200
    rows = []
    for stim in (1, 0):          # events/ms/column: normal vs silent-ish
        cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=npc,
                         synapses_per_neuron=50, seed=5,
                         stim_events_per_ms_per_column=stim)
        eng = EngineConfig(n_shards=1)

        spec, plan, dstate = E.build(cfg, eng)
        run_d = jax.jit(lambda s: E.run(spec, plan, s, 0, steps))
        _, raster_d, _ = run_d(dstate)
        jax.block_until_ready(raster_d)
        td = timing.time_fn(run_d, dstate, reps=1, warmup=0)

        spec2, plan2, eplan, estate = EV.build(cfg, eng)
        run_e = jax.jit(lambda s: EV.run(spec2, plan2, eplan, s, 0, steps))
        st2, raster_e = run_e(estate)
        jax.block_until_ready(raster_e)
        te = timing.time_fn(run_e, estate, reps=1, warmup=0)

        sig_d = observables.raster_signature(np.asarray(raster_d),
                                             np.asarray(plan.gid))
        sig_e = observables.raster_signature(np.asarray(raster_e),
                                             np.asarray(plan2.gid))
        rate = observables.mean_rate_hz(np.asarray(raster_d),
                                        cfg.n_neurons)
        row = dict(stim_per_ms=stim, rate_hz=round(rate, 1),
                   dense_s=round(td.median_s, 3),
                   event_s=round(te.median_s, 3),
                   speedup=round(td.median_s / max(te.median_s, 1e-9), 2),
                   identical_rasters=bool(sig_d == sig_e),
                   raster_sig=sig_d.hex(),
                   saturated=int(np.asarray(st2.sat).sum()))
        rows.append(row)
        print("[event_vs_dense]", json.dumps(row), flush=True)
    return rows


def run_suite(quick: bool = False) -> dict:
    rows = bench(quick=quick)
    deterministic, wall = {}, {}
    for r in rows:
        s = r["stim_per_ms"]
        deterministic[f"identical_rasters_stim{s}"] = r["identical_rasters"]
        deterministic[f"sig_stim{s}"] = r["raster_sig"]
        wall[f"dense_s_stim{s}"] = r["dense_s"]
        wall[f"event_s_stim{s}"] = r["event_s"]
    config = dict(quick=quick)
    return R.make_report("event_vs_dense", config, deterministic, wall,
                         extra=dict(rows=rows))
