"""Beyond-paper ablation: dense O(E) masked delivery vs event-driven
O(spikes x fan) delivery, across activity regimes AND distribution
layouts.

The paper's model is event-driven (on a CPU cluster that is the only
sensible choice); the dense formulation is the TPU-idiomatic one.  Two
measurement families:

  - single-device crossover (the original suite): fused end-to-end wall
    of both backends while varying the thalamic drive (lower stim ->
    sparser activity -> event advantage grows), rasters gated identical;

  - distributed cells (H x exchange x delivery, real `shard_map` over a
    `cells` mesh): per-phase A / exchange / B walls via
    `core.StepProgram.time_phases` — the paper's Table 2 split — so
    the crossover is measured under real sharding, where phase A is the
    event backend's O(spikes x fan) advantage and the exchange wire is
    shared by both backends.  Every cell must produce the same raster
    (Table 1 invariant + backend equivalence, gated hard).  The sparse
    (stim 0) point additionally runs RATE-CALIBRATED event cells: the
    default capacities are worst-case-sized (never saturate at 60 Hz),
    which pins event phase A to an O(E)-proportional floor; sizing the
    static buffers from the expected rate band — the paper's own AER
    trade — is what makes the O(spikes x fan) phase A win visible, and
    the saturation counters stay gated at 0.

Cells needing more devices than the platform offers are skipped and the
executed H list is recorded in config (CI forces 8 host devices, so the
committed baseline carries the full matrix).
"""
from __future__ import annotations

import dataclasses
import json

import jax
import numpy as np

from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as dcore
from repro.core import engine as E
from repro.core import event_engine as EV
from .. import report as R
from .. import timing

H_LIST = (1, 2, 4)
EXCHANGES = ("halo", "allgather")
DELIVERIES = ("dense", "event")


def bench(quick: bool = False):
    """Single-device fused crossover rows (stim 1 vs 0)."""
    npc = 250 if quick else 500
    steps = 100 if quick else 200
    rows = []
    for stim in (1, 0):          # events/ms/column: normal vs silent-ish
        cfg = GridConfig(grid_x=2, grid_y=2, neurons_per_column=npc,
                         synapses_per_neuron=50, seed=5,
                         stim_events_per_ms_per_column=stim)
        eng = EngineConfig(n_shards=1)

        spec, plan, dstate = E.build(cfg, eng)
        run_d = jax.jit(lambda s: E.run(spec, plan, s, 0, steps))
        _, raster_d, _ = run_d(dstate)
        jax.block_until_ready(raster_d)
        td = timing.time_fn(run_d, dstate, reps=1, warmup=0)

        spec2, plan2, eplan, estate = EV.build(cfg, eng)
        run_e = jax.jit(lambda s: EV.run(spec2, plan2, eplan, s, 0, steps))
        st2, raster_e, _ = run_e(estate)
        jax.block_until_ready(raster_e)
        te = timing.time_fn(run_e, estate, reps=1, warmup=0)

        sig_d = observables.raster_signature(np.asarray(raster_d),
                                             np.asarray(plan.gid))
        sig_e = observables.raster_signature(np.asarray(raster_e),
                                             np.asarray(plan2.gid))
        rate = observables.mean_rate_hz(np.asarray(raster_d),
                                        cfg.n_neurons)
        row = dict(stim_per_ms=stim, rate_hz=round(rate, 1),
                   dense_s=round(td.median_s, 3),
                   event_s=round(te.median_s, 3),
                   speedup=round(td.median_s / max(te.median_s, 1e-9), 2),
                   identical_rasters=bool(sig_d == sig_e),
                   raster_sig=sig_d.hex(),
                   saturated=int(np.asarray(st2.sat).sum()))
        rows.append(row)
        print("[event_vs_dense]", json.dumps(row), flush=True)
    return rows


def _phase_cell(spec, plan, state, mesh, steps: int, eplan=None,
                caps=None) -> dict:
    """Per-phase walls of one distributed cell under real shard_map.
    Warmup + timing discipline live in `StepProgram.time_phases` (shared
    with the cluster worker, so the two measurements cannot drift)."""
    sp = StepProgram.from_parts(spec, plan, eplan, mesh=mesh, caps=caps)
    s = sp.place(state)
    s, times, rasters, _ = sp.time_phases(s, 0, steps,
                                          collect_rasters=True)
    raster = np.stack(rasters)                         # [T, H, N]
    sig = observables.raster_signature(raster, np.asarray(plan.gid))
    out = dict(**{k: round(v, 4) for k, v in times.items()},
               raster_sig=sig.hex(), spikes=int(raster.sum()))
    if eplan is not None:
        out["saturated"] = int(np.asarray(s.sat).sum())
    return out


def bench_distributed(quick: bool = False):
    """H x exchange x delivery per-phase cells (+ one sparse-stim pair)."""
    npc = 100 if quick else 250
    steps = 40 if quick else 100
    h_list = [h for h in H_LIST if h <= jax.device_count()]
    cells = {}
    stims = {"": 1, "_stim0": 0}   # live crossover + silent sparse point
    for H in h_list:
        # ONE build per H: EV.build already contains the dense
        # spec/plan/state (estate.base is the dense initial state; the
        # spec differs only in eng.delivery, re-pointed per cell), and
        # connectivity is stim-independent, so both stim levels share it
        # too — the stimulus only enters at run time via spec.cfg
        cfg1 = GridConfig(grid_x=2, grid_y=2, neurons_per_column=npc,
                          synapses_per_neuron=50, seed=5)
        espec, esplan, e_eplan, estate = EV.build(
            cfg1, EngineConfig(n_shards=H, delivery="event"))
        mesh = dcore.make_mesh(H)
        for suffix, stim in stims.items():
            if stim == 0 and H != 2:
                continue
            spec_s = espec._replace(cfg=dataclasses.replace(
                cfg1, stim_events_per_ms_per_column=stim))
            for ex in EXCHANGES:
                for delivery in DELIVERIES:
                    key = f"h{H}_{ex}_{delivery}{suffix}"
                    eng = EngineConfig(n_shards=H, exchange=ex,
                                       delivery=delivery)
                    if delivery == "event":
                        cell = _phase_cell(spec_s._replace(eng=eng), esplan,
                                           estate, mesh, steps,
                                           eplan=e_eplan)
                    else:
                        cell = _phase_cell(spec_s._replace(eng=eng), esplan,
                                           estate.base, mesh, steps)
                    cells[key] = dict(h=H, exchange=ex, delivery=delivery,
                                      stim_per_ms=stim, steps=steps, **cell)
                    print("[event_vs_dense]", key,
                          json.dumps(cells[key]), flush=True)
                if stim != 0:
                    continue
                # rate-calibrated event cell: the default capacities are
                # worst-case-sized (cap_ev = E/4, c_post = N/2 — never
                # saturate at the paper's 60 Hz band), which keeps event
                # phase A O(E)-proportional regardless of activity.  In a
                # sparse regime the AER trade says: size the static
                # buffers from the EXPECTED rate and count overflows.
                # This cell does exactly that (floor-sized caps, sat
                # gated) — the regime where the event formulation's
                # O(spikes x fan) claim pays off.
                key = f"h{H}_{ex}_event{suffix}_rated"
                eng = EngineConfig(n_shards=H, exchange=ex,
                                   delivery="event")
                state_r = EV.init_event_state(spec_s, estate.base,
                                              cap_ev=256)
                cell = _phase_cell(spec_s._replace(eng=eng), esplan,
                                   state_r, mesh, steps, eplan=e_eplan,
                                   caps=dict(c_post=16, c_src=16))
                cells[key] = dict(h=H, exchange=ex, delivery="event",
                                  stim_per_ms=stim, steps=steps,
                                  rated_caps=True, **cell)
                print("[event_vs_dense]", key, json.dumps(cells[key]),
                      flush=True)
    return h_list, cells


def run_suite(quick: bool = False) -> dict:
    rows = bench(quick=quick)
    h_list, cells = bench_distributed(quick=quick)

    deterministic, wall = {}, {}
    for r in rows:
        s = r["stim_per_ms"]
        deterministic[f"identical_rasters_stim{s}"] = r["identical_rasters"]
        deterministic[f"sig_stim{s}"] = r["raster_sig"]
        wall[f"dense_s_stim{s}"] = r["dense_s"]
        wall[f"event_s_stim{s}"] = r["event_s"]

    # distributed: layout AND backend must never change the physics —
    # one signature per stim level across every (H, exchange, delivery)
    sigs = {}
    for key, c in cells.items():
        sigs.setdefault(c["stim_per_ms"], set()).add(c["raster_sig"])
    for stim, ss in sorted(sigs.items()):
        if len(ss) != 1:
            got = [(k, c["raster_sig"][:12]) for k, c in cells.items()
                   if c["stim_per_ms"] == stim]
            raise RuntimeError(
                f"distributed rasters diverge across layouts/backends at "
                f"stim={stim}: {got}")
        deterministic[f"dist_sig_stim{stim}"] = next(iter(ss))
    for key, c in cells.items():
        deterministic[f"sat_{key}"] = c.get("saturated", 0)
        for m in ("phase_a_s", "exchange_s", "phase_b_s"):
            wall[f"{key}_{m}"] = c[m]

    # the crossover summary: does event beat dense on phase A per cell?
    # (>1 = event faster; rated cells compare against the same-layout
    # default-caps dense cell)
    wins = {}
    for k, c in cells.items():
        if c["delivery"] != "event":
            continue
        dense_key = k.replace("_event", "_dense").replace("_rated", "")
        if dense_key in cells:
            wins[k] = round(cells[dense_key]["phase_a_s"]
                            / max(c["phase_a_s"], 1e-9), 2)
    config = dict(quick=quick, h_list=list(h_list))
    return R.make_report(
        "event_vs_dense", config, deterministic, wall,
        extra=dict(rows=rows, dist_cells=[dict(cell=k, **c)
                                          for k, c in sorted(cells.items())],
                   phase_a_event_speedup=wins))
