"""Paper Table 1: problem sizes, firing rates, and the normalized
time-per-synapse metric.

The paper sweeps 200K .. 1.6G synapses; on a CPU container we execute the
lower rows for real (0.2M .. 12.8M synapses) and verify (a) the firing
rate lands in the paper's 20-48 Hz initial-activity band, (b) the detailed
firing is reproducible (spike counts + raster signature are gated against
the committed baseline), (c) the normalized execution time (s per synapse
per simulated second per Hz — the paper's metric) is size-independent.
The full 128x64 grid is exercised by the dry-run (launch/dryrun --snn).
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.core import EngineConfig, GridConfig, build, observables, run
from .. import report as R
from .. import timing

# (grid_x, grid_y) -> paper row; synapses = cols * 1000 * 200
ROWS = [
    (1, 1),      # 200 K synapses   (paper: 20 Hz)
    (4, 4),      # 3.2 M            (paper: 26 Hz)
    (8, 4),      # 6.4 M            (paper: 29 Hz)
    (8, 8),      # 12.8 M           (paper: 31 Hz)
]
PAPER_RATES = {1: 20, 16: 26, 32: 29, 64: 31, 128: 33, 256: 33}


def bench(steps: int = 300, rows=None, quick: bool = False):
    rows = rows if rows is not None else (ROWS[:2] if quick else ROWS)
    steps = 150 if quick else steps
    out = []
    for gx, gy in rows:
        cfg = GridConfig(grid_x=gx, grid_y=gy)
        with timing.Timer() as tb:
            spec, plan, state = build(cfg, EngineConfig(n_shards=1))

        run_j = jax.jit(lambda s: run(spec, plan, s, 0, steps))
        _, raster, _ = run_j(state)                  # compile + warm run
        jax.block_until_ready(raster)
        t = timing.time_fn(run_j, state, reps=1 if quick else 2, warmup=0)

        raster = np.asarray(raster)
        rate = observables.mean_rate_hz(raster, cfg.n_neurons)
        sig = observables.raster_signature(raster, np.asarray(plan.gid))
        norm = timing.norm_seconds(t.median_s, cfg.n_synapses, steps, rate)
        row = dict(grid=f"{gx}x{gy}", columns=cfg.n_columns,
                   neurons=cfg.n_neurons, synapses=cfg.n_synapses,
                   steps=steps, rate_hz=round(float(rate), 1),
                   paper_rate_hz=PAPER_RATES.get(cfg.n_columns),
                   wall_s=round(t.median_s, 3), spread=round(t.spread, 3),
                   build_s=round(tb.s, 2),
                   spikes=int(raster.sum()), raster_sig=sig.hex(),
                   norm_s_per_syn_per_s_per_hz=float(f"{norm:.3e}"),
                   syn_events_per_s=int(cfg.n_synapses * rate * steps
                                        / 1000.0 / t.median_s))
        out.append(row)
        print("[table1]", json.dumps(row), flush=True)
    return out


def run_suite(quick: bool = False) -> dict:
    rows = bench(quick=quick)
    deterministic, wall = {}, {}
    for r in rows:
        g = r["grid"]
        deterministic[f"spikes_{g}"] = r["spikes"]
        deterministic[f"sig_{g}"] = r["raster_sig"]
        wall[f"wall_{g}"] = r["wall_s"]
        wall[f"norm_{g}"] = r["norm_s_per_syn_per_s_per_hz"]
    config = dict(quick=quick, grids=[r["grid"] for r in rows],
                  steps=rows[0]["steps"])
    return R.make_report("table1", config, deterministic, wall,
                         extra=dict(rows=rows))
