"""Exchange-vs-reach: per-phase profile of the DPSNN step across
lateral-connectivity profiles (arXiv:1803.08833's experiment, one command:
`python -m repro.bench run connectivity_sweep --quick`).

The paper's benchmark fixes projection to the 3rd Chebyshev ring; the
follow-up study on the same simulator shows Gaussian/exponential decay
kernels shift the compute/communication balance with connectivity reach.
This suite measures exactly that: for each profile it times phase A /
spike exchange / phase B (the `bench.profile` harness) under BOTH
exchange modes, and records the reach-derived distribution quantities —
halo columns per shard, static halo-offset schedule size, per-shard
synapse capacity — that the profile's `reach()` controls.

Within one profile the two exchange modes must produce bit-identical
rasters (paper Table 1 invariant at every reach — asserted here);
ACROSS profiles the rasters differ by construction (different physics),
so each profile gates its own spike count / raster signature against the
committed baseline.
"""
from __future__ import annotations

from .. import report as R
from ..profile import profile_cell
from ...core import profiles, topology
from ...core import distributed as dcore
from ...core import engine as engine_mod
from ...core.params import EngineConfig, GridConfig

#: Profile specs swept, in report order.  ring3 is the paper kernel
#: (reach 3), ring1 a narrow variant (reach 1), gaussian/exponential the
#: arXiv:1803.08833 decay kernels (reach 5 at these parameters).
PROFILE_SPECS = ("ring3", "ring1", "gaussian:sigma=1.5",
                 "exponential:lambda=1.0")

EXCHANGES = ("halo", "allgather")


def _key(spec: str) -> str:
    """Metric-key-safe profile tag: 'gaussian:sigma=1.5' -> 'gaussian'."""
    return spec.partition(":")[0]


def _reach_stats(cfg: GridConfig, eng: EngineConfig, built) -> dict:
    """Distribution-side quantities the profile's reach determines (read
    off the prebuilt (spec, plan, state) — no extra engine build)."""
    prof = profiles.from_config(cfg)
    halo_cols = [topology.shard_halo_columns(cfg, h, eng.n_shards,
                                             eng.placement).shape[0]
                 for h in range(eng.n_shards)]
    spec, plan, _ = built
    offsets = dcore.halo_offsets(spec, plan)
    return dict(reach=prof.reach(),
                ring_masses=[round(m, 4) for m in prof.ring_masses()],
                halo_cols_max=int(max(halo_cols)),
                halo_offsets=len(offsets),
                e_cap=spec.e_cap, s_cap=spec.s_cap)


def run_suite(quick: bool = False) -> dict:
    """Profile x exchange matrix -> one BENCH report.

    The grid must out-span the widest kernel (2*reach + 1 columns per
    axis) or periodic wrap aliases every halo to the full grid and the
    reach effect disappears; 12x12 covers reach 5, the 6x6 quick grid
    deliberately half-wraps (recorded in config, gated identically).
    """
    gx = gy = 6 if quick else 12
    npc = 40 if quick else 100
    M = 30 if quick else 60
    H = 4 if quick else 8
    steps = 40 if quick else 100

    rows, deterministic, wall = [], {}, {}
    for pspec in PROFILE_SPECS:
        cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc,
                         synapses_per_neuron=M, seed=2013,
                         connectivity=pspec)
        # one engine build per profile: the synapse tables are
        # exchange-independent, so both cells (and the reach stats) share
        # it via profile_cell's `built` hook
        eng0 = EngineConfig(n_shards=H, exchange=EXCHANGES[0],
                            placement="block")
        built = engine_mod.build(cfg, eng0)
        stats = _reach_stats(cfg, eng0, built)
        cells = {}
        for ex in EXCHANGES:
            eng = EngineConfig(n_shards=H, exchange=ex, placement="block")
            cells[ex] = profile_cell(cfg, eng, steps, built=built)

        sigs = {c["raster_sig"] for c in cells.values()}
        if len(sigs) != 1:
            raise RuntimeError(
                f"Table 1 invariant violated at profile {pspec!r}: "
                f"halo vs allgather rasters differ: "
                f"{ {k: c['raster_sig'] for k, c in cells.items()} }")

        key = _key(pspec)
        ref = cells["halo"]
        deterministic[f"{key}_spikes"] = ref["spikes"]
        deterministic[f"{key}_raster_sig"] = ref["raster_sig"]
        deterministic[f"{key}_reach"] = stats["reach"]
        deterministic[f"{key}_halo_offsets"] = stats["halo_offsets"]
        deterministic[f"{key}_halo_cols_max"] = stats["halo_cols_max"]
        deterministic[f"{key}_e_cap"] = stats["e_cap"]
        for ex, c in cells.items():
            for m in ("phase_a_s", "exchange_s", "phase_b_s", "wall_s"):
                wall[f"{key}_{ex}_{m}"] = c[m]
            wall[f"{key}_{ex}_comm_fraction"] = c["comm_fraction"]

        row = dict(profile=pspec, **stats,
                   rate_hz=ref["rate_hz"], spikes=ref["spikes"],
                   cells={ex: {m: c[m] for m in
                               ("phase_a_s", "exchange_s", "phase_b_s",
                                "wall_s", "comm_fraction")}
                          for ex, c in cells.items()})
        rows.append(row)
        exch_ratio = (ref["exchange_s"] / ref["phase_a_s"]
                      if ref["phase_a_s"] else float("nan"))
        print(f"[connectivity_sweep] {pspec}: reach {stats['reach']}, "
              f"{stats['halo_offsets']} halo offsets, halo exchange/phaseA "
              f"= {exch_ratio:.3f}, rate {ref['rate_hz']} Hz", flush=True)

    config = dict(grid=f"{gx}x{gy}", neurons_per_column=npc,
                  synapses_per_neuron=M, shards=H, steps=steps,
                  profiles=list(PROFILE_SPECS), exchanges=list(EXCHANGES),
                  quick=quick)
    extra = dict(rows=rows)
    return R.make_report("connectivity_sweep", config, deterministic, wall,
                         extra)
