"""Weak scaling toward the paper's 20G-synapse regime with streamed
on-the-fly connectivity (`python -m repro.bench run weak_scaling --quick`).

The DPSNN follow-up study (arXiv:1511.09325) frames cluster capacity as
time per synaptic event at constant synapses per process while the grid
grows.  The materialized engine cannot follow that curve far: per-shard
synapse tables are O(total synapses / H) live bytes, so doubling the grid
at fixed H doubles resident table memory.  `connectivity='streamed'`
regenerates per-chunk tables inside the jitted step from the counter-based
splitmix64 draw lanes, holding live table bytes at O(chunk) regardless of
grid size — rasters AND weights bit-identical to materialized mode.

Two measurements per run:

  1. RESIDENCY RATIO — one grid sized so the full synapse tables exceed
     streamed mode's per-chunk table bytes by >= 8x (the headline gate,
     asserted in-suite: `materialized_table_bytes / streamed_table_bytes
     >= RATIO_FLOOR`).  The same cell proves bit-identity: streamed and
     materialized runs must agree on the raster signature and on every
     final synapse weight (canonical-order signature), or the suite
     raises.
  2. WEAK-SCALING LADDER — constant synapses per shard, growing grid
     (paper Fig. 3-2's axis), streamed mode: wall and the normalized
     time per synaptic event per rung.  Spike totals and signatures gate
     deterministically; walls are tolerance-compared.

All rungs run the single-device vmap engine (logical shards), so the
suite needs no forced device count and the numbers are comparable across
machines; the cluster CI job drives the same streamed config across real
processes.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .. import report as R
from ...core import observables, stream_engine
from ...core.params import EngineConfig, GridConfig
from ...core.step_program import StepProgram

#: Minimum materialized/streamed live-table-bytes ratio the residency
#: cell must demonstrate (the ISSUE's acceptance floor).
RATIO_FLOOR = 8.0


def _weight_sig(sp: StepProgram, state) -> str:
    """sha256 over the final valid synapse weights in canonical order —
    comparable between materialized and streamed StepPrograms (both lay
    valid weights out in (tgt_gid, src_gid, j) order per shard)."""
    return sp.weight_signature(state).hex()


def _run_cell(cfg: GridConfig, eng: EngineConfig, steps: int) -> dict:
    """Warmed fused run -> wall, spikes, raster/weight signatures."""
    sp = StepProgram(cfg, eng)
    state0 = sp.init_state()
    jax.block_until_ready(sp.run(state0, 0, steps)[1])         # compile
    t0 = time.perf_counter()
    state_f, raster, _ = sp.run(state0, 0, steps)
    jax.block_until_ready(raster)
    wall = time.perf_counter() - t0
    raster = np.asarray(raster)
    return dict(
        sp=sp, wall_s=wall, spikes=int(raster.sum()),
        rate_hz=observables.mean_rate_hz(raster, cfg.n_neurons),
        raster_sig=observables.raster_signature(
            raster, np.asarray(sp.plan.gid)).hex(),
        weight_sig=_weight_sig(sp, state_f))


def _residency_cell(quick: bool) -> dict:
    """The >= 8x residency grid + the bit-identity wall."""
    gx = gy = 10 if quick else 14
    npc, M, steps = (30, 100, 20) if quick else (40, 120, 40)
    cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc,
                     synapses_per_neuron=M, seed=2013,
                     connectivity="ring:max_ring=1")
    e_s = EngineConfig(n_shards=1, connectivity="streamed:chunk=1")
    e_m = EngineConfig(n_shards=1)

    cs = _run_cell(cfg, e_s, steps)
    cm = _run_cell(cfg, e_m, steps)

    spec_s, spec_m = cs["sp"].spec, cm["sp"].spec
    streamed_b = stream_engine.streamed_table_bytes(spec_s)
    mat_b = stream_engine.materialized_table_bytes(spec_m.e_cap)
    ratio = mat_b / streamed_b
    if ratio < RATIO_FLOOR:
        raise RuntimeError(
            f"residency grid too small: materialized {mat_b} B / streamed "
            f"{streamed_b} B = {ratio:.1f}x < required {RATIO_FLOOR}x")
    if cs["raster_sig"] != cm["raster_sig"]:
        raise RuntimeError(
            f"streamed raster forked from materialized: "
            f"{cs['raster_sig'][:16]} != {cm['raster_sig'][:16]}")
    if cs["weight_sig"] != cm["weight_sig"]:
        raise RuntimeError(
            f"streamed final weights forked from materialized: "
            f"{cs['weight_sig'][:16]} != {cm['weight_sig'][:16]}")

    ss = spec_s.stream
    print(f"[weak_scaling] residency {gx}x{gy} npc={npc} M={M}: "
          f"materialized {mat_b} B vs streamed {streamed_b} B "
          f"({ratio:.1f}x, floor {RATIO_FLOOR}x); raster+weights "
          f"bit-identical ({cs['raster_sig'][:16]})", flush=True)
    return dict(
        grid=f"{gx}x{gy}", npc=npc, M=M, steps=steps,
        streamed_table_bytes=int(streamed_b),
        materialized_table_bytes=int(mat_b),
        ratio_x10=int(ratio * 10), k_cap=int(ss.k_cap),
        e_cap_materialized=int(spec_m.e_cap),
        n_chunks=int(ss.n_chunks),
        spikes=cs["spikes"], raster_sig=cs["raster_sig"],
        weight_sig=cs["weight_sig"],
        identical=(cs["raster_sig"] == cm["raster_sig"]
                   and cs["weight_sig"] == cm["weight_sig"]),
        wall_streamed_s=cs["wall_s"], wall_materialized_s=cm["wall_s"],
        rate_hz=round(cs["rate_hz"], 3))


#: ladder rungs: (grid_x, grid_y, shards) — columns per shard constant,
#: so synapses per shard are constant while the grid grows (weak scaling)
LADDER = ((4, 4, 1), (4, 8, 2), (8, 8, 4))


def _ladder(quick: bool) -> list:
    npc, M, steps = (30, 60, 20) if quick else (50, 80, 60)
    rows = []
    for gx, gy, H in LADDER:
        cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc,
                         synapses_per_neuron=M, seed=2013,
                         connectivity="ring:max_ring=1")
        eng = EngineConfig(n_shards=H, connectivity="streamed:chunk=2")
        c = _run_cell(cfg, eng, steps)
        events = c["spikes"] * M
        tpse = c["wall_s"] / events if events else float("nan")
        ss = c["sp"].spec.stream
        rows.append(dict(
            grid=f"{gx}x{gy}", shards=H, npc=npc, M=M, steps=steps,
            syn_per_shard=gx * gy * npc * M // H,
            k_cap=int(ss.k_cap), wall_s=round(c["wall_s"], 4),
            spikes=c["spikes"], rate_hz=round(c["rate_hz"], 3),
            raster_sig=c["raster_sig"],
            time_per_syn_event_s=float(f"{tpse:.3e}")))
        print(f"[weak_scaling] ladder {gx}x{gy} H={H}: "
              f"{rows[-1]['syn_per_shard']} syn/shard, wall "
              f"{rows[-1]['wall_s']}s, {tpse:.3e} s/syn-event", flush=True)
    return rows


def run_suite(quick: bool = False) -> dict:
    res = _residency_cell(quick)
    rows = _ladder(quick)

    deterministic = dict(
        residency_ratio_x10=res["ratio_x10"],
        residency_streamed_table_bytes=res["streamed_table_bytes"],
        residency_materialized_table_bytes=res["materialized_table_bytes"],
        residency_k_cap=res["k_cap"],
        residency_spikes=res["spikes"],
        residency_raster_sig=res["raster_sig"],
        residency_weight_sig=res["weight_sig"],
        residency_identical=res["identical"])
    wall = dict(residency_streamed_s=round(res["wall_streamed_s"], 4),
                residency_materialized_s=round(res["wall_materialized_s"],
                                               4))
    for r in rows:
        tag = f"ladder_{r['grid']}_h{r['shards']}"
        deterministic[f"{tag}_spikes"] = r["spikes"]
        deterministic[f"{tag}_raster_sig"] = r["raster_sig"]
        deterministic[f"{tag}_syn_per_shard"] = r["syn_per_shard"]
        wall[f"{tag}_wall_s"] = r["wall_s"]
        wall[f"{tag}_time_per_syn_event_s"] = r["time_per_syn_event_s"]

    config = dict(quick=quick, ratio_floor=int(RATIO_FLOOR),
                  residency=dict(grid=res["grid"], npc=res["npc"],
                                 M=res["M"], steps=res["steps"],
                                 chunk=1),
                  ladder=[dict(grid=r["grid"], shards=r["shards"],
                               npc=r["npc"], M=r["M"], steps=r["steps"],
                               chunk=2) for r in rows])
    extra = dict(residency={k: v for k, v in res.items()
                            if not hasattr(v, "spec")},
                 ladder=rows)
    return R.make_report("weak_scaling", config, deterministic, wall,
                         extra)
