"""Multi-tenant service throughput: `repro.simserve` at 1 / 4 / 8
tenants on one shape key.

The service's value proposition is that same-shape tenants share one
jitted round program on a free leading batch axis, so aggregate
steps/s should grow with tenant count until the vmap stops vectorizing
profitably.  Each cell submits N same-shape tenants (seeds differ —
exactly what the shape key ignores), drives the service to completion,
and reports

  wall           aggregate tenant-steps/s, fused wall, and the paper's
                 normalized time-per-synaptic-event (service wall /
                 (total spikes x synapses per neuron)) per tenant count;
  deterministic  one combined signature per tenant count (sha256 over
                 the per-tenant streamed raster signatures, which are
                 each bit-identical to solo runs — the service
                 correctness spine as gateable data) plus the program
                 cache's build and trace counts (the zero-recompile
                 criterion: 1 build, 1 trace regardless of N).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

from repro.core import EngineConfig, GridConfig
from repro.simserve import SimService, TenantRequest
from .. import report as R

TENANT_COUNTS = (1, 4, 8)


def _run_cell(n_tenants: int, steps: int, round_steps: int) -> dict:
    cfg0 = GridConfig(grid_x=2, grid_y=2, neurons_per_column=20,
                      synapses_per_neuron=10)
    eng = EngineConfig(n_shards=2, delivery="dense")
    svc = SimService(slots=n_tenants, round_steps=round_steps)
    reqs = [TenantRequest(f"t{i}", dataclasses.replace(
        cfg0, seed=2013 + 7919 * i), eng, steps)
        for i in range(n_tenants)]
    for r in reqs:
        svc.submit(r)
    snap = svc.run()

    sigs = [svc.sessions[r.name].stream.signature() for r in reqs]
    combined = hashlib.sha256(b"".join(sigs)).hexdigest()[:16]
    spikes = sum(svc.sessions[r.name].spike_total for r in reqs)
    syn_events = spikes * cfg0.synapses_per_neuron
    wall = snap["wall_s"]
    return dict(
        tenants=n_tenants, steps=steps, spikes=spikes,
        wall_s=round(wall, 4),
        steps_per_s=int(snap["tenant_steps_per_s"]),
        time_per_syn_event_s=wall / max(syn_events, 1),
        sig=combined,
        cache_builds=snap["program_cache"]["builds"],
        traces=sum(snap["program_cache"]["traces"].values()))


def run_suite(quick: bool = False) -> dict:
    steps, round_steps = (40, 10) if quick else (100, 20)
    deterministic, wall, rows = {}, {}, []
    for n in TENANT_COUNTS:
        row = _run_cell(n, steps, round_steps)
        rows.append(row)
        print("[simserve]", json.dumps(row), flush=True)
        deterministic[f"t{n}_sig"] = row["sig"]
        deterministic[f"t{n}_cache_builds"] = row["cache_builds"]
        deterministic[f"t{n}_traces"] = row["traces"]
        wall[f"t{n}_wall_s"] = row["wall_s"]
        wall[f"t{n}_steps_per_s"] = row["steps_per_s"]
        wall[f"t{n}_time_per_syn_event_s"] = row["time_per_syn_event_s"]
    config = dict(quick=quick, steps=steps, round_steps=round_steps,
                  tenants=list(TENANT_COUNTS))
    return R.make_report("simserve_throughput", config, deterministic,
                         wall, extra=dict(rows=rows))
