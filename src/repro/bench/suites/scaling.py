"""Paper Figures 3-1 / 3-2: strong and weak scaling of the DPSNN engine.

Each scaling point runs in a fresh interpreter with H forced host devices
and one shard per device (shard_map + real collectives).  NOTE on honesty:
a single-core container cannot show wall-clock decreasing with H the way
the paper's 128-core cluster does; what these curves measure there is
(a) the engine runs correctly at every H with identical spiking, (b) the
distribution overhead (collective + imbalance) vs H, which is exactly the
quantity the paper's Discussion section analyses.  On real hardware the
same harness produces the paper's curves.
"""
from __future__ import annotations

import json

from .. import report as R
from ..subproc import run_subprocess

_POINT = """
import time, numpy as np, jax
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D

H = {H}
cfg = GridConfig(grid_x={gx}, grid_y={gy}, neurons_per_column={npc})
eng = EngineConfig(n_shards=H, exchange={exchange!r})
sp = StepProgram(cfg, eng, mesh=D.make_mesh(H))
plan = sp.plan
state = sp.place(sp.init_state())
s2, raster, tm = sp.run(state, 0, {steps})       # compile
jax.block_until_ready(raster)
t0 = time.time()
s2, raster, tm = sp.run(state, 0, {steps})
jax.block_until_ready(raster)
wall = time.time() - t0
raster = np.asarray(raster)
rate = observables.mean_rate_hz(raster, cfg.n_neurons)
sig = observables.raster_signature(raster, np.asarray(plan.gid))
print("RESULT", wall, rate, sig.hex()[:16])
"""


def _run_point(H, gx, gy, npc, steps, exchange="allgather", timeout=None):
    # timeout=None defers to $REPRO_SUBPROC_TIMEOUT / the subproc default,
    # so slow CI runners can stretch every point without code changes.
    out = run_subprocess(_POINT.format(H=H, gx=gx, gy=gy, npc=npc,
                                       steps=steps, exchange=exchange), H,
                         timeout=timeout)
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, wall, rate, sig = line.split()
            return float(wall), float(rate), sig
    raise RuntimeError(out)


def strong_scaling(quick: bool = False):
    """Fixed problem (4x4 grid, 3.2M synapses), growing H."""
    gx = gy = 2 if quick else 4
    npc = 500 if quick else 1000
    steps = 100 if quick else 200
    hs = [1, 2, 4] if quick else [1, 2, 4, 8]
    rows, sig0 = [], None
    for h in hs:
        wall, rate, sig = _run_point(h, gx, gy, npc, steps)
        sig0 = sig0 or sig
        n_syn = gx * gy * npc * 200
        norm = wall / (n_syn * steps / 1000.0 * max(rate, 1e-9))
        row = dict(mode="strong", shards=h, synapses=n_syn, wall_s=round(
            wall, 3), rate_hz=round(rate, 1),
            raster_sig=sig,
            norm_s=float(f"{norm:.3e}"),
            identical_spikes=(sig == sig0))
        rows.append(row)
        print("[scaling]", json.dumps(row), flush=True)
    assert all(r["identical_spikes"] for r in rows), \
        "spiking must be identical across distributions (paper Table 1)"
    return rows


def weak_scaling(quick: bool = False):
    """Fixed synapses per shard (1 column/shard), growing H."""
    npc = 500 if quick else 1000
    steps = 100 if quick else 200
    grids = [(1, 1), (2, 1), (2, 2)] if quick else [(1, 1), (2, 1), (2, 2),
                                                    (4, 2)]
    rows = []
    for gx, gy in grids:
        h = gx * gy
        wall, rate, sig = _run_point(h, gx, gy, npc, steps)
        syn_per_shard = npc * 200
        norm = wall / (syn_per_shard * steps / 1000.0 * max(rate, 1e-9))
        row = dict(mode="weak", shards=h, syn_per_shard=syn_per_shard,
                   wall_s=round(wall, 3), rate_hz=round(rate, 1),
                   raster_sig=sig,
                   norm_s=float(f"{norm:.3e}"))
        rows.append(row)
        print("[scaling]", json.dumps(row), flush=True)
    return rows


def run_suite(quick: bool = False) -> dict:
    strong = strong_scaling(quick=quick)
    weak = weak_scaling(quick=quick)
    deterministic = dict(
        strong_raster_sig=strong[0]["raster_sig"],
        strong_identical_across_h=all(r["identical_spikes"]
                                      for r in strong))
    wall = {}
    for r in strong:
        wall[f"strong_h{r['shards']}_wall_s"] = r["wall_s"]
    for r in weak:
        wall[f"weak_h{r['shards']}_wall_s"] = r["wall_s"]
    config = dict(quick=quick,
                  strong_shards=[r["shards"] for r in strong],
                  weak_shards=[r["shards"] for r in weak])
    return R.make_report("scaling", config, deterministic, wall,
                         extra=dict(strong=strong, weak=weak))
