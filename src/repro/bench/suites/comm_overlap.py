"""Comm/compute overlap: hidden vs exposed spike-exchange time.

The paper keeps communication under ~10% of wall-clock by overlapping the
AER spike exchange with computation wherever the delay structure allows.
This suite measures exactly that trade for the JAX engine: every cell
runs the SAME physics under both exchange schedules —

  sync       exchange fenced between phase A and phase B; exchange_s is
             the wire's full exposed latency,
  pipelined  exchange dispatched between the two phase-A halves and only
             awaited right before the phase B that consumes it (one step
             later); exchange_s records just dispatch + residual wait —
             the exposed remainder after hiding behind the LTP half

— across lateral-connectivity profiles (the reach sets how much wire
there is to hide) and shard counts, timed by `StepProgram.time_phases`
(the identical discipline the cluster worker uses, so these numbers and
the multi-process ones are directly comparable).

Two invariants are gated in-suite, mirroring the ISSUE's acceptance
criteria: (a) both schedules produce bit-identical rasters in every cell
(a schedule is an execution layout, never physics), and (b) on profiles
with reach >= 3 — where the halo carries at least the paper's 3-ring
neighbourhood — the pipelined exposed exchange time is strictly below
the sync baseline.  Cells needing more devices than the platform offers
are skipped and the executed H list is recorded in config (CI forces 8
host devices, so the committed baseline carries the full matrix).
"""
from __future__ import annotations

import dataclasses
import json

import jax
import numpy as np

from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as dcore
from repro.core import engine as E
from repro.core import profiles as profmod
from .. import report as R

# (key, profile spec): keys are report-safe names, specs feed GridConfig
PROFILES = (("ring1", "ring1"),
            ("ring3", "ring3"),
            ("gauss5", "gaussian:sigma=1.5"))
SCHEDULES = ("sync", "pipelined")
H_LIST = (2, 4)


def _cell(spec, plan, state, mesh, steps: int, reps: int) -> dict:
    """One (profile, H, schedule) cell: per-phase walls are the per-key
    MINIMUM over `reps` timing passes (the programs are compiled once and
    the state is re-seeded each pass, so reps differ only by scheduler
    noise — min is the standard de-noised estimate and what makes the
    strict hidden<exposed gate safe on shared runners)."""
    sp = StepProgram.from_parts(spec, plan, mesh=mesh)
    s = sp.place(state)
    times = rasters = counts = None
    for _ in range(reps):
        _, t1, rasters, counts = sp.time_phases(s, 0, steps,
                                                collect_rasters=True)
        times = t1 if times is None else \
            {k: min(v, t1[k]) for k, v in times.items()}
    raster = np.stack(rasters)                          # [T, H, N]
    sig = observables.raster_signature(raster, np.asarray(plan.gid))
    phases_sum = sum(times.values())
    return dict(**{k: round(v, 4) for k, v in times.items()},
                phases_sum_s=round(phases_sum, 4),
                exposed_fraction=round(times["exchange_s"] / phases_sum, 4)
                if phases_sum else 0.0,
                spikes=counts["spikes"], raster_sig=sig.hex())


def run_suite(quick: bool = False) -> dict:
    npc = 80 if quick else 200
    steps = 40 if quick else 100
    reps = 3
    h_list = [h for h in H_LIST if h <= jax.device_count()]

    cells, pairs = {}, {}
    for pkey, pspec in PROFILES:
        reach = profmod.parse(pspec).reach()
        cfg = GridConfig(grid_x=4, grid_y=2, neurons_per_column=npc,
                         synapses_per_neuron=50, seed=5,
                         connectivity=pspec)
        for H in h_list:
            # one build per (profile, H): the plan is schedule-independent
            eng0 = EngineConfig(n_shards=H, exchange="halo")
            spec, plan, state = E.build(cfg, eng0)
            mesh = dcore.make_mesh(H)
            by_sched = {}
            for sched in SCHEDULES:
                eng = dataclasses.replace(eng0, exchange_schedule=sched)
                cell = _cell(spec._replace(eng=eng), plan, state, mesh,
                             steps, reps)
                key = f"{pkey}_h{H}_{sched}"
                cells[key] = dict(profile=pspec, reach=reach, h=H,
                                  schedule=sched, steps=steps, **cell)
                by_sched[sched] = cell
                print("[comm_overlap]", key, json.dumps(cells[key]),
                      flush=True)

            sy, pi = by_sched["sync"], by_sched["pipelined"]
            if sy["raster_sig"] != pi["raster_sig"]:
                raise RuntimeError(
                    f"schedule changed the physics at {pkey} H={H}: "
                    f"sync {sy['raster_sig'][:16]} != pipelined "
                    f"{pi['raster_sig'][:16]}")
            if reach >= 3 and pi["exchange_s"] >= sy["exchange_s"]:
                raise RuntimeError(
                    f"pipelined exchange not hidden at {pkey} (reach "
                    f"{reach}) H={H}: exposed {pi['exchange_s']}s >= sync "
                    f"{sy['exchange_s']}s")
            pairs[f"{pkey}_h{H}"] = dict(
                profile=pspec, reach=reach, h=H,
                sync_exchange_s=sy["exchange_s"],
                pipelined_exchange_s=pi["exchange_s"],
                hidden_s=round(sy["exchange_s"] - pi["exchange_s"], 4),
                hidden_fraction=round(
                    1.0 - pi["exchange_s"] / sy["exchange_s"], 4)
                if sy["exchange_s"] else 0.0)

    deterministic, wall = {}, {}
    for pair_key, p in pairs.items():
        deterministic[f"sig_{pair_key}"] = \
            cells[f"{pair_key}_sync"]["raster_sig"]
        deterministic[f"spikes_{pair_key}"] = \
            cells[f"{pair_key}_sync"]["spikes"]
        wall[f"{pair_key}_hidden_fraction"] = p["hidden_fraction"]
    for key, c in cells.items():
        for m in ("phase_a_s", "exchange_s", "phase_b_s",
                  "exposed_fraction"):
            wall[f"{key}_{m}"] = c[m]

    config = dict(quick=quick, h_list=list(h_list), grid="4x2",
                  neurons_per_column=npc, steps=steps, exchange="halo",
                  profiles=[p for _, p in PROFILES])
    return R.make_report(
        "comm_overlap", config, deterministic, wall,
        extra=dict(cells=[dict(cell=k, **c) for k, c in sorted(
            cells.items())],
            overlap=[dict(pair=k, **p) for k, p in sorted(pairs.items())]))
