"""Paper Table 2: per-phase time decomposition at H=1 (single process).

The paper instruments barrier wait, spike-counter exchange, payload
transmission, and total, concluding communication is <= ~10% of the total.
This suite is now a thin projection of the general per-phase profiler
(`repro.bench.profile`): one shard, 'halo' exchange (the AER pack +
counter-lane + match pipeline — the closest analogue of the paper's
two-phase delivery), reported in the paper's compute/communication split.
The full exchange x placement matrix lives in the 'profile' suite.
"""
from __future__ import annotations

import json

from repro.core.params import EngineConfig, GridConfig
from .. import profile as P
from .. import report as R


def bench(gx=2, gy=2, npc=1000, steps=200, quick=False):
    if quick:
        gx = gy = 2
        npc = 250
        steps = 100
    cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc)
    eng = EngineConfig(n_shards=1, exchange="halo")
    cell = P.profile_cell(cfg, eng, steps)
    row = dict(grid=f"{gx}x{gy}", steps=steps, spikes=cell["spikes"],
               compute_s=cell["phase_a_s"],
               exchange_s=cell["exchange_s"],
               arborize_s=cell["phase_b_s"],
               total_s=cell["phases_sum_s"],
               comm_fraction=cell["comm_fraction"],
               raster_sig=cell["raster_sig"],
               paper_claim="comm <= ~10% of total")
    print("[table2]", json.dumps(row), flush=True)
    return row


def run_suite(quick: bool = False) -> dict:
    row = bench(quick=quick)
    deterministic = dict(spikes=row["spikes"], raster_sig=row["raster_sig"])
    wall = dict(compute_s=row["compute_s"], exchange_s=row["exchange_s"],
                arborize_s=row["arborize_s"], total_s=row["total_s"])
    config = dict(quick=quick, grid=row["grid"], steps=row["steps"])
    return R.make_report("table2", config, deterministic, wall,
                         extra=dict(row=row))
