"""Fresh-interpreter benchmark subprocesses.

jax locks the host device count at first init, so every scaling point runs
in a fresh process with its own forced count — which is also what makes
the measurement honest: each point pays full startup, like an MPI job.
"""
from __future__ import annotations

import os
import subprocess
import sys
from typing import Optional

import repro
from repro._flags import subprocess_env

# src/ directory containing the `repro` package — valid for both the
# editable install and a plain checkout; exported on the child PYTHONPATH
# so subprocess code imports `repro` even when the parent runs uninstalled.
SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))

# Callers that don't pass a timeout get this, overridable per-environment
# (slow CI runners, fast local boxes) without touching call sites.
TIMEOUT_ENV = "REPRO_SUBPROC_TIMEOUT"
DEFAULT_TIMEOUT = 1800.0


class SubprocessError(RuntimeError):
    """A bench/test subprocess failed or timed out.

    `returncode` is the child's exit code (None on timeout), so callers
    can distinguish a crash (negative = signal) from a failed assertion
    without parsing the message."""

    def __init__(self, msg: str, returncode: Optional[int] = None,
                 stdout: str = "", stderr: str = ""):
        self.returncode = returncode
        self.stdout = stdout
        self.stderr = stderr
        super().__init__(msg)


def _tail(stream, limit: int = 2000) -> str:
    if stream is None:
        return "<no output captured>"
    if isinstance(stream, bytes):
        stream = stream.decode("utf-8", errors="replace")
    return stream[-limit:] if stream else "<no output captured>"


def resolve_timeout(timeout: Optional[float]) -> float:
    """Explicit timeout, else $REPRO_SUBPROC_TIMEOUT, else the default."""
    if timeout is not None:
        return timeout
    return float(os.environ.get(TIMEOUT_ENV, DEFAULT_TIMEOUT))


def run_subprocess(code: str, n_devices: int = 1,
                   timeout: Optional[float] = None, extra_env=None) -> str:
    """Run `code` in a fresh interpreter with `n_devices` forced host
    devices; returns its stdout.  On timeout the child is killed; on any
    failure the raised `SubprocessError` carries the exit code and the
    stdout/stderr tails (a bare `TimeoutExpired`/`CalledProcessError`
    would lose them)."""
    timeout = resolve_timeout(timeout)
    env = subprocess_env(n_devices, SRC)
    env.update(extra_env or {})
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=timeout)
    except subprocess.TimeoutExpired as e:
        raise SubprocessError(
            f"bench subprocess timed out after {timeout}s\n"
            f"stdout tail:\n{_tail(e.stdout)}\n"
            f"stderr tail:\n{_tail(e.stderr)}",
            returncode=None, stdout=_tail(e.stdout),
            stderr=_tail(e.stderr)) from e
    if out.returncode != 0:
        raise SubprocessError(
            f"bench subprocess failed with exit code {out.returncode}:\n"
            f"stdout tail:\n{_tail(out.stdout)}\n"
            f"stderr tail:\n{_tail(out.stderr)}",
            returncode=out.returncode, stdout=out.stdout or "",
            stderr=out.stderr or "")
    return out.stdout
