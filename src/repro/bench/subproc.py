"""Fresh-interpreter benchmark subprocesses.

jax locks the host device count at first init, so every scaling point runs
in a fresh process with its own forced count — which is also what makes
the measurement honest: each point pays full startup, like an MPI job.
"""
from __future__ import annotations

import os
import subprocess
import sys

import repro
from repro._flags import subprocess_env

# src/ directory containing the `repro` package — valid for both the
# editable install and a plain checkout; exported on the child PYTHONPATH
# so subprocess code imports `repro` even when the parent runs uninstalled.
SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))


def _tail(stream, limit: int = 2000) -> str:
    if stream is None:
        return "<no output captured>"
    if isinstance(stream, bytes):
        stream = stream.decode("utf-8", errors="replace")
    return stream[-limit:]


def run_subprocess(code: str, n_devices: int = 1, timeout: int = 1800,
                   extra_env=None) -> str:
    """Run `code` in a fresh interpreter with `n_devices` forced host
    devices; returns its stdout.  On timeout the child is killed and the
    captured stdout/stderr tails are surfaced in the raised error (a bare
    `TimeoutExpired` would lose them)."""
    env = subprocess_env(n_devices, SRC)
    env.update(extra_env or {})
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=timeout)
    except subprocess.TimeoutExpired as e:
        raise RuntimeError(
            f"bench subprocess timed out after {timeout}s\n"
            f"stdout tail:\n{_tail(e.stdout)}\n"
            f"stderr tail:\n{_tail(e.stderr)}") from e
    if out.returncode != 0:
        raise RuntimeError(f"bench subprocess failed "
                           f"(rc={out.returncode}):\n{out.stdout}\n"
                           f"{out.stderr}")
    return out.stdout
