"""Benchmark registry: name -> suite runner.

Every entry is a thin loader so `repro.bench list` never pays suite import
cost (the LM suites pull the full model stack).  `slow` entries (fresh-
interpreter scaling points) are excluded from the default `run` set and
must be named explicitly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List


@dataclasses.dataclass(frozen=True)
class Entry:
    name: str
    fn: Callable[[bool], dict]        # quick -> report dict
    doc: str
    slow: bool = False


def _profile(quick):
    from . import profile
    return profile.run_profile(quick)


def _table1(quick):
    from .suites import table1
    return table1.run_suite(quick)


def _table2(quick):
    from .suites import table2
    return table2.run_suite(quick)


def _event_vs_dense(quick):
    from .suites import event_vs_dense
    return event_vs_dense.run_suite(quick)


def _comm_overlap(quick):
    from .suites import comm_overlap
    return comm_overlap.run_suite(quick)


def _lm_throughput(quick):
    from .suites import lm_throughput
    return lm_throughput.run_suite(quick)


def _roofline(quick):
    from .suites import roofline
    return roofline.run_suite(quick)


def _scaling(quick):
    from .suites import scaling
    return scaling.run_suite(quick)


def _connectivity_sweep(quick):
    from .suites import connectivity_sweep
    return connectivity_sweep.run_suite(quick)


def _weak_scaling(quick):
    from .suites import weak_scaling
    return weak_scaling.run_suite(quick)


def _simserve_throughput(quick):
    from .suites import simserve_throughput
    return simserve_throughput.run_suite(quick)


def _cluster_scaling(quick):
    from ..cluster import cli as cluster_cli
    return cluster_cli.sweep_report(quick=quick)


BENCHES: Dict[str, Entry] = {e.name: e for e in [
    Entry("profile", _profile,
          "per-phase compute/exchange/arborization split, "
          "{allgather,halo} x {block,scatter} (paper Table 2)"),
    Entry("table1", _table1,
          "problem sizes, rates, normalized time/synapse (paper Table 1)"),
    Entry("table2", _table2,
          "H=1 compute/communication split (paper Table 2, legacy view)"),
    Entry("event_vs_dense", _event_vs_dense,
          "dense O(E) vs event-driven delivery crossover (beyond-paper)"),
    Entry("comm_overlap", _comm_overlap,
          "hidden vs exposed spike-exchange time, sync vs pipelined "
          "schedule x profile x H (comm/compute overlap)"),
    Entry("connectivity_sweep", _connectivity_sweep,
          "per-phase split across lateral-connectivity profiles "
          "(ring/Gaussian/exponential; arXiv:1803.08833)"),
    Entry("weak_scaling", _weak_scaling,
          "streamed O(chunk) table residency >= 8x smaller than "
          "materialized + bit-identity wall + time/syn-event ladder at "
          "constant synapses/shard (arXiv:1511.09325)"),
    Entry("lm_throughput", _lm_throughput,
          "LM substrate train/decode tokens/s (CPU micro-benchmark)"),
    Entry("simserve_throughput", _simserve_throughput,
          "multi-tenant service aggregate steps/s + time/syn-event at "
          "1/4/8 tenants, zero-recompile gated (repro.simserve)"),
    Entry("roofline", _roofline,
          "three-term roofline table from results/dryrun (analytic)"),
    Entry("scaling", _scaling,
          "strong/weak scaling, fresh interpreter per H "
          "(paper Figs 3-1/3-2)", slow=True),
    Entry("cluster_scaling", _cluster_scaling,
          "strong scaling over REAL process counts, fixed total shards "
          "(paper Figs 5-8; repro.cluster)", slow=True),
]}


def get(name: str) -> Entry:
    if name not in BENCHES:
        raise KeyError(f"unknown benchmark {name!r}; known: "
                       f"{sorted(BENCHES)}")
    return BENCHES[name]


def default_names(include_slow: bool = False) -> List[str]:
    return [n for n, e in BENCHES.items() if include_slow or not e.slow]
