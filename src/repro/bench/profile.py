"""Per-phase profiling of the DPSNN step (paper Table 2 instrumentation).

The paper splits wall-clock into computational and communication parts and
concludes communication stays <= ~10% of the total.  Here the step is cut
at the same joints, each phase a separately-jitted function timed with
`block_until_ready`:

  phase_a     — local dynamics: arrivals -> currents -> LTD -> Izhikevich
                update -> LTP (the paper's "dynamic phase" compute),
  exchange    — spike delivery between shards, in both engine modes:
                'allgather' builds the global spike mask, 'halo' packs
                fixed-capacity AER buffers and routes them along the static
                halo offsets (the paper's two-phase sparse delivery),
  phase_b     — deferred axonal arborization (arrival-ring updates).

Shards are logical (`vmap` over the stacked [H, ...] plan) so the profile
runs on a single device: the halo route is emulated with `jnp.roll` over
the shard axis, which preserves the exchange's full compute graph (AER
pack/sort, scatter-match) while the wire itself is measured by the
multi-process scaling suite.  Both the phase handles and the timing loop
come from `core.StepProgram` (mesh=None), so the profiler, the cluster
worker and the bench suites time the SAME machinery — the loop is
schedule-aware, attributing only the exposed remainder of a pipelined
exchange to exchange_s.  Alongside wall-clock, each cell records the
deterministic counters (total spikes/arrivals, raster signature) and the
trip-count-aware HLO flops/bytes of the fused step
(`launch/hlo_cost.py`) — the metrics the baseline comparator gates hard.
"""
from __future__ import annotations

import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import StepProgram, engine, observables
from ..core.params import EngineConfig, GridConfig

EXCHANGES = ("allgather", "halo")
PLACEMENTS = ("block", "scatter")


def profiled_phase_fns(spec, plan, eplan=None, caps=None):
    """Unified-signature phase handles for single-device profiling.

    A thin route into `StepProgram.phase_fns` (mesh=None) kept for
    callers that already hold built parts.  Its predecessor (a module-
    local `make_phase_fns`) shadowed `core.distributed.make_phase_fns`
    while constructing a *different* program; routing both the profiler
    and the cluster worker through StepProgram removes the collision and
    the drift."""
    return StepProgram.from_parts(spec, plan, eplan, caps=caps).phase_fns()


def _hlo_step_cost(sp: StepProgram, state) -> Tuple[int, int]:
    """(flops, bytes) of one fused step from the optimized HLO."""
    from ..launch import hlo_cost
    compiled = sp.fused.lower(sp.planT, state, jnp.int32(0)).compile()
    parsed = hlo_cost.analyze(compiled.as_text())
    return int(round(parsed["flops"])), int(round(parsed["bytes"]))


def profile_cell(cfg: GridConfig, eng: EngineConfig, steps: int,
                 built=None) -> dict:
    """Profile one (exchange, placement[, schedule]) cell; flat metrics.

    `built` optionally passes a prebuilt (spec, plan, state) from
    `engine.build` for the same (cfg, shards, placement): the plan is
    exchange-independent, so callers sweeping exchange modes (the
    connectivity_sweep / comm_overlap suites) skip rebuilding the synapse
    tables — `spec.eng` is re-pointed at `eng` here."""
    if built is None:
        spec, plan, state = engine.build(cfg, eng)
    else:
        spec, plan, state = built
        assert (spec.eng.n_shards, spec.eng.placement) == \
            (eng.n_shards, eng.placement), "prebuilt plan layout mismatch"
        spec = spec._replace(eng=eng)
    sp = StepProgram.from_parts(spec, plan, state0=state)
    pp = sp.phase_fns()

    # warmup: compile the phase programs outside the wall-clock window
    # (t is traced, so one call covers every step; the pipelined split
    # halves compile in time_phases' own warm pass, already warm here)
    st_w, spiked_w, _ = pp.phase_a(state, 0)
    ss_w = pp.exchange(spiked_w)
    jax.block_until_ready(pp.phase_b(st_w, ss_w, 0))
    if spec.eng.exchange_schedule == "pipelined":
        st_w, spiked_w, _ = pp.phase_a_dynamics(state, 0)
        jax.block_until_ready(pp.phase_a_plasticity(st_w, spiked_w, 0))

    wall0 = time.perf_counter()
    _, times, rasters, counts = sp.time_phases(state, 0, steps,
                                               collect_rasters=True)
    wall_s = time.perf_counter() - wall0

    raster = np.stack(rasters)                       # [T, H, N]
    sig = observables.raster_signature(raster, np.asarray(plan.gid))
    rate = observables.mean_rate_hz(raster, cfg.n_neurons)
    hlo_flops, hlo_bytes = _hlo_step_cost(sp, state)

    phases_sum = sum(times.values())
    return dict(
        exchange=eng.exchange, placement=eng.placement, steps=steps,
        exchange_schedule=eng.exchange_schedule,
        **{k: round(v, 4) for k, v in times.items()},
        phases_sum_s=round(phases_sum, 4), wall_s=round(wall_s, 4),
        steps_per_s=round(steps / wall_s, 2) if wall_s else 0.0,
        comm_fraction=round(times["exchange_s"] / phases_sum, 4)
        if phases_sum else 0.0,
        spikes=counts["spikes"], arrivals=counts["arrivals"],
        raster_sig=sig.hex(), rate_hz=round(rate, 2),
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes)


def run_profile(quick: bool = False) -> dict:
    """Full exchange x placement profiling matrix -> one bench report.

    Enforces the paper's Table 1 invariant in-process: every cell must
    produce the identical raster signature and spike/arrival totals — the
    distribution layout may change the timings, never the physics.
    """
    from . import report as R

    gx = gy = 2
    npc = 200 if quick else 1000
    H = 2 if quick else 4
    steps = 60 if quick else 150
    cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc)

    cells = {}
    for ex in EXCHANGES:
        for pl in PLACEMENTS:
            eng = EngineConfig(n_shards=H, exchange=ex, placement=pl)
            cells[f"{ex}_{pl}"] = profile_cell(cfg, eng, steps)

    sigs = {k: c["raster_sig"] for k, c in cells.items()}
    if len(set(sigs.values())) != 1:
        raise RuntimeError(f"paper Table 1 invariant violated: raster "
                           f"signatures differ across layouts: {sigs}")
    counts = {k: (c["spikes"], c["arrivals"]) for k, c in cells.items()}
    if len(set(counts.values())) != 1:
        raise RuntimeError(f"spike/arrival totals differ across layouts: "
                           f"{counts}")

    ref = next(iter(cells.values()))
    deterministic = dict(spikes=ref["spikes"], arrivals=ref["arrivals"],
                         raster_sig=ref["raster_sig"])
    wall = {}
    for key, c in cells.items():
        deterministic[f"hlo_flops_{key}"] = c["hlo_flops"]
        deterministic[f"hlo_bytes_{key}"] = c["hlo_bytes"]
        for m in ("phase_a_s", "exchange_s", "phase_b_s", "wall_s",
                  "steps_per_s"):
            wall[f"{key}_{m}"] = c[m]
    config = dict(grid=f"{gx}x{gy}", neurons_per_column=npc, shards=H,
                  steps=steps, quick=quick)
    extra = dict(rate_hz=ref["rate_hz"],
                 cells=[dict(cell=k, **c) for k, c in cells.items()])
    return R.make_report("profile", config, deterministic, wall, extra)
