"""Per-phase profiling of the DPSNN step (paper Table 2 instrumentation).

The paper splits wall-clock into computational and communication parts and
concludes communication stays <= ~10% of the total.  Here the step is cut
at the same joints, each phase a separately-jitted function timed with
`block_until_ready`:

  phase_a     — local dynamics: arrivals -> currents -> LTD -> Izhikevich
                update -> LTP (the paper's "dynamic phase" compute),
  exchange    — spike delivery between shards, in both engine modes:
                'allgather' builds the global spike mask, 'halo' packs
                fixed-capacity AER buffers and routes them along the static
                halo offsets (the paper's two-phase sparse delivery),
  phase_b     — deferred axonal arborization (arrival-ring updates).

Shards are logical (`vmap` over the stacked [H, ...] plan) so the profile
runs on a single device: the halo route is emulated with `jnp.roll` over
the shard axis, which preserves the exchange's full compute graph (AER
pack/sort, scatter-match) while the wire itself is measured by the
multi-process scaling suite.  Alongside wall-clock, each cell records the
deterministic counters (total spikes/arrivals, raster signature) and the
trip-count-aware HLO flops/bytes of the fused step
(`launch/hlo_cost.py`) — the metrics the baseline comparator gates hard.
"""
from __future__ import annotations

import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import aer, engine, observables, stimulus
from ..core import distributed as dcore
from ..core.params import EngineConfig, GridConfig

EXCHANGES = ("allgather", "halo")
PLACEMENTS = ("block", "scatter")


def make_phase_fns(spec, plan) -> Tuple:
    """(phase_a, exchange, phase_b, fused_step) jitted over stacked shards.

    `exchange` matches `spec.eng.exchange`.  The plan is an explicit
    argument of every jitted function, NOT a closure: closed-over arrays
    lower to XLA literal constants, which the CPU backend re-materializes
    on every execution — measured ~50x slower per phase call at 200k
    synapses.  `plan` here is only used to derive the static halo offsets.
    """
    stim_k = stimulus.stim_key(spec.cfg)

    def _phase_a(plan, state, t):
        return jax.vmap(
            lambda p, s: engine.phase_a(spec, p, s, t, stim_k))(plan, state)

    def _ex_allgather(plan, spiked):
        glob = engine._global_spike_mask(spec, plan, spiked)
        return jax.vmap(
            lambda p: glob.at[p.src_gid].get(mode="fill", fill_value=False)
            & (p.src_gid >= 0))(plan)

    offsets = dcore.halo_offsets(spec, plan) \
        if spec.eng.exchange == "halo" else None

    def _ex_halo(plan, spiked):
        ids_all, _ = jax.vmap(
            lambda p, s: aer.pack(s, p.gid, p.gid.shape[0]))(plan, spiked)
        # receiver h hears sender (h - d) % H: the single-device analogue of
        # the ppermute in core.distributed._spiked_src_halo
        received = [jnp.roll(ids_all, d, axis=0) for d in offsets]
        all_ids = jnp.concatenate(received, axis=1)

        def match(p, ids_row):
            mask = jnp.zeros((spec.n_total,), bool).at[ids_row].set(
                True, mode="drop")
            return mask.at[p.src_gid].get(mode="fill", fill_value=False) \
                & (p.src_gid >= 0)

        return jax.vmap(match)(plan, all_ids)

    _exchange = _ex_halo if spec.eng.exchange == "halo" else _ex_allgather

    def _phase_b(plan, state, spiked_src, t):
        return jax.vmap(
            lambda p, s, x: engine.phase_b(spec, p, s, x, t))(plan, state,
                                                              spiked_src)

    def _fused(plan, state, t):
        state, spiked, tm = _phase_a(plan, state, t)
        spiked_src = _exchange(plan, spiked)
        state = _phase_b(plan, state, spiked_src, t)
        return state, spiked, tm

    return (jax.jit(_phase_a), jax.jit(_exchange), jax.jit(_phase_b),
            jax.jit(_fused))


def _hlo_step_cost(fused, plan, state) -> Tuple[int, int]:
    """(flops, bytes) of one fused step from the optimized HLO."""
    from ..launch import hlo_cost
    compiled = fused.lower(plan, state, jnp.int32(0)).compile()
    parsed = hlo_cost.analyze(compiled.as_text())
    return int(round(parsed["flops"])), int(round(parsed["bytes"]))


def profile_cell(cfg: GridConfig, eng: EngineConfig, steps: int,
                 built=None) -> dict:
    """Profile one (exchange, placement) cell; returns flat metrics.

    `built` optionally passes a prebuilt (spec, plan, state) from
    `engine.build` for the same (cfg, shards, placement): the plan is
    exchange-independent, so callers sweeping exchange modes (the
    connectivity_sweep suite) skip rebuilding the synapse tables —
    `spec.eng` is re-pointed at `eng` here."""
    if built is None:
        spec, plan, state = engine.build(cfg, eng)
    else:
        spec, plan, state = built
        assert (spec.eng.n_shards, spec.eng.placement) == \
            (eng.n_shards, eng.placement), "prebuilt plan layout mismatch"
        spec = spec._replace(eng=eng)
    phase_a, exchange, phase_b, fused = make_phase_fns(spec, plan)

    # warmup: compile all three phase functions (t is traced, so one call
    # covers every step)
    t0j = jnp.int32(0)
    st_w, spiked_w, _ = phase_a(plan, state, t0j)
    ss_w = exchange(plan, spiked_w)
    jax.block_until_ready(phase_b(plan, st_w, ss_w, t0j))

    times = dict(phase_a_s=0.0, exchange_s=0.0, phase_b_s=0.0)
    spikes = arrivals = 0
    rasters = []
    s = state
    wall0 = time.perf_counter()
    for t in range(steps):
        tt = jnp.int32(t)
        t0 = time.perf_counter()
        s2, spiked, tm = phase_a(plan, s, tt)
        jax.block_until_ready(spiked)
        times["phase_a_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        spiked_src = exchange(plan, spiked)
        jax.block_until_ready(spiked_src)
        times["exchange_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        s = phase_b(plan, s2, spiked_src, tt)
        jax.block_until_ready(s.arr_ring)
        times["phase_b_s"] += time.perf_counter() - t0

        spikes += int(np.asarray(tm.spikes).sum())
        arrivals += int(np.asarray(tm.arrivals).sum())
        rasters.append(np.asarray(spiked))
    wall_s = time.perf_counter() - wall0

    raster = np.stack(rasters)                       # [T, H, N]
    sig = observables.raster_signature(raster, np.asarray(plan.gid))
    rate = observables.mean_rate_hz(raster, cfg.n_neurons)
    hlo_flops, hlo_bytes = _hlo_step_cost(fused, plan, state)

    phases_sum = sum(times.values())
    return dict(
        exchange=eng.exchange, placement=eng.placement, steps=steps,
        **{k: round(v, 4) for k, v in times.items()},
        phases_sum_s=round(phases_sum, 4), wall_s=round(wall_s, 4),
        steps_per_s=round(steps / wall_s, 2) if wall_s else 0.0,
        comm_fraction=round(times["exchange_s"] / phases_sum, 4)
        if phases_sum else 0.0,
        spikes=spikes, arrivals=arrivals, raster_sig=sig.hex(),
        rate_hz=round(rate, 2), hlo_flops=hlo_flops, hlo_bytes=hlo_bytes)


def run_profile(quick: bool = False) -> dict:
    """Full exchange x placement profiling matrix -> one bench report.

    Enforces the paper's Table 1 invariant in-process: every cell must
    produce the identical raster signature and spike/arrival totals — the
    distribution layout may change the timings, never the physics.
    """
    from . import report as R

    gx = gy = 2
    npc = 200 if quick else 1000
    H = 2 if quick else 4
    steps = 60 if quick else 150
    cfg = GridConfig(grid_x=gx, grid_y=gy, neurons_per_column=npc)

    cells = {}
    for ex in EXCHANGES:
        for pl in PLACEMENTS:
            eng = EngineConfig(n_shards=H, exchange=ex, placement=pl)
            cells[f"{ex}_{pl}"] = profile_cell(cfg, eng, steps)

    sigs = {k: c["raster_sig"] for k, c in cells.items()}
    if len(set(sigs.values())) != 1:
        raise RuntimeError(f"paper Table 1 invariant violated: raster "
                           f"signatures differ across layouts: {sigs}")
    counts = {k: (c["spikes"], c["arrivals"]) for k, c in cells.items()}
    if len(set(counts.values())) != 1:
        raise RuntimeError(f"spike/arrival totals differ across layouts: "
                           f"{counts}")

    ref = next(iter(cells.values()))
    deterministic = dict(spikes=ref["spikes"], arrivals=ref["arrivals"],
                         raster_sig=ref["raster_sig"])
    wall = {}
    for key, c in cells.items():
        deterministic[f"hlo_flops_{key}"] = c["hlo_flops"]
        deterministic[f"hlo_bytes_{key}"] = c["hlo_bytes"]
        for m in ("phase_a_s", "exchange_s", "phase_b_s", "wall_s",
                  "steps_per_s"):
            wall[f"{key}_{m}"] = c[m]
    config = dict(grid=f"{gx}x{gy}", neurons_per_column=npc, shards=H,
                  steps=steps, quick=quick)
    extra = dict(rate_hz=ref["rate_hz"],
                 cells=[dict(cell=k, **c) for k, c in cells.items()])
    return R.make_report("profile", config, deterministic, wall, extra)
