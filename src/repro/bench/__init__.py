"""repro.bench — the benchmarking subsystem.

The paper is a benchmark mini-application: its headline results are
scaling curves plus a per-phase computation/communication profile.  This
package makes those measurements first-class and machine-readable:

  timing   — honest wall-clock harness (warmup, block_until_ready,
             median-of-k, the paper's normalized time/synapse metric)
  profile  — per-phase (compute / exchange / arborization) instrumentation
             across exchange modes and placements, with deterministic
             counters and trip-count-aware HLO costs
  report   — versioned BENCH_<name>.json schema + baseline comparator
             (hard-fails deterministic drift, warns on wall-clock)
  registry — suite registration; cli — `python -m repro.bench
             run|compare|list`
  subproc  — fresh-interpreter scaling points (forced host device counts)

`benchmarks/*.py` at the repo root are thin entry scripts over this
package; committed baselines live in `benchmarks/baselines/`.
"""
from . import registry, report, timing
from .report import CompareResult, compare, compare_dirs, make_report, validate

__all__ = [
    "registry", "report", "timing",
    "CompareResult", "compare", "compare_dirs", "make_report", "validate",
]
