"""Machine-readable benchmark reports (`BENCH_<name>.json`) + the baseline
comparator that turns them into a CI regression gate.

Schema (version 1):

  {
    "schema_version": 1,
    "name": "<suite name>",           # one report per registered suite
    "env": {"jax", "backend", "device_count", "python", "platform"},
    "config": {...},                  # suite knobs; must match to compare
    "deterministic": {key: int|str|bool},   # bit-exact gate (spike counts,
                                            # raster signatures, HLO costs)
    "wall": {key: number},            # seconds / rates; tolerance-compared
    "extra": {...}                    # free-form rows, never gated
  }

Gating policy (`compare`):

  - deterministic drift is a hard FAILURE — these are the paper's
    reproducibility invariants (identical spiking for any distribution)
    plus compiler-level fingerprints (trip-count-aware HLO flops/bytes);
  - `hlo_*` keys are definitionally tied to the compiler, so when the
    baseline was produced under a different jax version their drift
    downgrades to a WARNING (regenerate baselines when bumping jax);
  - wall-clock drift beyond `wall_tol` relative is always a WARNING, never
    a failure: shared CI runners cannot promise stable wall time.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform as _platform
import sys
from typing import Dict, Iterable, Optional

SCHEMA_VERSION = 1

_DET_TYPES = (int, str, bool)


def environment() -> dict:
    import jax
    # process_count distinguishes reports produced inside a cluster worker
    # (repro.cluster) from single-process ones; like every env key except
    # the jax version, it is recorded, never gated.
    return dict(jax=jax.__version__,
                backend=jax.default_backend(),
                device_count=jax.device_count(),
                process_count=jax.process_count(),
                python=_platform.python_version(),
                platform=sys.platform)


def make_report(name: str, config: dict, deterministic: dict, wall: dict,
                extra: Optional[dict] = None) -> dict:
    rep = dict(schema_version=SCHEMA_VERSION, name=name, env=environment(),
               config=dict(config), deterministic=dict(deterministic),
               wall=dict(wall))
    if extra is not None:
        rep["extra"] = extra
    return rep


def validate(report: dict) -> list:
    """Schema check; returns a list of human-readable errors (empty = OK)."""
    errs = []
    if not isinstance(report, dict):
        return ["report is not a dict"]
    for key in ("schema_version", "name", "env", "config", "deterministic",
                "wall"):
        if key not in report:
            errs.append(f"missing required key: {key}")
    if errs:
        return errs
    if report["schema_version"] != SCHEMA_VERSION:
        errs.append(f"schema_version {report['schema_version']} != "
                    f"{SCHEMA_VERSION}")
    if not isinstance(report["name"], str) or not report["name"]:
        errs.append("name must be a non-empty string")
    for sect in ("env", "config", "deterministic", "wall"):
        if not isinstance(report[sect], dict):
            errs.append(f"{sect} must be a dict")
    if errs:
        return errs
    for k in ("jax", "backend", "device_count"):
        if k not in report["env"]:
            errs.append(f"env missing {k}")
    for k, v in report["deterministic"].items():
        # bool is an int subclass — accept it explicitly, reject floats:
        # a float in the deterministic section cannot be gated bit-exactly.
        if not isinstance(v, _DET_TYPES) or isinstance(v, float):
            errs.append(f"deterministic[{k}] must be int/str/bool, "
                        f"got {type(v).__name__}")
    for k, v in report["wall"].items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            errs.append(f"wall[{k}] must be a number, "
                        f"got {type(v).__name__}")
    return errs


def report_path(out_dir: str, name: str) -> str:
    return os.path.join(out_dir, f"BENCH_{name}.json")


def save(report: dict, out_dir: str) -> str:
    errs = validate(report)
    if errs:
        raise ValueError(f"refusing to save invalid report "
                         f"{report.get('name')!r}: {errs}")
    os.makedirs(out_dir, exist_ok=True)
    path = report_path(out_dir, report["name"])
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def load_dir(d: str) -> Dict[str, dict]:
    """name -> report for every BENCH_*.json under `d`."""
    out = {}
    if not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if fn.startswith("BENCH_") and fn.endswith(".json"):
            rep = load(os.path.join(d, fn))
            out[rep.get("name", fn)] = rep
    return out


@dataclasses.dataclass
class CompareResult:
    failures: list = dataclasses.field(default_factory=list)
    warnings: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures

    def extend(self, other: "CompareResult") -> None:
        self.failures.extend(other.failures)
        self.warnings.extend(other.warnings)

    def render(self) -> str:
        lines = []
        for w in self.warnings:
            lines.append(f"WARN  {w}")
        for f in self.failures:
            lines.append(f"FAIL  {f}")
        lines.append("compare: "
                     + ("OK" if self.ok else f"{len(self.failures)} "
                                             f"failure(s)")
                     + (f", {len(self.warnings)} warning(s)"
                        if self.warnings else ""))
        return "\n".join(lines)


def compare(current: dict, baseline: dict, wall_tol: float = 0.5
            ) -> CompareResult:
    """Gate `current` against `baseline` (see module docstring policy)."""
    res = CompareResult()
    name = baseline.get("name", "?")

    for rep, tag in ((current, "current"), (baseline, "baseline")):
        errs = validate(rep)
        if errs:
            res.failures.append(f"{name}: {tag} report invalid: {errs}")
    if res.failures:
        return res
    if current["name"] != baseline["name"]:
        res.failures.append(f"{name}: comparing different suites "
                            f"({current['name']} vs {baseline['name']})")
        return res
    if current["config"] != baseline["config"]:
        # values may be unhashable (lists), so diff by key, not by set
        keys = sorted(set(current["config"]) | set(baseline["config"]))
        diff = {k: (current["config"].get(k), baseline["config"].get(k))
                for k in keys
                if current["config"].get(k) != baseline["config"].get(k)}
        res.failures.append(f"{name}: config mismatch (not comparable): "
                            f"{diff}")
        return res

    same_jax = current["env"].get("jax") == baseline["env"].get("jax")
    if not same_jax:
        res.warnings.append(
            f"{name}: jax version differs (current "
            f"{current['env'].get('jax')} vs baseline "
            f"{baseline['env'].get('jax')}); hlo_* drift downgraded to "
            f"warnings — regenerate baselines if the bump is intentional")

    cur_det = current["deterministic"]
    for k, base_v in baseline["deterministic"].items():
        if k not in cur_det:
            res.failures.append(f"{name}: deterministic metric {k!r} "
                                f"missing from current report")
            continue
        if cur_det[k] != base_v:
            msg = (f"{name}: deterministic drift in {k!r}: "
                   f"{cur_det[k]!r} != baseline {base_v!r}")
            if k.startswith("hlo_") and not same_jax:
                res.warnings.append(msg + " (jax version differs)")
            else:
                res.failures.append(msg)
    for k in sorted(set(cur_det) - set(baseline["deterministic"])):
        res.warnings.append(f"{name}: new deterministic metric {k!r} not in "
                            f"baseline (will gate after re-baselining)")

    for k, base_v in baseline["wall"].items():
        cur_v = current["wall"].get(k)
        if cur_v is None or not base_v:
            continue
        rel = (cur_v - base_v) / base_v
        if abs(rel) > wall_tol:
            res.warnings.append(f"{name}: wall metric {k!r} drifted "
                                f"{rel:+.0%} ({base_v} -> {cur_v}, "
                                f"tol ±{wall_tol:.0%})")
    return res


def compare_dirs(current_dir: str, baseline_dir: str,
                 names: Optional[Iterable] = None,
                 wall_tol: float = 0.5) -> CompareResult:
    """Compare every baseline report (or the `names` subset) against the
    matching current report; a baseline with no current report is a
    failure (the benchmark silently disappeared)."""
    res = CompareResult()
    base = load_dir(baseline_dir)
    cur = load_dir(current_dir)
    if names:
        base = {n: r for n, r in base.items() if n in set(names)}
    if not base:
        res.failures.append(f"no baseline reports found under "
                            f"{baseline_dir!r}")
        return res
    for n, brep in sorted(base.items()):
        if n not in cur:
            res.failures.append(f"{n}: no current report in "
                                f"{current_dir!r} (expected "
                                f"{report_path(current_dir, n)})")
            continue
        res.extend(compare(cur[n], brep, wall_tol=wall_tol))
    return res
