"""repro.bench.plans — config-driven, resumable experiment orchestration.

The beNNch idea (arXiv:2112.09018) applied to this benchmark: a sweep
like "profiles x delivery x exchange x schedule x process counts on this
grid ladder" is a committed YAML/JSON file, not a shell history.

  schema     plan documents -> validated `Plan` (strict: typos fail)
  expand     axes product -> cells with stable keys + content hashes
  store      one result file per completed cell; hash-keyed resume
  runner     executes cells via bench.subproc / repro.cluster, skips
             completed ones, exits with an executed/skipped/failed
             summary
  reporting  merges cells into BENCH_plan_<name>.json (the existing
             comparator gates it like any suite)
  dashboard  static inline-SVG HTML: scaling curves, per-phase stacked
             bars, hidden-exchange fractions, time/synaptic-event, plus
             the committed BENCH history

CLI: `python -m repro.bench plan run|resume|report|expand <plan file>`;
committed plans live in `benchmarks/plans/`.
"""
from .schema import Plan, PlanError, load, validate
from .expand import cell_hash, cell_key, expand, physics_group
from .store import ResultStore
from .runner import run_plan
from .reporting import load_plan_history, merged_report, write_report

__all__ = [
    "Plan", "PlanError", "load", "validate",
    "cell_hash", "cell_key", "expand", "physics_group",
    "ResultStore", "run_plan", "merged_report", "write_report",
]
