"""Self-describing static HTML scaling dashboard (no JS, no network).

One HTML file, generated from (a) the plan's completed cell records and
(b) the committed `BENCH_*.json` history, with every chart an inline SVG
— it renders from `file://`, inside CI artifact viewers, and over any
airgap, because there is nothing to fetch and nothing to execute.

Sections (each only when its data exists):

  scaling curves       wall vs shards / processes / grid columns, one
                       line per execution variant (the paper's strong and
                       weak scaling figures);
  plan-over-plan       each cell's fused wall across prior runs of this
                       plan (archived BENCH_plan_<name>.json reports) —
                       regression drift at a glance;
  per-phase split      stacked A / exchange / B bars per cell (Table 2);
  hidden exchange      sync-vs-pipelined exposed-exchange reduction for
                       cell pairs differing only in schedule;
  time per syn event   the paper's normalized metric per cell;
  cells table          every cell with its knobs, walls and signature;
  history              one chart per committed BENCH suite (wall metrics).

Colors follow the repo dashboard palette (light + dark from the same
hues): categorical slots are assigned in fixed order — phase A / exchange
/ phase B always wear slots 1/2/3 — and series beyond the eighth fold
into a muted "other" bucket rather than inventing hues.  Values are
labeled directly in ink (never in the series color); SVG `<title>` nodes
carry the hover detail.
"""
from __future__ import annotations

import html
import json
from typing import Dict, List, Optional, Sequence, Tuple

from .reporting import PHASE_KEYS, identity_groups

# fixed categorical assignment; --sN custom properties hold both modes
_SLOTS = 8
_PHASE_SLOT = {"phase_a_s": 1, "exchange_s": 2, "phase_b_s": 3}
_PHASE_LABEL = {"phase_a_s": "phase A", "exchange_s": "exchange",
                "phase_b_s": "phase B"}

_CSS = """
.viz-root { color-scheme: light;
  --page:#f9f9f7; --surface:#fcfcfb; --ink:#0b0b0b; --ink2:#52514e;
  --muted:#898781; --grid:#e1e0d9; --axis:#c3c2b7;
  --border:rgba(11,11,11,0.10); --good:#006300;
  --s1:#2a78d6; --s2:#eb6834; --s3:#1baf7a; --s4:#eda100;
  --s5:#e87ba4; --s6:#008300; --s7:#4a3aa7; --s8:#e34948;
  background:var(--page); color:var(--ink);
  font:14px/1.5 system-ui,-apple-system,"Segoe UI",sans-serif;
  margin:0; padding:24px; }
@media (prefers-color-scheme: dark) { .viz-root { color-scheme: dark;
  --page:#0d0d0d; --surface:#1a1a19; --ink:#ffffff; --ink2:#c3c2b7;
  --muted:#898781; --grid:#2c2c2a; --axis:#383835;
  --border:rgba(255,255,255,0.10); --good:#0ca30c;
  --s1:#3987e5; --s2:#d95926; --s3:#199e70; --s4:#c98500;
  --s5:#d55181; --s6:#008300; --s7:#9085e9; --s8:#e66767; } }
.viz-root h1 { font-size:20px; margin:0 0 4px; }
.viz-root h2 { font-size:16px; margin:28px 0 8px; }
.viz-root .sub { color:var(--ink2); margin:0 0 16px; }
.viz-root figure { margin:0 0 20px; background:var(--surface);
  border:1px solid var(--border); border-radius:8px; padding:16px; }
.viz-root figcaption { color:var(--ink2); font-size:12px;
  margin-bottom:8px; }
.viz-root svg { display:block; max-width:100%; }
.viz-root svg text { font:11px system-ui,-apple-system,"Segoe UI",
  sans-serif; fill:var(--ink2); }
.viz-root svg .val { fill:var(--ink); }
.viz-root svg .tick { fill:var(--muted); }
.viz-root svg .gridline { stroke:var(--grid); stroke-width:1; }
.viz-root svg .axisline { stroke:var(--axis); stroke-width:1; }
.viz-root svg g.mark:hover { opacity:0.8; }
.viz-root .legend { display:flex; flex-wrap:wrap; gap:12px;
  font-size:12px; color:var(--ink2); margin:4px 0 8px; }
.viz-root .legend .sw { display:inline-block; width:10px; height:10px;
  border-radius:2px; margin-right:5px; vertical-align:-1px; }
.viz-root table { border-collapse:collapse; font-size:12px;
  background:var(--surface); border:1px solid var(--border);
  border-radius:8px; }
.viz-root th, .viz-root td { padding:4px 10px; text-align:left;
  border-bottom:1px solid var(--grid); }
.viz-root th { color:var(--ink2); font-weight:600; }
.viz-root td.num { font-variant-numeric:tabular-nums;
  text-align:right; }
.viz-root code { font-size:11px; }
.viz-root .ok { color:var(--good); }
.viz-root .bad { color:#d03b3b; }
"""


def _e(s) -> str:
    return html.escape(str(s), quote=True)


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) < 1e-3 or abs(v) >= 1e5:
        return f"{v:.2e}"
    return f"{v:.4g}"


def _slot(i: int) -> str:
    """Fixed-order categorical color; beyond the 8 slots, fold to muted
    (never cycle hues)."""
    return f"var(--s{i + 1})" if i < _SLOTS else "var(--muted)"


def _legend(items: Sequence[Tuple[str, str]]) -> str:
    spans = "".join(
        f'<span><span class="sw" style="background:{c}"></span>'
        f'{_e(lbl)}</span>' for lbl, c in items)
    return f'<div class="legend">{spans}</div>'


def _figure(title: str, caption: str, body: str) -> str:
    return (f"<figure><figcaption><strong>{_e(title)}</strong>"
            f"{(' — ' + _e(caption)) if caption else ''}</figcaption>"
            f"{body}</figure>")


def _xticks_grid(x0, x1, y0, y1, vmax, fmt=_fmt, n=4) -> str:
    """Vertical hairline grid + muted tick labels for a 0..vmax x-scale."""
    out = []
    for i in range(n + 1):
        v = vmax * i / n
        x = x0 + (x1 - x0) * (i / n)
        out.append(f'<line class="{"axisline" if i == 0 else "gridline"}" '
                   f'x1="{x:.1f}" y1="{y0}" x2="{x:.1f}" y2="{y1}"/>')
        out.append(f'<text class="tick" x="{x:.1f}" y="{y1 + 14}" '
                   f'text-anchor="middle">{fmt(v)}</text>')
    return "".join(out)


def hbar_chart(rows: Sequence[Tuple[str, float, str, str]],
               unit: str = "s", label_w: int = 300) -> str:
    """Horizontal bars: rows of (label, value, color, tooltip)."""
    if not rows:
        return ""
    bar_w, bar_h, gap = 340, 16, 8
    vmax = max(v for _, v, _, _ in rows) or 1.0
    h = len(rows) * (bar_h + gap) + 30
    w = label_w + bar_w + 90
    parts = [f'<svg viewBox="0 0 {w} {h}" role="img">']
    parts.append(_xticks_grid(label_w, label_w + bar_w, 0,
                              h - 24, vmax))
    y = 4
    for label, v, color, tip in rows:
        bw = bar_w * v / vmax
        parts.append(
            f'<g class="mark"><title>{_e(tip or label)}</title>'
            f'<text x="{label_w - 8}" y="{y + bar_h - 4}" '
            f'text-anchor="end">{_e(label)}</text>'
            f'<rect x="{label_w}" y="{y}" width="{max(bw, 1):.1f}" '
            f'height="{bar_h}" rx="3" fill="{color}"/>'
            f'<text class="val" x="{label_w + max(bw, 1) + 6:.1f}" '
            f'y="{y + bar_h - 4}">{_fmt(v)}{_e(unit)}</text></g>')
        y += bar_h + gap
    parts.append("</svg>")
    return "".join(parts)


def stacked_hbar_chart(rows: Sequence[Tuple[str, List[Tuple[str, float]],
                                            str]],
                       label_w: int = 300) -> str:
    """Stacked horizontal bars: (label, [(segment key, value)...], tip);
    segments wear the fixed phase slots with a 2px surface gap."""
    if not rows:
        return ""
    bar_w, bar_h, gap = 340, 16, 8
    vmax = max(sum(v for _, v in segs) for _, segs, _ in rows) or 1.0
    h = len(rows) * (bar_h + gap) + 30
    w = label_w + bar_w + 90
    parts = [f'<svg viewBox="0 0 {w} {h}" role="img">']
    parts.append(_xticks_grid(label_w, label_w + bar_w, 0, h - 24, vmax))
    y = 4
    for label, segs, tip in rows:
        total = sum(v for _, v in segs)
        parts.append(f'<g class="mark"><title>{_e(tip or label)}</title>'
                     f'<text x="{label_w - 8}" y="{y + bar_h - 4}" '
                     f'text-anchor="end">{_e(label)}</text>')
        x = float(label_w)
        for sk, v in segs:
            sw = bar_w * v / vmax
            slot = _PHASE_SLOT.get(sk, 4)
            parts.append(
                f'<rect x="{x:.1f}" y="{y}" '
                f'width="{max(sw - 2, 0.5):.1f}" height="{bar_h}" '
                f'fill="{_slot(slot - 1)}"><title>'
                f'{_e(_PHASE_LABEL.get(sk, sk))}: {_fmt(v)}s</title>'
                f'</rect>')
            x += sw
        parts.append(f'<text class="val" x="{x + 6:.1f}" '
                     f'y="{y + bar_h - 4}">{_fmt(total)}s</text></g>')
        y += bar_h + gap
    parts.append("</svg>")
    return "".join(parts)


def line_chart(series: Sequence[Tuple[str, List[Tuple[float, float]]]],
               x_label: str, y_label: str = "wall (s)") -> str:
    """Lines over a shared numeric x: (label, [(x, y)...]) per series."""
    series = [(lbl, sorted(pts)) for lbl, pts in series if pts]
    if not series:
        return ""
    W, H, ml, mr, mt, mb = 640, 280, 56, 16, 12, 40
    xs = sorted({x for _, pts in series for x, _ in pts})
    ymax = max(y for _, pts in series for _, y in pts) or 1.0
    x0, x1 = min(xs), max(xs)
    span = (x1 - x0) or 1.0

    def sx(x):
        return ml + (W - ml - mr) * (x - x0) / span

    def sy(y):
        return mt + (H - mt - mb) * (1 - y / (ymax * 1.05))

    parts = [f'<svg viewBox="0 0 {W} {H}" role="img">']
    for i in range(5):
        yv = ymax * 1.05 * i / 4
        yy = sy(yv)
        cls = "axisline" if i == 0 else "gridline"
        parts.append(f'<line class="{cls}" x1="{ml}" y1="{yy:.1f}" '
                     f'x2="{W - mr}" y2="{yy:.1f}"/>')
        parts.append(f'<text class="tick" x="{ml - 6}" y="{yy + 4:.1f}" '
                     f'text-anchor="end">{_fmt(yv)}</text>')
    for x in xs:
        parts.append(f'<text class="tick" x="{sx(x):.1f}" '
                     f'y="{H - mb + 16}" text-anchor="middle">'
                     f'{_fmt(x)}</text>')
    parts.append(f'<text class="tick" x="{(ml + W - mr) / 2:.1f}" '
                 f'y="{H - 6}" text-anchor="middle">{_e(x_label)}</text>')
    for i, (lbl, pts) in enumerate(series):
        color = _slot(i)
        path = " ".join(f"{'M' if j == 0 else 'L'}{sx(x):.1f},{sy(y):.1f}"
                        for j, (x, y) in enumerate(pts))
        parts.append(f'<g class="mark"><title>{_e(lbl)}</title>'
                     f'<path d="{path}" fill="none" stroke="{color}" '
                     f'stroke-width="2"/>')
        for x, y in pts:
            parts.append(f'<circle cx="{sx(x):.1f}" cy="{sy(y):.1f}" '
                         f'r="4" fill="{color}"><title>{_e(lbl)}: '
                         f'{x_label}={_fmt(x)}, {y_label}={_fmt(y)}'
                         f'</title></circle>')
        parts.append("</g>")
    parts.append("</svg>")
    return "".join(parts)


# -- plan sections -------------------------------------------------------

_LADDERS = (("shards", "total shards H", lambda c: c["shards"]),
            ("nprocs", "processes P", lambda c: c["nprocs"]),
            ("grid", "grid columns",
             lambda c: int(c["grid"].split("x")[0]) *
             int(c["grid"].split("x")[1])))


def _series_label(cell: dict, ladder: str) -> str:
    parts = []
    for a, short in (("profile", ""), ("delivery", ""), ("exchange", ""),
                     ("exchange_schedule", ""), ("placement", None),
                     ("stim", None)):
        if a == ladder:
            continue
        v = cell[a]
        if short is None:       # only when non-default (keeps labels short)
            from .schema import AXIS_DEFAULTS
            if [v] == AXIS_DEFAULTS[a]:
                continue
        parts.append(str(v))
    for a, tag in (("grid", "g"), ("shards", "H"), ("nprocs", "P")):
        if a != ladder:
            parts.append(f"{tag}{cell[a]}")
    return " ".join(parts)


def scaling_section(records: List[dict]) -> str:
    """One line chart per ladder axis that actually varies."""
    out = []
    for axis, x_label, xval in _LADDERS:
        series: Dict[str, List[Tuple[float, float]]] = {}
        for rec in records:
            res = rec["result"]
            if "wall_s" not in res:
                continue
            lbl = _series_label(rec["cell"], axis)
            series.setdefault(lbl, []).append(
                (float(xval(rec["cell"])), float(res["wall_s"])))
        series = {lbl: pts for lbl, pts in series.items()
                  if len({x for x, _ in pts}) >= 2}
        if not series:
            continue
        labels = sorted(series)
        shown = labels[:_SLOTS]
        folded = len(labels) - len(shown)
        chart = line_chart([(lbl, series[lbl]) for lbl in labels],
                           x_label=x_label)
        legend = _legend([(lbl, _slot(i)) for i, lbl in
                          enumerate(shown)] +
                         ([(f"other ({folded})", "var(--muted)")]
                          if folded else []))
        cap = (f"fused wall per cell vs {x_label}"
               + (f"; {folded} series folded into 'other'" if folded
                  else ""))
        out.append(_figure(f"Scaling over {axis}", cap, legend + chart))
    return "".join(out)


def phase_section(records: List[dict]) -> str:
    rows = []
    for rec in records:
        res = rec["result"]
        segs = [(pk, float(res[pk])) for pk in PHASE_KEYS if pk in res]
        if segs:
            rows.append((rec["key"], segs,
                         f"{rec['key']} — per-phase wall over "
                         f"{res.get('phase_steps', '?')} steps"))
    if not rows:
        return ""
    legend = _legend([(_PHASE_LABEL[pk], _slot(_PHASE_SLOT[pk] - 1))
                      for pk in PHASE_KEYS])
    return _figure("Per-phase split (A / exchange / B)",
                   "paper Table 2: computation vs communication vs "
                   "arborization, per cell",
                   legend + stacked_hbar_chart(rows))


def hidden_exchange_section(records: List[dict]) -> str:
    """Pairs differing only in exchange_schedule: how much of the sync
    exchange wall the pipelined schedule hides."""
    by_key = {}
    for rec in records:
        c, res = rec["cell"], rec["result"]
        if "exchange_s" not in res:
            continue
        base = tuple((a, c[a]) for a in sorted(c)
                     if a in ("grid", "profile", "delivery", "exchange",
                              "placement", "shards", "nprocs", "stim"))
        by_key.setdefault(base, {})[c["exchange_schedule"]] = (
            rec["key"], float(res["exchange_s"]))
    rows = []
    for base, scheds in sorted(by_key.items()):
        if "sync" in scheds and "pipelined" in scheds:
            (_, sy), (pk, pi) = scheds["sync"], scheds["pipelined"]
            hidden = (sy - pi) / sy if sy else 0.0
            label = pk.replace("_pipelined", "")
            rows.append((label, max(hidden, 0.0), _slot(1),
                         f"sync {_fmt(sy)}s vs pipelined exposed "
                         f"{_fmt(pi)}s"))
    if not rows:
        return ""
    return _figure("Hidden exchange fraction",
                   "1 - exposed/sync exchange wall for schedule pairs "
                   "(higher = more communication hidden behind phase A)",
                   hbar_chart(rows, unit=""))


def time_per_event_section(records: List[dict]) -> str:
    rows = [(rec["key"], float(rec["result"]["time_per_syn_event_s"]),
             _slot(0),
             f"{rec['key']}: {rec['result']['time_per_syn_event_s']}s "
             f"per synaptic event "
             f"({rec['result'].get('spikes')} spikes)")
            for rec in records
            if "time_per_syn_event_s" in rec["result"]]
    if not rows:
        return ""
    return _figure("Time per synaptic event",
                   "the paper's normalized metric: fused wall / "
                   "(spikes x synapses per neuron)",
                   hbar_chart(rows))


def cells_table(records: List[dict]) -> str:
    if not records:
        return ""
    head = ("<tr><th>cell</th><th>H</th><th>P</th><th>wall s</th>"
            "<th>spikes</th><th>rate Hz</th><th>raster sig</th></tr>")
    rows = []
    for rec in records:
        c, res = rec["cell"], rec["result"]
        rows.append(
            f"<tr><td><code>{_e(rec['key'])}</code></td>"
            f"<td class='num'>{c['shards']}</td>"
            f"<td class='num'>{c['nprocs']}</td>"
            f"<td class='num'>{_fmt(res.get('wall_s', 0))}</td>"
            f"<td class='num'>{res.get('spikes', '')}</td>"
            f"<td class='num'>{res.get('rate_hz', '')}</td>"
            f"<td><code>{_e(str(res.get('raster_sig', ''))[:16])}</code>"
            f"</td></tr>")
    return (f"<h2>Cells</h2><table>{head}{''.join(rows)}</table>")


def identity_section(records: List[dict]) -> str:
    groups = identity_groups(records)
    multi = {g: d for g, d in groups.items() if len(d["cells"]) > 1}
    if not multi:
        return ""
    items = []
    for g, d in sorted(multi.items()):
        cls, mark = (("ok", "identical") if d["identical"]
                     else ("bad", "DIVERGED"))
        items.append(f"<li><code>{_e(g)}</code>: {len(d['cells'])} "
                     f"layout variants — <span class='{cls}'>{mark}"
                     f"</span></li>")
    return ("<h2>Table 1 invariant</h2><p class='sub'>cells sharing "
            "physics must spike identically under every execution "
            "layout</p><ul>" + "".join(items) + "</ul>")


def plan_history_section(prior: Sequence[Tuple[str, dict]],
                         records: List[dict]) -> str:
    """Plan-over-plan: each cell's fused wall charted across prior runs
    of THIS plan (committed/archived BENCH_plan_<name>.json reports) plus
    the current store — regression drift per cell at a glance."""
    runs: List[Tuple[str, Dict[str, float]]] = []
    for label, rep in prior:
        walls = {k[:-len("_wall_s")]: float(v)
                 for k, v in rep.get("wall", {}).items()
                 if k.endswith("_wall_s") and isinstance(v, (int, float))}
        if walls:
            runs.append((label, walls))
    cur = {rec["key"]: float(rec["result"]["wall_s"])
           for rec in records if "wall_s" in rec["result"]}
    if cur:
        runs.append(("current", cur))
    if len(runs) < 2:
        return ""
    cells = sorted({c for _, walls in runs for c in walls})
    series = []
    for c in cells:
        pts = [(float(i), walls[c]) for i, (_, walls) in enumerate(runs)
               if c in walls]
        if len(pts) >= 2:
            series.append((c, pts))
    if not series:
        return ""
    shown = series[:_SLOTS]
    folded = len(series) - len(shown)
    legend = _legend([(lbl, _slot(i)) for i, (lbl, _) in
                      enumerate(shown)] +
                     ([(f"other ({folded})", "var(--muted)")]
                      if folded else []))
    run_key = "; ".join(f"{i}={lbl}" for i, (lbl, _) in enumerate(runs))
    return _figure("Wall across plan runs",
                   f"fused wall per cell over prior runs of this plan "
                   f"({run_key})",
                   legend + line_chart(series, x_label="run"))


def history_section(history: Dict[str, dict]) -> str:
    """One wall-metric chart per committed BENCH suite report."""
    out = []
    for name in sorted(history):
        rep = history[name]
        wall = rep.get("wall", {})
        items = sorted(wall.items())
        dropped = max(len(items) - 24, 0)
        if dropped:
            items = items[:24]
        rows = [(k, float(v), _slot(0), f"{name}.{k} = {_fmt(v)}s")
                for k, v in items if isinstance(v, (int, float))]
        env = rep.get("env", {})
        cap = (f"jax {env.get('jax', '?')}, "
               f"{len(rep.get('deterministic', {}))} gated metrics"
               + (f"; first 24 of {len(wall)} wall metrics shown"
                  if dropped else ""))
        body = (hbar_chart(rows) if rows else
                "<p class='sub'>no wall metrics</p>")
        out.append(_figure(f"BENCH {name}", cap, body))
    if not out:
        return ""
    return "<h2>Committed benchmark history</h2>" + "".join(out)


def render(plan_config: dict, records: List[dict],
           history: Optional[Dict[str, dict]] = None,
           summary: Optional[dict] = None,
           prior_reports: Optional[Sequence[Tuple[str, dict]]] = None
           ) -> str:
    """Full dashboard HTML (self-contained, inline-SVG, no scripts)."""
    name = plan_config.get("name", "plan")
    n_axes = {a: len(v) for a, v in plan_config.get("axes", {}).items()
              if len(v) > 1}
    sub = (f"{len(records)} cells; swept axes: "
           f"{json.dumps(n_axes) if n_axes else 'none'}")
    if summary:
        sub += (f" — last run: {summary.get('executed', 0)} executed, "
                f"{summary.get('skipped', 0)} skipped, "
                f"{summary.get('failed', 0)} failed")
    body = [
        f"<h1>Experiment plan: {_e(name)}</h1>",
        f"<p class='sub'>{_e(sub)}</p>",
        scaling_section(records),
        plan_history_section(prior_reports or (), records),
        phase_section(records),
        hidden_exchange_section(records),
        time_per_event_section(records),
        identity_section(records),
        cells_table(records),
        history_section(history or {}),
    ]
    return ("<!DOCTYPE html><html><head><meta charset='utf-8'>"
            f"<title>repro experiment plan: {_e(name)}</title>"
            f"<style>{_CSS}</style></head>"
            f"<body class='viz-root'>{''.join(body)}</body></html>")


def write(path: str, plan_config: dict, records: List[dict],
          history: Optional[Dict[str, dict]] = None,
          summary: Optional[dict] = None,
          prior_reports: Optional[Sequence[Tuple[str, dict]]] = None
          ) -> str:
    with open(path, "w") as f:
        f.write(render(plan_config, records, history=history,
                       summary=summary, prior_reports=prior_reports))
    return path
