"""Merge completed plan cells into a gateable `BENCH_plan_<name>.json`.

The merged report rides the existing `repro.bench.report` schema
(version 1), so the committed comparator — and therefore CI — gates plan
results exactly like any other suite:

  deterministic   per-cell spike totals and raster signatures, plus one
                  `identical_<physics group>` flag per group of cells
                  that share physics but differ in execution layout
                  (shards, processes, exchange, schedule, placement,
                  delivery) — the paper's Table 1 invariant as data;
  wall            per-cell fused wall + per-phase A/exchange/B splits
                  (tolerance-compared, never a hard failure);
  config          the plan document itself (env-independent, so two
                  machines running the same committed plan compare);
  extra           full cell records + the runner summary, for dashboards
                  and humans.

A report over an incomplete store is refused unless `allow_partial`
(a partial report would gate as "metric missing" failures downstream and
mask the real problem: unfinished cells).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .. import report as bench_report
from .expand import expand
from .schema import Plan, PlanError
from .store import ResultStore

PHASE_KEYS = ("phase_a_s", "exchange_s", "phase_b_s")


def collect(plan: Plan, store: ResultStore,
            env: Optional[dict] = None) -> Tuple[List[dict], List[str]]:
    """(completed records in plan order, missing cell keys)."""
    cells, _ = expand(plan, env=env)
    records, missing = [], []
    for cell in cells:
        rec = store.load_cell(cell["key"])
        if rec is None or rec.get("hash") != cell["hash"]:
            missing.append(cell["key"])
        else:
            records.append(rec)
    return records, missing


def identity_groups(records: List[dict]) -> Dict[str, dict]:
    """physics_group -> {cells, sigs, identical}: the Table 1 invariant
    across every execution-layout variant the plan swept."""
    groups: Dict[str, dict] = {}
    for rec in records:
        g = rec["cell"].get("physics_group", "ungrouped")
        d = groups.setdefault(g, dict(cells=[], sigs=set()))
        d["cells"].append(rec["key"])
        sig = rec["result"].get("raster_sig")
        if sig:
            d["sigs"].add(sig)
    for d in groups.values():
        d["identical"] = len(d["sigs"]) <= 1
        d["sigs"] = sorted(d["sigs"])
    return groups


def merged_report(plan: Plan, records: List[dict],
                  summary: Optional[dict] = None) -> dict:
    """Cell records -> BENCH-schema report named `plan_<plan name>`."""
    deterministic, wall = {}, {}
    for rec in records:
        key, res = rec["key"], rec["result"]
        if "spikes" in res:
            deterministic[f"{key}_spikes"] = int(res["spikes"])
        if "raster_sig" in res:
            deterministic[f"{key}_sig"] = str(res["raster_sig"])
        if "saturated" in res:
            deterministic[f"{key}_saturated"] = int(res["saturated"])
        if "wall_s" in res:
            wall[f"{key}_wall_s"] = res["wall_s"]
        for pk in PHASE_KEYS:
            if pk in res:
                wall[f"{key}_{pk}"] = res[pk]

    groups = identity_groups(records)
    for g, d in sorted(groups.items()):
        if len(d["cells"]) > 1:
            deterministic[f"identical_{g}"] = bool(d["identical"])

    extra = dict(cells=[dict(key=r["key"], hash=r["hash"], cell=r["cell"],
                             result=r["result"],
                             elapsed_s=r.get("elapsed_s"))
                        for r in records],
                 groups={g: dict(cells=d["cells"], sigs=d["sigs"],
                                 identical=d["identical"])
                         for g, d in groups.items()})
    if summary is not None:
        extra["summary"] = summary
    return bench_report.make_report(f"plan_{plan.name}", plan.to_config(),
                                    deterministic, wall, extra=extra)


def load_plan_history(d: str, plan_name: str) -> List[Tuple[str, dict]]:
    """Prior merged reports of THIS plan, in filename order: every
    `BENCH_*.json` under `d` whose report name is `plan_<plan_name>`
    (committed baselines and archived runs alike).  Feeds the dashboard's
    plan-over-plan section — one (label, report) per prior run."""
    import os

    out: List[Tuple[str, dict]] = []
    if not d or not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if not (fn.startswith("BENCH_") and fn.endswith(".json")):
            continue
        rep = bench_report.load(os.path.join(d, fn))
        if rep.get("name") == f"plan_{plan_name}":
            out.append((fn[len("BENCH_"):-len(".json")], rep))
    return out


def write_report(plan: Plan, out_root: str, *,
                 allow_partial: bool = False,
                 env: Optional[dict] = None) -> Tuple[str, dict]:
    """Merge the store into BENCH_plan_<name>.json inside the store dir;
    returns (path, report).  Raises PlanError when cells are missing and
    `allow_partial` is not set."""
    store = ResultStore(out_root, plan.name)
    records, missing = collect(plan, store, env=env)
    if missing and not allow_partial:
        raise PlanError(
            [f"{len(missing)} of {len(missing) + len(records)} cells "
             f"have no (current) result — run `plan run`/`plan resume` "
             f"first, or pass --partial for a provisional report"]
            + [f"missing: {k}" for k in missing[:10]])
    rep = merged_report(plan, records, summary=store.load_summary())
    path = bench_report.save(rep, store.root)
    return path, rep
