"""Plan expansion: axes product -> executable cells, with exclusions.

A *cell* is one fully-resolved experiment: every axis pinned to one value
plus the plan's workload and budget knobs flattened in.  Expansion is the
grid product over `Plan.axes` in canonical axis order, minus

  structural rules (always on):
    - `shards % nprocs != 0` — the cluster launcher places H/P devices
      per process, so the division must be exact;
    - `exchange == 'hier'` with `nprocs < 2` — the two-level exchange
      derives its groups from the per-process device blocks, so it needs
      at least two real process groups;

  user excludes: an entry `{axis: value-or-list, ...}` drops every cell
  matching ALL of its constraints (value in list).

Every surviving cell gets a stable human-readable `key` (used as result
file name and report metric prefix) and a `hash` over (schema version,
cell knobs, code-relevant env) — the resume fingerprint: a completed
result file whose hash matches is skipped, one whose hash differs (other
jax version, edited plan) is stale and re-executed.

`physics_group` names the subset of knobs that define the simulation's
trajectory (grid geometry, profile, stimulus, seed, sizes, steps).  Cells
in one group differ only by execution layout — shards, processes,
exchange wire, schedule, placement, delivery backend — so the paper's
Table 1 invariant says their rasters must be bit-identical; the reporter
gates exactly that.
"""
from __future__ import annotations

import hashlib
import itertools
import json
from typing import Dict, List, Tuple

from .schema import AXES, SCHEMA_VERSION, STIM_REGIMES, Plan, PlanError

# cell fields whose change must invalidate a stored result (everything
# that feeds the subprocess, minus pure-budget knobs like timeout_s)
_HASHED_FIELDS = AXES + ("neurons_per_column", "synapses_per_neuron",
                         "steps", "phase_steps", "seed", "reps",
                         "stim_events", "stim_amplitude")

# fields that pin the physics (the Table 1 invariant group); everything
# else is execution layout and must not change the raster.  `connectivity`
# (table residency) is deliberately NOT here: streamed and materialized
# cells share a physics group, so the reporter's bit-identity gate covers
# the streamed-regeneration invariant for free.
PHYSICS_FIELDS = ("grid", "profile", "stim", "seed", "neurons_per_column",
                  "synapses_per_neuron", "steps")


def runtime_env() -> dict:
    """The code-relevant environment folded into cell hashes: jax version
    + backend decide numerics and HLO, so a bump re-runs every cell."""
    import jax
    return dict(jax=jax.__version__, backend=jax.default_backend())


def cell_key(cell: dict) -> str:
    """Filesystem/report-safe unique cell name in canonical axis order."""
    def safe(v):
        return "".join(c if c.isalnum() else "-" for c in str(v))

    return (f"{safe(cell['profile'])}_{safe(cell['connectivity'])}"
            f"_{cell['delivery']}"
            f"_{cell['exchange']}_{cell['exchange_schedule']}"
            f"_{cell['placement']}_h{cell['shards']}p{cell['nprocs']}"
            f"_g{cell['grid']}_{cell['stim']}")


def cell_hash(cell: dict, env: dict) -> str:
    doc = dict(schema_version=SCHEMA_VERSION,
               cell={k: cell[k] for k in _HASHED_FIELDS}, env=dict(env))
    blob = json.dumps(doc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def physics_group(cell: dict) -> str:
    """Readable label of the physics knobs (used as a report metric name:
    cells sharing it must produce bit-identical rasters)."""
    prof = "".join(c if c.isalnum() else "-" for c in str(cell["profile"]))
    return (f"g{cell['grid']}-{prof}-{cell['stim']}-s{cell['seed']}"
            f"-n{cell['neurons_per_column']}x{cell['synapses_per_neuron']}"
            f"-t{cell['steps']}")


def _matches(cell: dict, entry: Dict[str, list]) -> bool:
    return all(cell.get(k) in vals for k, vals in entry.items())


def _structural_reason(cell: dict) -> str:
    if cell["shards"] % cell["nprocs"]:
        return (f"shards {cell['shards']} not divisible by nprocs "
                f"{cell['nprocs']}")
    if cell["exchange"] == "hier" and cell["nprocs"] < 2:
        return "exchange='hier' needs >= 2 process groups"
    if cell["delivery"] == "event" and cell["connectivity"] != \
            "materialized":
        return ("delivery='event' requires connectivity='materialized' "
                "(event row tables are an O(E) synapse-id permutation)")
    return ""


def expand(plan: Plan, env: dict = None) -> Tuple[List[dict], List[dict]]:
    """Plan -> (cells, excluded).

    `cells` carry every axis value + workload + budgets + `key`/`hash`/
    `physics_group`; `excluded` records each dropped combination with its
    reason so a sweep can never silently shrink.  Raises PlanError on
    duplicate keys/hashes or an empty expansion.
    """
    env = env if env is not None else runtime_env()
    cells, excluded = [], []
    for combo in itertools.product(*(plan.axes[a] for a in AXES)):
        cell = dict(zip(AXES, combo))
        cell.update(plan.workload)
        cell["reps"] = plan.budgets["reps"]
        ev, amp = STIM_REGIMES[cell["stim"]]
        cell["stim_events"], cell["stim_amplitude"] = ev, amp

        reason = _structural_reason(cell)
        if not reason:
            for entry in plan.exclude:
                if _matches(cell, entry):
                    reason = f"excluded by {json.dumps(entry)}"
                    break
        if reason:
            excluded.append(dict(cell=dict(cell), reason=reason))
            continue
        cell["key"] = cell_key(cell)
        cell["hash"] = cell_hash(cell, env)
        cell["physics_group"] = physics_group(cell)
        cells.append(cell)

    errs = []
    if not cells:
        errs.append("plan expands to zero cells (everything excluded?)")
    seen_keys, seen_hashes = set(), set()
    for c in cells:
        if c["key"] in seen_keys:
            errs.append(f"duplicate cell key after expansion: {c['key']} "
                        f"(axis values collide after sanitizing)")
        if c["hash"] in seen_hashes:
            errs.append(f"duplicate cell hash after expansion: "
                        f"{c['hash']} ({c['key']})")
        seen_keys.add(c["key"])
        seen_hashes.add(c["hash"])
    if errs:
        raise PlanError(errs)
    return cells, excluded
