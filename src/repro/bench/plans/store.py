"""Resumable result store: one JSON file per completed plan cell.

Layout (under `<out_root>/<plan name>/`):

  cells/<cell key>.json       one completed cell: {key, hash, cell, env,
                              result, elapsed_s}
  last_run_summary.json       the most recent runner exit summary
  BENCH_plan_<name>.json      merged report (written by the reporter)
  dashboard.html              static dashboard (written by the reporter)

Resume is file-existence + fingerprint: a cell whose file exists AND
whose stored `hash` equals the freshly-computed one is complete and is
skipped; a missing file or a stale hash (plan edited, jax bumped) means
the cell runs (again) and the file is atomically replaced.  Failed cells
never write a file, so an interrupted or partially-failed run resumes by
re-executing exactly the unfinished cells.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, List, Optional

SUMMARY_FILE = "last_run_summary.json"


def _atomic_write_json(path: str, doc: dict) -> None:
    """Write-then-rename so an interrupt mid-write can never leave a
    half-written 'completed' cell behind."""
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class ResultStore:
    def __init__(self, out_root: str, plan_name: str):
        self.root = os.path.join(out_root, plan_name)
        self.cells_dir = os.path.join(self.root, "cells")

    def exists(self) -> bool:
        return os.path.isdir(self.cells_dir)

    def cell_path(self, key: str) -> str:
        return os.path.join(self.cells_dir, f"{key}.json")

    def completed(self, key: str, hash_: str) -> bool:
        """True iff a result for `key` exists with a matching
        fingerprint (stale results don't count as done)."""
        rec = self.load_cell(key)
        return rec is not None and rec.get("hash") == hash_

    def load_cell(self, key: str) -> Optional[dict]:
        path = self.cell_path(key)
        if not os.path.isfile(path):
            return None
        try:
            with open(path) as f:
                return json.load(f)
        except (json.JSONDecodeError, OSError):
            return None          # corrupt/partial file == not completed

    def save_cell(self, record: dict) -> str:
        path = self.cell_path(record["key"])
        _atomic_write_json(path, record)
        return path

    def drop_cell(self, key: str) -> bool:
        path = self.cell_path(key)
        if os.path.isfile(path):
            os.unlink(path)
            return True
        return False

    def load_results(self) -> List[dict]:
        """Every stored cell record, sorted by key."""
        out = []
        if not self.exists():
            return out
        for fn in sorted(os.listdir(self.cells_dir)):
            if fn.endswith(".json"):
                rec = self.load_cell(fn[:-len(".json")])
                if rec is not None:
                    out.append(rec)
        return out

    # -- runner exit summary --------------------------------------------

    def save_summary(self, summary: Dict) -> str:
        path = os.path.join(self.root, SUMMARY_FILE)
        _atomic_write_json(path, summary)
        return path

    def load_summary(self) -> Optional[dict]:
        path = os.path.join(self.root, SUMMARY_FILE)
        if not os.path.isfile(path):
            return None
        with open(path) as f:
            return json.load(f)
