"""Experiment-plan schema: declarative sweep files -> validated `Plan`.

A plan is a committed YAML/JSON document (benchmarks/plans/*.yaml)
declaring the paper's experiment grid as data instead of hand-run
commands:

  name: quick                     # -> BENCH_plan_quick.json
  workload:                       # physics base, shared by every cell
    neurons_per_column: 40
    synapses_per_neuron: 16
    steps: 40
    phase_steps: 10               # 0 skips the per-phase split
    seed: 7
  axes:                           # grid product over ALL axes
    grid: [2x2]                   # problem-size ladder ("GXxGY")
    profile: [ring3, ring1]       # lateral connectivity (core.profiles)
    connectivity: [materialized]  # table residency (or streamed:chunk=K)
    delivery: [dense, event]
    exchange: [halo, allgather, hier]
    exchange_schedule: [sync, pipelined]
    shards: [1, 2]                # total logical shards H
    nprocs: [1, 2]                # OS processes (repro.cluster when > 1)
    stim: [default]               # named stimulus regime (STIM_REGIMES)
  exclude:                        # drop cells matching EVERY entry key
    - {nprocs: 2, exchange: allgather}
  budgets:
    timeout_s: 600                # per-cell subprocess timeout
    reps: 1                       # fused-wall repetitions (min is kept)

Validation is strict — unknown keys, out-of-domain axis values, duplicate
axis values, exclude entries that can never match, and duplicate expanded
cells are all hard errors (`PlanError` carries the full list) — because a
plan file is reviewed config: a typo silently shrinking the sweep is worse
than a failing load.

The loader reads YAML when PyYAML is available (it is in the CI images;
`pip install pyyaml` otherwise) and always reads JSON, so the format never
becomes a hard dependency of the bench package.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

# name -> (stim_events_per_ms_per_column, stim_amplitude): the paper's
# thalamic stimulus knob as reviewable regimes instead of free floats.
STIM_REGIMES: Dict[str, tuple] = {
    "default": (1, 20.0),        # paper default: 1 event/ms/column
    "quiet": (0, 20.0),          # no external drive (recurrent only)
    "strong": (2, 20.0),         # doubled event rate (sparse/dense flip)
}

AXIS_DOMAINS = {
    "delivery": ("dense", "event"),
    "exchange": ("allgather", "halo", "hier"),
    "exchange_schedule": ("sync", "pipelined"),
    "placement": ("block", "scatter"),
    "stim": tuple(STIM_REGIMES),
}

# canonical axis order: cell keys, expansion order and hashes all follow it
AXES = ("grid", "profile", "connectivity", "delivery", "exchange",
        "exchange_schedule", "placement", "shards", "nprocs", "stim")

AXIS_DEFAULTS = {
    "grid": ["2x2"], "profile": ["ring3"],
    "connectivity": ["materialized"], "delivery": ["dense"],
    "exchange": ["allgather"], "exchange_schedule": ["sync"],
    "placement": ["block"], "shards": [1], "nprocs": [1],
    "stim": ["default"],
}

WORKLOAD_DEFAULTS = {
    "neurons_per_column": 100,
    "synapses_per_neuron": 40,
    "steps": 60,
    "phase_steps": 0,
    "seed": 2013,
}

BUDGET_DEFAULTS = {
    "timeout_s": None,           # None -> repro.bench.subproc default
    "reps": 1,
}

_GRID_RE = re.compile(r"^(\d+)x(\d+)$")


class PlanError(ValueError):
    """Plan failed validation; `errors` is the full list."""

    def __init__(self, errors: List[str]):
        self.errors = list(errors)
        super().__init__("invalid experiment plan:\n  " +
                         "\n  ".join(self.errors))


@dataclasses.dataclass(frozen=True)
class Plan:
    name: str
    workload: dict
    axes: dict                   # axis -> list of values (all axes present)
    exclude: tuple               # tuple of {axis: [values...]} matchers
    budgets: dict
    description: str = ""

    def to_config(self) -> dict:
        """JSON round-trippable view for the BENCH report config section
        (env-independent: two machines running the same plan compare)."""
        return dict(schema_version=SCHEMA_VERSION, name=self.name,
                    workload=dict(self.workload),
                    axes={a: list(v) for a, v in self.axes.items()},
                    exclude=[{k: list(v) for k, v in e.items()}
                             for e in self.exclude],
                    budgets=dict(self.budgets))


def _listify(v) -> list:
    return list(v) if isinstance(v, (list, tuple)) else [v]


def _check_axis_value(axis: str, v, errs: List[str]) -> None:
    if axis in AXIS_DOMAINS:
        if v not in AXIS_DOMAINS[axis]:
            errs.append(f"axes.{axis}: {v!r} not in "
                        f"{list(AXIS_DOMAINS[axis])}")
    elif axis == "grid":
        m = _GRID_RE.match(str(v))
        if not m or int(m.group(1)) < 1 or int(m.group(2)) < 1:
            errs.append(f"axes.grid: {v!r} is not 'GXxGY' with positive "
                        f"integers")
    elif axis in ("shards", "nprocs"):
        if not isinstance(v, int) or isinstance(v, bool) or v < 1:
            errs.append(f"axes.{axis}: {v!r} must be a positive int")
    elif axis == "profile":
        try:
            from ...core import profiles
            profiles.parse(str(v))
        except Exception as e:
            errs.append(f"axes.profile: {v!r} rejected by "
                        f"core.profiles.parse: {e}")
    elif axis == "connectivity":
        try:
            from ...core import connectivity
            connectivity.parse_mode(str(v))
        except Exception as e:
            errs.append(f"axes.connectivity: {v!r} rejected by "
                        f"core.connectivity.parse_mode: {e}")


def validate(doc: dict, name_hint: Optional[str] = None) -> Plan:
    """Raw dict -> Plan; raises PlanError with every problem found."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        raise PlanError(["plan document must be a mapping, got "
                         f"{type(doc).__name__}"])
    unknown = set(doc) - {"name", "description", "workload", "axes",
                          "exclude", "budgets"}
    if unknown:
        errs.append(f"unknown top-level keys: {sorted(unknown)}")

    name = doc.get("name", name_hint)
    if not isinstance(name, str) or not re.match(r"^[A-Za-z0-9_\-]+$",
                                                 name or ""):
        errs.append(f"name must be a [A-Za-z0-9_-]+ string, got {name!r}")

    workload = dict(WORKLOAD_DEFAULTS)
    wl = doc.get("workload", {}) or {}
    if not isinstance(wl, dict):
        errs.append("workload must be a mapping")
        wl = {}
    for k, v in wl.items():
        if k not in WORKLOAD_DEFAULTS:
            errs.append(f"workload.{k}: unknown key (known: "
                        f"{sorted(WORKLOAD_DEFAULTS)})")
        elif not isinstance(v, int) or isinstance(v, bool) or v < 0:
            errs.append(f"workload.{k}: {v!r} must be a non-negative int")
        else:
            workload[k] = v

    axes = {a: list(AXIS_DEFAULTS[a]) for a in AXES}
    ax = doc.get("axes", {}) or {}
    if not isinstance(ax, dict):
        errs.append("axes must be a mapping")
        ax = {}
    for a, vals in ax.items():
        if a not in AXES:
            errs.append(f"axes.{a}: unknown axis (known: {list(AXES)})")
            continue
        vals = _listify(vals)
        if not vals:
            errs.append(f"axes.{a}: empty value list")
            continue
        seen = set()
        for v in vals:
            _check_axis_value(a, v, errs)
            vk = json.dumps(v) if not isinstance(v, str) else v
            if vk in seen:
                errs.append(f"axes.{a}: duplicate value {v!r} (would "
                            f"expand to duplicate cells)")
            seen.add(vk)
        axes[a] = vals

    exclude = []
    exc = doc.get("exclude", []) or []
    if not isinstance(exc, list):
        errs.append("exclude must be a list of axis->value mappings")
        exc = []
    for i, entry in enumerate(exc):
        if not isinstance(entry, dict) or not entry:
            errs.append(f"exclude[{i}]: must be a non-empty mapping")
            continue
        norm = {}
        for k, v in entry.items():
            if k not in AXES:
                errs.append(f"exclude[{i}].{k}: unknown axis")
                continue
            vals = _listify(v)
            for vv in vals:
                _check_axis_value(k, vv, errs)
            norm[k] = vals
        if norm:
            exclude.append(norm)

    budgets = dict(BUDGET_DEFAULTS)
    bd = doc.get("budgets", {}) or {}
    if not isinstance(bd, dict):
        errs.append("budgets must be a mapping")
        bd = {}
    for k, v in bd.items():
        if k not in BUDGET_DEFAULTS:
            errs.append(f"budgets.{k}: unknown key (known: "
                        f"{sorted(BUDGET_DEFAULTS)})")
        elif k == "reps" and (not isinstance(v, int) or v < 1):
            errs.append(f"budgets.reps: {v!r} must be a positive int")
        elif k == "timeout_s" and v is not None and (
                not isinstance(v, (int, float)) or v <= 0):
            errs.append(f"budgets.timeout_s: {v!r} must be a positive "
                        f"number or null")
        else:
            budgets[k] = v

    if errs:
        raise PlanError(errs)
    return Plan(name=name, workload=workload, axes=axes,
                exclude=tuple(exclude), budgets=budgets,
                description=str(doc.get("description", "")))


def load(path: str) -> Plan:
    """Load + validate a plan file (.yaml/.yml via PyYAML, .json always)."""
    if not os.path.isfile(path):
        raise PlanError([f"plan file not found: {path}"])
    with open(path) as f:
        text = f.read()
    if path.endswith((".yaml", ".yml")):
        try:
            import yaml
        except ImportError as e:                      # pragma: no cover
            raise PlanError(
                [f"{path}: reading YAML plans needs PyYAML (pip install "
                 f"pyyaml) — or commit the plan as JSON"]) from e
        doc = yaml.safe_load(text)
    else:
        doc = json.loads(text)
    hint = os.path.splitext(os.path.basename(path))[0]
    return validate(doc, name_hint=hint)
