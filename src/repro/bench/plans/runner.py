"""Plan runner: execute expanded cells resumably, one result file each.

Single-process cells (`nprocs == 1`) run through the existing
fresh-interpreter machinery (`repro.bench.subproc`): each cell's
subprocess forces its own host device count (= shards), builds a
`StepProgram` on a real mesh and reports the fused wall, spike totals,
raster signature and — when the plan budgets phase steps — the per-phase
A/exchange/B split from `StepProgram.time_phases`.  Multi-process cells
delegate to `repro.cluster` (the same launcher+worker path the
cluster_scaling suite uses), so plan results and the committed BENCH
history stay directly comparable.

Every completed cell is persisted through `ResultStore` keyed by its
content hash; a second `run` (or `resume`) skips completed cells and the
exit summary counts executed/skipped/failed — CI re-runs the committed
quick plan and asserts `executed == 0` (the `--assert-complete` flag) to
prove resume end-to-end.  Failed cells are reported, leave no result
file, and make the runner exit nonzero; everything else still runs, so
one flaky point never costs the whole sweep.
"""
from __future__ import annotations

import json
import time
from typing import Callable, List, Optional

from .. import _summary
from .expand import expand, runtime_env
from .schema import Plan
from .store import ResultStore

# executed in a fresh interpreter with `shards` forced host devices; the
# cell dict is substituted as JSON (no .format: the source has braces)
_CELL_SRC = """
import json, time
import numpy as np
import jax
from repro.core import EngineConfig, GridConfig, StepProgram, observables
from repro.core import distributed as D

cell = json.loads(__CELL_JSON__)
gx, gy = (int(v) for v in cell["grid"].split("x"))
cfg = GridConfig(grid_x=gx, grid_y=gy,
                 neurons_per_column=cell["neurons_per_column"],
                 synapses_per_neuron=cell["synapses_per_neuron"],
                 seed=cell["seed"], connectivity=cell["profile"],
                 stim_events_per_ms_per_column=cell["stim_events"],
                 stim_amplitude=cell["stim_amplitude"])
eng = EngineConfig(n_shards=cell["shards"], exchange=cell["exchange"],
                   exchange_schedule=cell["exchange_schedule"],
                   placement=cell["placement"], delivery=cell["delivery"],
                   connectivity=cell["connectivity"])
sp = StepProgram(cfg, eng, mesh=D.make_mesh(cell["shards"]))
state = sp.place(sp.init_state())
jax.block_until_ready(sp.run(state, 0, cell["steps"])[1])      # compile
wall = None
for _ in range(cell["reps"]):
    t0 = time.perf_counter()
    state_f, raster, _ = sp.run(state, 0, cell["steps"])
    jax.block_until_ready(raster)
    w = time.perf_counter() - t0
    wall = w if wall is None else min(wall, w)
raster = np.asarray(raster)
res = dict(wall_s=round(wall, 4), spikes=int(raster.sum()),
           rate_hz=round(observables.mean_rate_hz(raster,
                                                  cfg.n_neurons), 3),
           raster_sig=observables.raster_signature(
               raster, np.asarray(sp.plan.gid)).hex())
if cell["delivery"] == "event":
    res["saturated"] = int(np.asarray(state_f.sat).sum())
if cell["phase_steps"]:
    _, times, _, counts = sp.time_phases(state, 0, cell["phase_steps"])
    res.update((k, round(v, 4)) for k, v in times.items())
    res["phase_steps"] = cell["phase_steps"]
    res["arrivals"] = int(counts["arrivals"])
print("PLAN_CELL " + json.dumps(res))
"""

RESULT_PREFIX = "PLAN_CELL "


class CellError(RuntimeError):
    pass


def _finalize(cell: dict, res: dict) -> dict:
    """Uniform derived metrics: the paper's normalized elapsed time per
    synaptic event (each spike fans out to synapses_per_neuron targets),
    computable identically for local and cluster cells."""
    events = res.get("spikes", 0) * cell["synapses_per_neuron"]
    if res.get("wall_s") and events:
        res["time_per_syn_event_s"] = float(
            f"{res['wall_s'] / events:.3e}")
    return res


def run_local_cell(cell: dict, timeout: Optional[float] = None) -> dict:
    """One fresh-interpreter cell on `cell['shards']` forced devices."""
    from ..subproc import run_subprocess
    code = _CELL_SRC.replace("__CELL_JSON__",
                             repr(json.dumps(cell, sort_keys=True)))
    out = run_subprocess(code, n_devices=cell["shards"], timeout=timeout)
    for line in out.splitlines():
        if line.startswith(RESULT_PREFIX):
            return _finalize(cell, json.loads(line[len(RESULT_PREFIX):]))
    raise CellError(f"no {RESULT_PREFIX!r} line in cell output:\n"
                    f"{out[-2000:]}")


def run_cluster_cell(cell: dict, timeout: Optional[float] = None) -> dict:
    """One real multi-process cell via the repro.cluster launcher."""
    from ...cluster import cli as cluster_cli
    row = cluster_cli.run_plan_cell(cell, timeout=timeout)
    keep = ("wall_s", "spikes", "rate_hz", "raster_sig", "saturated",
            "phase_a_s", "exchange_s", "phase_b_s", "per_proc")
    res = {k: row[k] for k in keep if k in row}
    if cell["phase_steps"]:
        res["phase_steps"] = cell["phase_steps"]
    return _finalize(cell, res)


def execute_cell(cell: dict, timeout: Optional[float] = None) -> dict:
    if cell["nprocs"] > 1:
        return run_cluster_cell(cell, timeout=timeout)
    return run_local_cell(cell, timeout=timeout)


def run_plan(plan: Plan, out_root: str, *,
             assert_complete: bool = False,
             executor: Optional[Callable[[dict], dict]] = None,
             env: Optional[dict] = None,
             log: Callable[[str], None] = print) -> dict:
    """Execute every incomplete cell of `plan`; returns the exit summary

      {plan, total, executed, skipped, failed, excluded, ok,
       executed_keys, skipped_keys, failed_keys}

    `ok` is False when any cell failed, or when `assert_complete` was set
    and anything had to execute (the CI resume proof).  `executor`
    overrides cell execution (tests inject fakes); `env` overrides the
    hash environment the same way.
    """
    env = env if env is not None else runtime_env()
    executor = executor or (
        lambda c: execute_cell(c, timeout=plan.budgets["timeout_s"]))
    cells, excluded = expand(plan, env=env)
    store = ResultStore(out_root, plan.name)

    executed, skipped, failed = [], [], []
    t_start = time.time()
    for i, cell in enumerate(cells):
        tag = f"[plan {plan.name}] cell {i + 1}/{len(cells)} {cell['key']}"
        if store.completed(cell["key"], cell["hash"]):
            skipped.append(cell["key"])
            log(f"{tag}: complete, skipping (hash {cell['hash']})")
            continue
        t0 = time.time()
        try:
            result = executor(cell)
        except Exception as e:
            failed.append(cell["key"])
            log(f"{tag}: FAILED after {time.time() - t0:.1f}s: "
                f"{str(e)[:500]}")
            continue
        record = dict(key=cell["key"], hash=cell["hash"], cell=cell,
                      env=env, result=result,
                      elapsed_s=round(time.time() - t0, 3))
        store.save_cell(record)
        executed.append(cell["key"])
        log(f"{tag}: done in {record['elapsed_s']}s "
            f"(wall {result.get('wall_s')}s, "
            f"sig {str(result.get('raster_sig'))[:16]})")

    summary = dict(plan=plan.name, total=len(cells),
                   executed=len(executed), skipped=len(skipped),
                   failed=len(failed), excluded=len(excluded),
                   executed_keys=executed, skipped_keys=skipped,
                   failed_keys=failed,
                   wall_s=round(time.time() - t_start, 3),
                   ok=not failed and not (assert_complete and executed))
    store.save_summary(summary)
    log(f"[plan {plan.name}] PLAN_SUMMARY " + json.dumps(
        {k: summary[k] for k in ("plan", "total", "executed", "skipped",
                                 "failed", "excluded", "ok")}))
    if assert_complete and executed:
        log(f"[plan {plan.name}] --assert-complete: {len(executed)} "
            f"cell(s) had to execute — resume did NOT cover the plan")
    _summary.append(_summary_markdown(plan, summary, excluded))
    return summary


def _summary_markdown(plan: Plan, summary: dict,
                      excluded: List[dict]) -> str:
    """Runner summary for the PR checks page ($GITHUB_STEP_SUMMARY)."""
    lines = [f"### experiment plan `{plan.name}`",
             "",
             f"| total | executed | skipped | failed | excluded |",
             f"|---|---|---|---|---|",
             f"| {summary['total']} | {summary['executed']} | "
             f"{summary['skipped']} | {summary['failed']} | "
             f"{summary['excluded']} |",
             ""]
    if summary["failed_keys"]:
        lines.append("failed cells: " + ", ".join(
            f"`{k}`" for k in summary["failed_keys"]))
    status = "resumed clean" if summary["executed"] == 0 else (
        f"{summary['executed']} executed")
    lines.append(f"outcome: **{'OK' if summary['ok'] else 'FAIL'}** "
                 f"({status}, {summary['wall_s']}s)")
    return "\n".join(lines)
