"""$GITHUB_STEP_SUMMARY writer: bench/plan outcomes on the checks page.

GitHub renders whatever a job appends to the file named by the
GITHUB_STEP_SUMMARY environment variable as markdown on the PR checks
page — so comparator verdicts and per-cell pass/fail are readable
without downloading artifacts.  Outside Actions the variable is unset
and `append` is a silent no-op, which keeps every caller unconditional.
"""
from __future__ import annotations

import os

ENV_VAR = "GITHUB_STEP_SUMMARY"


def append(markdown: str) -> bool:
    """Append a markdown block to the step summary; True if written."""
    path = os.environ.get(ENV_VAR)
    if not path:
        return False
    try:
        with open(path, "a") as f:
            f.write(markdown.rstrip() + "\n\n")
        return True
    except OSError:
        return False


def code_block(text: str, title: str = "") -> str:
    """Markdown helper: optional heading + fenced block."""
    head = f"### {title}\n\n" if title else ""
    return f"{head}```\n{text.rstrip()}\n```"
