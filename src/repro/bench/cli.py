"""`python -m repro.bench` — run benchmarks, write BENCH_<name>.json,
gate against committed baselines.

  python -m repro.bench list
  python -m repro.bench run [names...] [--quick] [--all] [--out DIR]
  python -m repro.bench compare [names...] [--current DIR]
                                [--baseline DIR] [--wall-tol F]
  python -m repro.bench plan run|resume PLANFILE [--out DIR]
                                [--assert-complete]
  python -m repro.bench plan report PLANFILE [--out DIR]
                                [--history DIR] [--partial]
  python -m repro.bench plan expand PLANFILE

`run` with no names executes every non-slow suite; `compare` exits
nonzero on any deterministic drift (see repro.bench.report for the
policy), which is what the CI bench job gates on.  `plan` commands drive
config-driven experiment plans (repro.bench.plans): `run` executes every
incomplete cell of the plan (so it doubles as resume; `resume` insists
prior results exist), `report` merges cell results into a gateable
BENCH_plan_<name>.json plus a static HTML dashboard, and `expand` prints
the cell list without running anything.  Inside GitHub Actions the
compare and plan commands also append their summaries to
$GITHUB_STEP_SUMMARY.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import _summary, registry, report

DEFAULT_OUT = "results/bench"
DEFAULT_BASELINES = "benchmarks/baselines"
DEFAULT_PLAN_OUT = "results/plans"


def _cmd_list(args) -> int:
    for name in sorted(registry.BENCHES):
        e = registry.BENCHES[name]
        tag = " [slow]" if e.slow else ""
        print(f"{name:16s}{tag:7s} {e.doc}")
    return 0


def _cmd_run(args) -> int:
    names = args.names or registry.default_names(include_slow=args.all)
    failures = []
    for name in names:
        print(f"\n===== {name} =====", flush=True)
        try:
            rep = registry.get(name).fn(args.quick)
            path = report.save(rep, args.out)
            print(f"[bench] wrote {path} "
                  f"({len(rep['deterministic'])} deterministic, "
                  f"{len(rep['wall'])} wall metrics)", flush=True)
        except Exception as e:
            failures.append(name)
            print(f"[bench] {name} FAILED: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"\nFAILURES: {failures}")
        return 1
    print(f"\nall {len(names)} benchmark suite(s) completed")
    return 0


def _cmd_compare(args) -> int:
    res = report.compare_dirs(args.current, args.baseline,
                              names=args.names or None,
                              wall_tol=args.wall_tol)
    rendered = res.render()
    print(rendered)
    _summary.append(_summary.code_block(
        rendered, title=f"bench compare ({args.current} vs "
                        f"{args.baseline})"))
    return 0 if res.ok else 1


def _cmd_plan(args) -> int:
    from . import plans

    try:
        plan = plans.load(args.plan)
    except plans.PlanError as e:
        print(e)
        return 2

    if args.plan_cmd == "expand":
        cells, excluded = plans.expand(plan)
        for c in cells:
            print(f"{c['key']}  hash={c['hash']}  "
                  f"group={c['physics_group']}")
        for ex in excluded:
            print(f"EXCLUDED  {plans.cell_key(ex['cell'])}: "
                  f"{ex['reason']}")
        print(f"{len(cells)} cell(s), {len(excluded)} excluded")
        return 0

    store = plans.ResultStore(args.out, plan.name)
    if args.plan_cmd in ("run", "resume"):
        if args.plan_cmd == "resume" and not store.exists():
            print(f"[plan {plan.name}] nothing to resume under "
                  f"{store.root} — use `plan run`")
            return 2
        summary = plans.run_plan(
            plan, args.out,
            assert_complete=getattr(args, "assert_complete", False))
        return 0 if summary["ok"] else 1

    # report: merged BENCH json + dashboard
    try:
        path, rep = plans.write_report(plan, args.out,
                                       allow_partial=args.partial)
    except plans.PlanError as e:
        print(e)
        return 1
    print(f"[plan {plan.name}] wrote {path} "
          f"({len(rep['deterministic'])} deterministic, "
          f"{len(rep['wall'])} wall metrics)")

    from .plans import dashboard as dash
    records = rep["extra"]["cells"]
    history = report.load_dir(args.history) if args.history else {}
    prior = plans.load_plan_history(args.plan_history, plan.name)
    html_path = dash.write(
        f"{store.root}/dashboard.html", plan.to_config(), records,
        history=history, summary=store.load_summary(),
        prior_reports=prior)
    print(f"[plan {plan.name}] wrote {html_path} "
          f"({len(records)} cells, {len(history)} history suites, "
          f"{len(prior)} prior plan runs)")

    bad = [g for g, d in rep["extra"]["groups"].items()
           if not d["identical"]]
    if bad:
        print(f"[plan {plan.name}] Table 1 invariant VIOLATED in "
              f"group(s): {bad}")
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.bench",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="list registered benchmark suites")

    rp = sub.add_parser("run", help="run suites, write BENCH_*.json")
    rp.add_argument("names", nargs="*",
                    help="suite names (default: all non-slow)")
    rp.add_argument("--quick", action="store_true",
                    help="CI-sized grids/steps")
    rp.add_argument("--all", action="store_true",
                    help="include slow (subprocess) suites in the default "
                         "set")
    rp.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output directory (default {DEFAULT_OUT})")

    cp = sub.add_parser("compare",
                        help="gate current reports against baselines")
    cp.add_argument("names", nargs="*",
                    help="suite names (default: every baseline present)")
    cp.add_argument("--current", default=DEFAULT_OUT,
                    help=f"directory with fresh reports "
                         f"(default {DEFAULT_OUT})")
    cp.add_argument("--baseline", default=DEFAULT_BASELINES,
                    help=f"committed baseline directory "
                         f"(default {DEFAULT_BASELINES})")
    cp.add_argument("--wall-tol", type=float, default=0.5,
                    help="relative wall-clock warn threshold "
                         "(default 0.5 = ±50%%)")

    pp = sub.add_parser("plan",
                        help="config-driven experiment plans "
                             "(run/resume/report/expand)")
    psub = pp.add_subparsers(dest="plan_cmd", required=True)
    for pcmd, phelp in (("run", "execute every incomplete cell"),
                        ("resume", "like run, but requires prior "
                                   "results to exist")):
        q = psub.add_parser(pcmd, help=phelp)
        q.add_argument("plan", help="plan file (benchmarks/plans/*.yaml)")
        q.add_argument("--out", default=DEFAULT_PLAN_OUT,
                       help=f"result store root "
                            f"(default {DEFAULT_PLAN_OUT})")
        q.add_argument("--assert-complete", action="store_true",
                       help="exit nonzero if ANY cell had to execute "
                            "(CI resume proof: a second run must skip "
                            "everything)")
    q = psub.add_parser("report",
                        help="merge cells -> BENCH_plan_<name>.json + "
                             "dashboard.html")
    q.add_argument("plan")
    q.add_argument("--out", default=DEFAULT_PLAN_OUT)
    q.add_argument("--history", default=DEFAULT_BASELINES,
                   help=f"BENCH_*.json history charted in the dashboard "
                        f"(default {DEFAULT_BASELINES}; '' disables)")
    q.add_argument("--plan-history", default=f"{DEFAULT_BASELINES}/plans",
                   help="dir of prior BENCH_plan_<name>.json runs for "
                        "the plan-over-plan wall chart (default "
                        f"{DEFAULT_BASELINES}/plans; '' disables)")
    q.add_argument("--partial", action="store_true",
                   help="report over an incomplete store (missing cells "
                        "are simply absent)")
    q = psub.add_parser("expand", help="print the expanded cell list")
    q.add_argument("plan")

    args = ap.parse_args(argv)
    return {"list": _cmd_list, "run": _cmd_run, "compare": _cmd_compare,
            "plan": _cmd_plan}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
