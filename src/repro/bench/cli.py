"""`python -m repro.bench` — run benchmarks, write BENCH_<name>.json,
gate against committed baselines.

  python -m repro.bench list
  python -m repro.bench run [names...] [--quick] [--all] [--out DIR]
  python -m repro.bench compare [names...] [--current DIR]
                                [--baseline DIR] [--wall-tol F]

`run` with no names executes every non-slow suite; `compare` exits
nonzero on any deterministic drift (see repro.bench.report for the
policy), which is what the CI bench job gates on.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import registry, report

DEFAULT_OUT = "results/bench"
DEFAULT_BASELINES = "benchmarks/baselines"


def _cmd_list(args) -> int:
    for name in sorted(registry.BENCHES):
        e = registry.BENCHES[name]
        tag = " [slow]" if e.slow else ""
        print(f"{name:16s}{tag:7s} {e.doc}")
    return 0


def _cmd_run(args) -> int:
    names = args.names or registry.default_names(include_slow=args.all)
    failures = []
    for name in names:
        print(f"\n===== {name} =====", flush=True)
        try:
            rep = registry.get(name).fn(args.quick)
            path = report.save(rep, args.out)
            print(f"[bench] wrote {path} "
                  f"({len(rep['deterministic'])} deterministic, "
                  f"{len(rep['wall'])} wall metrics)", flush=True)
        except Exception as e:
            failures.append(name)
            print(f"[bench] {name} FAILED: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"\nFAILURES: {failures}")
        return 1
    print(f"\nall {len(names)} benchmark suite(s) completed")
    return 0


def _cmd_compare(args) -> int:
    res = report.compare_dirs(args.current, args.baseline,
                              names=args.names or None,
                              wall_tol=args.wall_tol)
    print(res.render())
    return 0 if res.ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.bench",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="list registered benchmark suites")

    rp = sub.add_parser("run", help="run suites, write BENCH_*.json")
    rp.add_argument("names", nargs="*",
                    help="suite names (default: all non-slow)")
    rp.add_argument("--quick", action="store_true",
                    help="CI-sized grids/steps")
    rp.add_argument("--all", action="store_true",
                    help="include slow (subprocess) suites in the default "
                         "set")
    rp.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output directory (default {DEFAULT_OUT})")

    cp = sub.add_parser("compare",
                        help="gate current reports against baselines")
    cp.add_argument("names", nargs="*",
                    help="suite names (default: every baseline present)")
    cp.add_argument("--current", default=DEFAULT_OUT,
                    help=f"directory with fresh reports "
                         f"(default {DEFAULT_OUT})")
    cp.add_argument("--baseline", default=DEFAULT_BASELINES,
                    help=f"committed baseline directory "
                         f"(default {DEFAULT_BASELINES})")
    cp.add_argument("--wall-tol", type=float, default=0.5,
                    help="relative wall-clock warn threshold "
                         "(default 0.5 = ±50%%)")

    args = ap.parse_args(argv)
    return {"list": _cmd_list, "run": _cmd_run,
            "compare": _cmd_compare}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
