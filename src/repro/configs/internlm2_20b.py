"""InternLM2-20B [arXiv:2403.17297]: 48L, d=6144, 48H GQA kv=8, ff=16384,
vocab 92544."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="decoder",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    pattern=(("ga", "dense"),),
    act="swiglu",
    tie_embeddings=False,
    rope_theta=1000000.0,
    subquadratic=False,
)

SMOKE = CONFIG.scaled(n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
                      head_dim=16, d_ff=256, vocab_size=512)
