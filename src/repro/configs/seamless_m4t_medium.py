"""SeamlessM4T-medium [arXiv:2308.11596]: enc-dec, 12L speech encoder +
12L text decoder, d=1024, 16H (kv=16), ff=4096, vocab 256206.

[audio]: the conformer speech frontend is a STUB by spec — input_specs()
provide precomputed frame embeddings ('enc_embeds' [B, S, d]); the
transformer backbone (bidirectional encoder + causal decoder with
cross-attention) is exact."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,             # decoder depth
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    pattern=(("xa", "dense"),),
    act="gelu",
    tie_embeddings=True,
    modality="audio",
    subquadratic=False,
)

SMOKE = CONFIG.scaled(n_layers=2, n_encoder_layers=2, d_model=128,
                      n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                      vocab_size=512)
