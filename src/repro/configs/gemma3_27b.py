"""Gemma-3-27B [hf:google/gemma-3 family]: 62L, d=5376, 32H GQA kv=16,
ff=21504, vocab 262144; 5 local(window 1024):1 global pattern, qk-norm,
128k context.  62 = 10 x (5L+1G) + 2 trailing local layers."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="decoder",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=(("la", "dense"),) * 5 + (("ga", "dense"),),
    window=1024,
    qk_norm=True,
    rope_theta=1000000.0,
    act="gelu",  # geglu: gelu with gate
    tie_embeddings=True,
    emb_scale=5376 ** 0.5,   # gemma embeds are sqrt(d)-scaled
    # local layers dominate (5:1, window 1024) => effectively subquadratic;
    # global layers at 500k decode are linear per step
    subquadratic=True,
)

# geglu needs a gate; reuse swiglu-style gate with gelu activation
CONFIG = CONFIG.scaled(act="swiglu")

SMOKE = CONFIG.scaled(n_layers=8, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=512, window=64,
                      emb_scale=128 ** 0.5)
