"""MiniCPM-2B [arXiv:2404.06395]: 40L, d=2304, 36H (kv=36 -> MHA), ff=5760,
vocab 122753.  Arch-defining features: muP-style scaling knobs + the WSD
(warmup-stable-decay) schedule, wired in optim/schedules.py."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="decoder",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    pattern=(("ga", "dense"),),
    act="swiglu",
    tie_embeddings=True,
    # muP knobs (paper: scale_emb=12, scale_depth=1.4, dim_model_base=256)
    emb_scale=12.0,
    residual_scale=1.4 / (40 ** 0.5),
    logit_scale=1.0 / (2304 / 256),
    subquadratic=False,
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
                      head_dim=32, d_ff=320, vocab_size=512,
                      residual_scale=1.4 / 2.0, logit_scale=0.5)
