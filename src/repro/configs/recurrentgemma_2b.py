"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: 26L, d=2560, 10H GQA
kv=1 (MQA), ff=7680; pattern = [RG-LRU, RG-LRU, local-attn(window 2048)];
26 = 8 x 3 + 2 trailing recurrent blocks.  vocab 256000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="decoder",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=(("rg", "dense"), ("rg", "dense"), ("la", "dense")),
    window=2048,
    rg_lru_width=2560,
    conv1d_width=4,
    act="swiglu",  # geglu variant
    tie_embeddings=True,
    emb_scale=2560 ** 0.5,
    subquadratic=True,   # hybrid: recurrent state + fixed-window attention
)

SMOKE = CONFIG.scaled(n_layers=5, d_model=128, n_heads=2, n_kv_heads=1,
                      head_dim=64, d_ff=256, vocab_size=512, window=32,
                      rg_lru_width=128, emb_scale=128 ** 0.5)
