"""Architecture registry: --arch <id> resolution for all assigned configs
plus the paper's own DPSNN grids."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig, MoEConfig, ShapeConfig, SHAPES, shape_by_name
from . import (gemma3_27b, granite_moe_3b_a800m, internlm2_20b,
               llama4_maverick_400b_a17b, llava_next_34b, minicpm_2b,
               qwen3_0_6b, recurrentgemma_2b, rwkv6_1_6b,
               seamless_m4t_medium)

_MODULES = {
    "minicpm-2b": minicpm_2b,
    "internlm2-20b": internlm2_20b,
    "gemma3-27b": gemma3_27b,
    "qwen3-0.6b": qwen3_0_6b,
    "llava-next-34b": llava_next_34b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {k: m.CONFIG for k, m in _MODULES.items()}


def valid_cells():
    """The (arch x shape) dry-run matrix with applicability skips.

    long_500k runs only for subquadratic archs (SSM / hybrid / 5:1-local);
    pure full-attention archs skip it (DESIGN.md §Arch-applicability).
    """
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sh in SHAPES:
            if sh.name == "long_500k" and not cfg.subquadratic:
                continue
            cells.append((arch, sh.name))
    return cells


__all__ = ["ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES",
           "shape_by_name", "ARCH_IDS", "get_config", "get_smoke_config",
           "all_configs", "valid_cells"]
