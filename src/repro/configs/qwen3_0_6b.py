"""Qwen3-0.6B [hf:Qwen/Qwen3-0.6B]: 28L, d=1024, 16H GQA kv=8, head 128,
ff=3072, vocab 151936, qk-norm."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="decoder",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    pattern=(("ga", "dense"),),
    qk_norm=True,
    rope_theta=1000000.0,
    act="swiglu",
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = CONFIG.scaled(n_layers=3, d_model=96, n_heads=4, n_kv_heads=2,
                      head_dim=24, d_ff=192, vocab_size=512)
