"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6-34b]: 60L backbone (Yi-34B-ish),
d=7168, 56H GQA kv=8, ff=20480, vocab 64000.

[vlm]: the anyres tiling vision frontend is a STUB by spec —
input_specs()/the data pipeline provide precomputed patch embeddings
('embeds' [B, T, d]); the language backbone is exact."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="decoder",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    pattern=(("ga", "dense"),),
    act="swiglu",
    tie_embeddings=False,
    rope_theta=5000000.0,
    modality="vlm",
    subquadratic=False,
)

SMOKE = CONFIG.scaled(n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
                      head_dim=16, d_ff=256, vocab_size=512)
