"""RWKV-6 (Finch) 1.6B [arXiv:2404.05892]: 24L, d=2048, attention-free
(time mix w/ data-dependent decay + channel mix), ff=7168 (channel mix),
vocab 65536."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="decoder",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # d / rwkv_head_dim; informational
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    pattern=(("rwkv", "cmix"),),
    rwkv_head_dim=64,
    act="relu2",
    tie_embeddings=False,
    subquadratic=True,     # attention-free: O(1) state per token
)

SMOKE = CONFIG.scaled(n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
                      head_dim=32, d_ff=256, vocab_size=512,
                      rwkv_head_dim=32)
