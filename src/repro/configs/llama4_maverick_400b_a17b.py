"""Llama-4-Maverick 400B-A17B [hf:meta-llama/Llama-4-Maverick-17B-128E]:
48L, d=5120, 40H GQA kv=8, ff=8192, vocab 202048; MoE 128 experts top-1
with a shared expert, interleaved dense:MoE = 1:1 (DESIGN.md §Config
fidelity: reproduces ~400B total / ~17B active params).  Early-fusion
multimodality is a frontend concern (text path exercised here)."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="decoder",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(("ga", "dense"), ("ga", "moe")),
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                  shared_expert=True, capacity_factor=2.0),
    act="swiglu",
    tie_embeddings=False,
    rope_theta=500000.0,
    subquadratic=False,
)

# smoke capacity covers all tokens (no drops) so decode == forward exactly
SMOKE = CONFIG.scaled(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=512,
                      moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=256,
                                    shared_expert=True,
                                    capacity_factor=16.0))
