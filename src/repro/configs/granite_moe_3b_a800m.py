"""Granite-3.0-3B-A800M MoE [hf:ibm-granite/granite-3.0-3b-a800m-base]:
32L, d=1536, 24H GQA kv=8, expert ff=512, 40 experts top-8, vocab 49155."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="decoder",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                # informational; experts carry the FFN
    vocab_size=49155,
    pattern=(("ga", "moe"),),
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                  shared_expert=False, capacity_factor=2.0),
    act="swiglu",
    tie_embeddings=True,
    subquadratic=False,
)

# smoke capacity covers all tokens (no drops) so decode == forward exactly
SMOKE = CONFIG.scaled(n_layers=3, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=64, vocab_size=512,
                      moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                                    capacity_factor=8.0))
