"""Architecture / run configuration schema.

Every assigned architecture is described by a `ModelConfig`; the per-layer
block structure is a repeating `pattern` of (mixer, mlp) kinds so the model
stack can `lax.scan` over repeated units (compact HLO at any depth) and
unroll only the remainder layers.

Mixer kinds:  'ga' global attention | 'la' local (sliding-window) attention
              | 'rg' RG-LRU recurrent block | 'rwkv' RWKV-6 time mix
              | 'bi' bidirectional attention (encoder)
              | 'xa' causal self-attn + cross-attn (decoder w/ encoder)
MLP kinds:    'dense' | 'moe'
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False
    capacity_factor: float = 2.0
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # 'decoder' | 'encdec'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # repeating layer pattern: tuple of (mixer, mlp) kind pairs
    pattern: Tuple[Tuple[str, str], ...] = (("ga", "dense"),)
    window: Optional[int] = None     # for 'la' layers
    qk_norm: bool = False
    softcap: Optional[float] = None  # attention logit soft-capping
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "swiglu"              # 'swiglu' | 'gelu' | 'relu2'
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    # recurrent dims
    rg_lru_width: Optional[int] = None
    conv1d_width: int = 4
    rwkv_head_dim: int = 64
    # modality frontend: 'text' | 'vlm' | 'audio' (vlm/audio get precomputed
    # frame/patch embeddings by spec; backbone is exact)
    modality: str = "text"
    # enc-dec split (family == 'encdec'): n_layers is the decoder depth
    n_encoder_layers: int = 0
    # muP-style scaling knobs (MiniCPM / WSD arch)
    emb_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    # numerics
    dtype: str = "bfloat16"
    # long-context capability flag: False for pure full-attention archs =>
    # the long_500k shape is skipped (DESIGN.md §Arch-applicability)
    subquadratic: bool = False

    @property
    def layers(self) -> Tuple[Tuple[str, str], ...]:
        """The full per-layer (mixer, mlp) list, pattern-expanded."""
        p = self.pattern
        reps = -(-self.n_layers // len(p))
        return (p * reps)[: self.n_layers]

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers - self.n_units * len(self.pattern)

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: an input-shape regime for an architecture."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
