"""Per-process cluster worker: join the job, run shards, report.

  python -m repro.cluster.worker --grid 2x2 --shards 4 --steps 100 ...

Every process builds the full plan locally (construction is a pure
function of the config — the paper's reproducible-construction property),
places its own shards on the process-spanning `cells` mesh, and runs:

  1. the fused engine (`core.StepProgram.run`) — timed end-to-end in
     checkpoint-period chunks, raster gathered to every host for the
     global signature;
  2. optionally a phase-split loop (`StepProgram.time_phases`)
     attributing wall-clock to phase A / exchange / phase B *per
     process* — the paper's Table 2 instrumentation, now across real
     processes, schedule-aware under `--exchange-schedule pipelined`.

Fault tolerance (see `cluster.faults` and DESIGN.md §Fault tolerance):
with `--ckpt-dir`/`--ckpt-every K`, the worker writes a sha256-verified,
layout-free epoch every K steps (primary process only; atomic
tmp+rename) carrying the run's cumulative spike events, and at startup
SELF-RESUMES from the newest VALID epoch found in the directory — so the
supervisor (`local.supervised_launch`) relaunches a failed gang with an
unchanged command line and recovery replays at most one period.  Chunk
boundaries are aligned to `base_t + k*K` regardless of the resume point,
and chunked execution is bit-identical to unchunked, so the recovered
run's final raster AND weight signatures equal the fault-free run's.
Progress beacons (`REPRO_BEACON_DIR`) and the deterministic fault hooks
(`REPRO_FAULT`) ride the same chunk boundaries.

The result is one `CLUSTER_RESULT {json}` line on stdout per process;
`repro.cluster.report` parses and aggregates them in the parent.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from . import faults

RESULT_PREFIX = "CLUSTER_RESULT "


def add_workload_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--grid", default="2x2")
    ap.add_argument("--neurons-per-column", type=int, default=100)
    ap.add_argument("--synapses", type=int, default=40)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--shards", type=int, default=2,
                    help="total shards H across ALL processes")
    ap.add_argument("--exchange", default="allgather",
                    choices=["allgather", "halo", "hier"])
    ap.add_argument("--exchange-schedule", default="sync",
                    choices=["sync", "pipelined"],
                    help="'pipelined' overlaps the spike exchange with "
                         "phase A's LTP half (bit-identical outputs)")
    ap.add_argument("--placement", default="block",
                    choices=["block", "scatter"])
    ap.add_argument("--delivery", default="dense",
                    choices=["dense", "event"],
                    help="synaptic delivery backend: dense O(E) masked or "
                         "event-driven O(spikes x fan)")
    ap.add_argument("--profile", default="ring3",
                    help="lateral-connectivity profile spec "
                         "(repro.core.profiles)")
    ap.add_argument("--connectivity-mode", default="materialized",
                    help="synapse-table residency: 'materialized' or "
                         "'streamed:chunk=K' (per-chunk regeneration "
                         "inside the step; requires --delivery dense)")
    ap.add_argument("--stim-events", type=int, default=1,
                    help="thalamic events per ms per column "
                         "(GridConfig.stim_events_per_ms_per_column)")
    ap.add_argument("--stim-amplitude", type=float, default=20.0,
                    help="thalamic event amplitude in mV")
    ap.add_argument("--phase-steps", type=int, default=0,
                    help="extra phase-split steps for per-phase timings "
                         "(0 = skip)")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint to restore before running (its saved "
                         "t becomes t0)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for periodic epochs; at startup the "
                         "worker self-resumes from the newest sha256-VALID "
                         "epoch found here (corrupt epochs skipped)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="periodic checkpoint period K in steps "
                         "(0 = off; needs --ckpt-dir)")


def workload_argv(args) -> list:
    """args -> worker argv tail (parent-side helper, kept next to the
    parser so the two cannot drift)."""
    argv = ["--grid", args.grid,
            "--neurons-per-column", str(args.neurons_per_column),
            "--synapses", str(args.synapses),
            "--seed", str(args.seed),
            "--steps", str(args.steps),
            "--shards", str(args.shards),
            "--exchange", args.exchange,
            "--exchange-schedule", getattr(args, "exchange_schedule",
                                           "sync"),
            "--placement", args.placement,
            "--delivery", getattr(args, "delivery", "dense"),
            "--profile", args.profile,
            "--connectivity-mode", getattr(args, "connectivity_mode",
                                           "materialized"),
            "--stim-events", str(getattr(args, "stim_events", 1)),
            "--stim-amplitude", str(getattr(args, "stim_amplitude",
                                            20.0)),
            "--phase-steps", str(args.phase_steps)]
    if getattr(args, "ckpt", None):
        argv += ["--ckpt", args.ckpt]
    if getattr(args, "ckpt_dir", None):
        argv += ["--ckpt-dir", args.ckpt_dir]
    if getattr(args, "ckpt_every", 0):
        argv += ["--ckpt-every", str(args.ckpt_every)]
    return argv


def _chunk_spans(t_from: int, t_end: int, k: int, align: int) -> list:
    """[(a, b)] chunk boundaries for [t_from, t_end), cut at every
    `align + i*k` (k=0: one chunk).  Alignment to the run BASE rather
    than the resume point is what makes a resumed run re-enter the exact
    chunk sequence of the fault-free run — the precondition for the
    bit-identity argument (chunked == unchunked, any split)."""
    bs = [t_from]
    if k > 0:
        b = align + ((t_from - align) // k + 1) * k
        while b < t_end:
            bs.append(b)
            b += k
    if bs[-1] != t_end:
        bs.append(t_end)
    return [(bs[i], bs[i + 1]) for i in range(len(bs) - 1)
            if bs[i + 1] > bs[i]]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.cluster.worker")
    add_workload_args(ap)
    args = ap.parse_args(argv)
    if args.ckpt_every > 0 and not args.ckpt_dir:
        raise SystemExit("worker: --ckpt-every needs --ckpt-dir")

    # rank from the launcher env (jax not initialized yet); faults and
    # beacons key off it before the distributed runtime comes up
    from .._flags import ENV_PROC_ID
    rank = int(os.environ.get(ENV_PROC_ID, "0") or 0)
    attempt = int(os.environ.get(faults.ENV_ATTEMPT, "0") or 0)
    inj = faults.FaultInjector.from_env(rank)
    beacon = faults.BeaconWriter.from_env(rank)
    beacon.write(0, "boot", attempt=attempt)

    # join the job BEFORE anything touches jax devices
    from . import runtime
    runtime.ensure_initialized()

    import jax
    import numpy as np

    from ..core import (EngineConfig, GridConfig, StepProgram, checkpoint,
                        observables)
    from ..dist import mesh as dist_mesh

    H = args.shards
    if jax.device_count() != H:
        raise SystemExit(
            f"worker: global device count {jax.device_count()} != shards "
            f"{H} (launcher must set devices_per_proc = H / nprocs)")

    gx, gy = (int(v) for v in args.grid.split("x"))
    cfg = GridConfig(grid_x=gx, grid_y=gy,
                     neurons_per_column=args.neurons_per_column,
                     synapses_per_neuron=args.synapses, seed=args.seed,
                     connectivity=args.profile,
                     stim_events_per_ms_per_column=args.stim_events,
                     stim_amplitude=args.stim_amplitude)
    eng = EngineConfig(n_shards=H, exchange=args.exchange,
                       exchange_schedule=args.exchange_schedule,
                       placement=args.placement, delivery=args.delivery,
                       connectivity=args.connectivity_mode)
    event = args.delivery == "event"
    sp = StepProgram(cfg, eng, mesh=dist_mesh.make_snn_mesh(H))
    state, base_t = sp.init_state(), 0
    if args.ckpt:
        state, base_t = sp.load(args.ckpt)

    # self-resume: newest VALID periodic epoch wins over the cold start /
    # the explicit --ckpt base.  Cumulative events ride the epoch so the
    # FULL-run signature survives the restart.
    t0, restored_from = base_t, None
    ev_t = np.zeros((0,), np.int64)
    ev_g = np.zeros((0,), np.int64)
    if args.ckpt_dir:
        newest = checkpoint.latest_valid(args.ckpt_dir)
        if newest is not None and checkpoint.saved_t(newest) > base_t:
            state, t0 = sp.load(newest)
            ev = checkpoint.load_raster_events(newest)
            if ev is not None:
                ev_t, ev_g = ev
            restored_from = newest
            print(f"[worker {rank}] resumed from {newest} (t={t0}, "
                  f"{ev_t.shape[0]} events restored)", flush=True)
    t_end = base_t + args.steps
    beacon.write(t0, "built")

    state_d = sp.place(state)
    spans = _chunk_spans(t0, t_end, args.ckpt_every, base_t)

    # warmup: compile each distinct chunk length once (the runner re-jits
    # per length, not per t0), so the timed loop measures steady state
    for n in sorted({b - a for a, b in spans}):
        jax.block_until_ready(sp.run(state_d, t0, n)[1])
    beacon.write(t0, "warmup")

    gid_np = np.asarray(sp.plan.gid)
    cur = state_d
    wall_s = ckpt_wall_s = 0.0
    n_ckpts = 0
    for a, b in spans:
        beacon.write(a, "chunk")
        inj.on_chunk(a, b)
        w0 = time.perf_counter()
        cur, raster, _ = sp.run(cur, a, b - a)
        jax.block_until_ready(raster)
        wall_s += time.perf_counter() - w0
        # event times are RELATIVE to the run base (t - base_t): a run
        # restored from --ckpt signs its continuation window exactly like
        # a single-process run over the same window, and the cumulative
        # list carried across self-resumes stays in one consistent frame
        ct, cg = observables.raster_events(runtime.gather(raster), gid_np,
                                           t0=a - base_t)
        ev_t = np.concatenate([ev_t, ct])
        ev_g = np.concatenate([ev_g, cg])
        if args.ckpt_every > 0:
            c0 = time.perf_counter()
            host = runtime.gather(cur)
            path = os.path.join(args.ckpt_dir, f"ckpt_{b}.npz")
            if runtime.is_primary():
                checkpoint.save(path, sp.spec, sp.plan, host, b,
                                raster_events=(ev_t, ev_g))
                inj.on_checkpoint_written(path, b)
            ckpt_wall_s += time.perf_counter() - c0
            n_ckpts += 1

    beacon.write(t_end, "report")
    state_host = runtime.gather(cur)
    T = t_end - base_t
    result = dict(
        proc=runtime.process_index(), nprocs=runtime.process_count(),
        shards=H, t0=base_t, steps=args.steps,
        exchange=args.exchange, placement=args.placement,
        exchange_schedule=args.exchange_schedule,
        delivery=args.delivery, profile=args.profile,
        connectivity_mode=args.connectivity_mode,
        stim_events=args.stim_events,
        tuned_env=os.environ.get("REPRO_TUNED_ENV", "") == "1",
        local_devices=jax.local_device_count(),
        wall_s=round(wall_s, 4),
        spikes=int(ev_t.shape[0]),
        rate_hz=round(ev_t.shape[0] / (cfg.n_neurons * T / 1000.0), 3)
        if T else 0.0,
        # signature over the FULL run window [base_t, t_end): per-chunk
        # events concatenate in canonical order, so this equals the
        # one-shot raster_signature bit-for-bit (observables docstring)
        raster_sig=observables.events_signature(ev_t, ev_g).hex(),
        weights_sig=sp.weight_signature(state_host).hex(),
        # recovery bookkeeping (surfaced by cluster.report)
        attempt=attempt,
        ckpt_every=args.ckpt_every, n_ckpts=n_ckpts,
        ckpt_wall_s=round(ckpt_wall_s, 4),
        restored_from=restored_from,
        restored_t=(t0 if restored_from else None),
        # steps salvaged from periodic epochs instead of recomputed —
        # the restart replays only [restored_t, failure point)
        recovered_steps=(t0 - base_t) if restored_from else 0)
    if event:
        result["saturated"] = int(np.asarray(state_host.sat).sum())

    if args.phase_steps > 0:
        # sp.run never mutates its input state, so state_d re-seeds the
        # split loop; warmup + per-phase blocking + the schedule-aware
        # exchange fencing live in StepProgram.time_phases (shared with
        # the bench suites)
        _, times, _, _ = sp.time_phases(state_d, t0, args.phase_steps)
        result["phase_steps"] = args.phase_steps
        result.update({k: round(v, 4) for k, v in times.items()})

    if inj.emit_result():
        print(RESULT_PREFIX + json.dumps(result), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
