"""Per-process cluster worker: join the job, run shards, report.

  python -m repro.cluster.worker --grid 2x2 --shards 4 --steps 100 ...

Every process builds the full plan locally (construction is a pure
function of the config — the paper's reproducible-construction property),
places its own shards on the process-spanning `cells` mesh, and runs:

  1. the fused engine (`core.StepProgram.run`) — timed end-to-end,
     raster gathered to every host for the global signature;
  2. optionally a phase-split loop (`StepProgram.time_phases`)
     attributing wall-clock to phase A / exchange / phase B *per
     process* — the paper's Table 2 instrumentation, now across real
     processes, schedule-aware under `--exchange-schedule pipelined`.

The result is one `CLUSTER_RESULT {json}` line on stdout per process;
`repro.cluster.report` parses and aggregates them in the parent.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


RESULT_PREFIX = "CLUSTER_RESULT "


def add_workload_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--grid", default="2x2")
    ap.add_argument("--neurons-per-column", type=int, default=100)
    ap.add_argument("--synapses", type=int, default=40)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--shards", type=int, default=2,
                    help="total shards H across ALL processes")
    ap.add_argument("--exchange", default="allgather",
                    choices=["allgather", "halo", "hier"])
    ap.add_argument("--exchange-schedule", default="sync",
                    choices=["sync", "pipelined"],
                    help="'pipelined' overlaps the spike exchange with "
                         "phase A's LTP half (bit-identical outputs)")
    ap.add_argument("--placement", default="block",
                    choices=["block", "scatter"])
    ap.add_argument("--delivery", default="dense",
                    choices=["dense", "event"],
                    help="synaptic delivery backend: dense O(E) masked or "
                         "event-driven O(spikes x fan)")
    ap.add_argument("--profile", default="ring3",
                    help="lateral-connectivity profile spec "
                         "(repro.core.profiles)")
    ap.add_argument("--connectivity-mode", default="materialized",
                    help="synapse-table residency: 'materialized' or "
                         "'streamed:chunk=K' (per-chunk regeneration "
                         "inside the step; requires --delivery dense)")
    ap.add_argument("--stim-events", type=int, default=1,
                    help="thalamic events per ms per column "
                         "(GridConfig.stim_events_per_ms_per_column)")
    ap.add_argument("--stim-amplitude", type=float, default=20.0,
                    help="thalamic event amplitude in mV")
    ap.add_argument("--phase-steps", type=int, default=0,
                    help="extra phase-split steps for per-phase timings "
                         "(0 = skip)")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint to restore before running (its saved "
                         "t becomes t0)")


def workload_argv(args) -> list:
    """args -> worker argv tail (parent-side helper, kept next to the
    parser so the two cannot drift)."""
    argv = ["--grid", args.grid,
            "--neurons-per-column", str(args.neurons_per_column),
            "--synapses", str(args.synapses),
            "--seed", str(args.seed),
            "--steps", str(args.steps),
            "--shards", str(args.shards),
            "--exchange", args.exchange,
            "--exchange-schedule", getattr(args, "exchange_schedule",
                                           "sync"),
            "--placement", args.placement,
            "--delivery", getattr(args, "delivery", "dense"),
            "--profile", args.profile,
            "--connectivity-mode", getattr(args, "connectivity_mode",
                                           "materialized"),
            "--stim-events", str(getattr(args, "stim_events", 1)),
            "--stim-amplitude", str(getattr(args, "stim_amplitude",
                                            20.0)),
            "--phase-steps", str(args.phase_steps)]
    if getattr(args, "ckpt", None):
        argv += ["--ckpt", args.ckpt]
    return argv


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.cluster.worker")
    add_workload_args(ap)
    args = ap.parse_args(argv)

    # join the job BEFORE anything touches jax devices
    from . import runtime
    runtime.ensure_initialized()

    import os

    import jax
    import numpy as np

    from ..core import EngineConfig, GridConfig, StepProgram, observables
    from ..dist import mesh as dist_mesh

    H = args.shards
    if jax.device_count() != H:
        raise SystemExit(
            f"worker: global device count {jax.device_count()} != shards "
            f"{H} (launcher must set devices_per_proc = H / nprocs)")

    gx, gy = (int(v) for v in args.grid.split("x"))
    cfg = GridConfig(grid_x=gx, grid_y=gy,
                     neurons_per_column=args.neurons_per_column,
                     synapses_per_neuron=args.synapses, seed=args.seed,
                     connectivity=args.profile,
                     stim_events_per_ms_per_column=args.stim_events,
                     stim_amplitude=args.stim_amplitude)
    eng = EngineConfig(n_shards=H, exchange=args.exchange,
                       exchange_schedule=args.exchange_schedule,
                       placement=args.placement, delivery=args.delivery,
                       connectivity=args.connectivity_mode)
    event = args.delivery == "event"
    sp = StepProgram(cfg, eng, mesh=dist_mesh.make_snn_mesh(H))
    state, t0 = sp.init_state(), 0
    if args.ckpt:
        state, t0 = sp.load(args.ckpt)

    state_d = sp.place(state)

    # fused run: warmup (compile), then timed from the same initial state
    jax.block_until_ready(sp.run(state_d, t0, args.steps)[1])
    w0 = time.perf_counter()
    state_f, raster, _ = sp.run(state_d, t0, args.steps)
    jax.block_until_ready(raster)
    wall_s = time.perf_counter() - w0

    raster_np = runtime.gather(raster)                    # [T, H, N]
    gid_np = np.asarray(sp.plan.gid)
    result = dict(
        proc=runtime.process_index(), nprocs=runtime.process_count(),
        shards=H, t0=t0, steps=args.steps,
        exchange=args.exchange, placement=args.placement,
        exchange_schedule=args.exchange_schedule,
        delivery=args.delivery, profile=args.profile,
        connectivity_mode=args.connectivity_mode,
        stim_events=args.stim_events,
        tuned_env=os.environ.get("REPRO_TUNED_ENV", "") == "1",
        local_devices=jax.local_device_count(),
        wall_s=round(wall_s, 4),
        spikes=int(raster_np.sum()),
        rate_hz=round(observables.mean_rate_hz(raster_np, cfg.n_neurons), 3),
        raster_sig=observables.raster_signature(raster_np, gid_np).hex())
    if event:
        result["saturated"] = int(np.asarray(
            runtime.gather(state_f.sat)).sum())

    if args.phase_steps > 0:
        # sp.run never mutates its input state, so state_d re-seeds the
        # split loop; warmup + per-phase blocking + the schedule-aware
        # exchange fencing live in StepProgram.time_phases (shared with
        # the bench suites)
        _, times, _, _ = sp.time_phases(state_d, t0, args.phase_steps)
        result["phase_steps"] = args.phase_steps
        result.update({k: round(v, 4) for k, v in times.items()})

    print(RESULT_PREFIX + json.dumps(result), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
