"""Aggregate per-process worker results into scaling rows and a
BENCH-schema report.

Workers emit one `CLUSTER_RESULT {json}` line each (repro.cluster.worker);
`summarize_point` folds the P lines of one launch into a single row —
cross-checking that every process computed the same globally-gathered
raster signature — and `scaling_report` turns a sweep's rows into the
`BENCH_cluster_scaling.json` document that rides the existing
`repro.bench.report` schema and CI comparator: raster signatures gate
hard (the paper's Table 1 invariant over the process axis), per-process
phase A / exchange / phase B walls are tolerance-only (paper Figs. 5-8).
"""
from __future__ import annotations

import json
from typing import Dict, List, Sequence

from ..bench import report as bench_report
from .worker import RESULT_PREFIX

PHASE_KEYS = ("phase_a_s", "exchange_s", "phase_b_s")


def parse_worker_outputs(outputs: Sequence[str]) -> List[dict]:
    """One result dict per worker stdout, ordered by process id."""
    results = []
    for i, out in enumerate(outputs):
        lines = [ln for ln in out.splitlines()
                 if ln.startswith(RESULT_PREFIX)]
        if len(lines) != 1:
            raise ValueError(f"worker {i}: expected exactly one "
                             f"{RESULT_PREFIX!r} line, got {len(lines)}:\n"
                             f"{out[-2000:]}")
        results.append(json.loads(lines[0][len(RESULT_PREFIX):]))
    return sorted(results, key=lambda r: r["proc"])


def summarize_point(results: List[dict],
                    attempts: List[dict] = None) -> dict:
    """Fold one launch's per-process results into a scaling row.

    Wall time is the max over processes (the job is done when the slowest
    process is); per-phase walls keep both the max and the per-process
    breakdown.  Raster AND weight signatures must agree across processes
    — each gathered the same global raster and plastic state.

    `attempts` (from `local.supervised_launch`) attaches the recovery
    history: the row records how many restarts the point needed, why each
    attempt died, and what the surviving attempt salvaged from periodic
    epochs."""
    if not results:
        raise ValueError("no worker results")
    sigs = {r["raster_sig"] for r in results}
    if len(sigs) != 1:
        raise ValueError(f"raster signatures diverge across processes: "
                         f"{[r['raster_sig'] for r in results]}")
    wsigs = {r["weights_sig"] for r in results if "weights_sig" in r}
    if len(wsigs) > 1:
        raise ValueError(f"weight signatures diverge across processes: "
                         f"{sorted(wsigs)}")
    nprocs = results[0]["nprocs"]
    if len(results) != nprocs or [r["proc"] for r in results] != list(
            range(nprocs)):
        raise ValueError(f"expected results from procs 0..{nprocs - 1}, "
                         f"got {[r['proc'] for r in results]}")
    row = dict(nprocs=nprocs, shards=results[0]["shards"],
               steps=results[0]["steps"], t0=results[0]["t0"],
               exchange=results[0]["exchange"],
               placement=results[0]["placement"],
               delivery=results[0].get("delivery", "dense"),
               profile=results[0].get("profile", "ring3"),
               connectivity_mode=results[0].get("connectivity_mode",
                                                "materialized"),
               exchange_schedule=results[0].get("exchange_schedule",
                                                "sync"),
               tuned_env=results[0].get("tuned_env", False),
               wall_s=max(r["wall_s"] for r in results),
               spikes=results[0]["spikes"],
               rate_hz=results[0]["rate_hz"],
               raster_sig=results[0]["raster_sig"],
               per_proc=[{k: r[k] for k in
                          ("proc", "wall_s", *PHASE_KEYS) if k in r}
                         for r in results])
    if wsigs:
        row["weights_sig"] = next(iter(wsigs))
    if "ckpt_every" in results[0]:
        row["ckpt_every"] = results[0]["ckpt_every"]
        row["n_ckpts"] = max(r.get("n_ckpts", 0) for r in results)
        row["ckpt_wall_s"] = round(
            max(r.get("ckpt_wall_s", 0.0) for r in results), 4)
    # recovery bookkeeping: what the surviving attempt restored, plus the
    # supervisor's restart history when the launch was supervised
    restored = [r for r in results if r.get("restored_from")]
    row["recovery"] = dict(
        attempt=max((r.get("attempt", 0) for r in results), default=0),
        restarts=len(attempts or []),
        restored=bool(restored),
        restored_t=(restored[0].get("restored_t") if restored else None),
        recovered_steps=max(
            (r.get("recovered_steps", 0) for r in results), default=0),
        attempts=[dict(index=a["index"], reason=a["reason"],
                       backoff_s=a["backoff_s"])
                  for a in (attempts or [])])
    if "saturated" in results[0]:
        row["saturated"] = max(r.get("saturated", 0) for r in results)
    for k in PHASE_KEYS:
        if all(k in r for r in results):
            row[k] = round(max(r[k] for r in results), 4)
    return row


def scaling_report(rows: List[dict], config: Dict, name: str =
                   "cluster_scaling") -> dict:
    """Sweep rows (one per process count, same workload) -> BENCH report.

    Deterministic section: the shared raster signature, total spikes, and
    the across-P identity flag.  Wall section: per-P end-to-end wall and
    the per-phase maxima."""
    if not rows:
        raise ValueError("no scaling rows")
    sigs = [r["raster_sig"] for r in rows]
    deterministic = dict(
        raster_sig=sigs[0],
        spikes=rows[0]["spikes"],
        identical_across_procs=(len(set(sigs)) == 1))
    wsigs = [r["weights_sig"] for r in rows if "weights_sig" in r]
    if wsigs:
        deterministic["weights_sig"] = wsigs[0]
        deterministic["identical_weights_across_procs"] = (
            len(set(wsigs)) == 1)
    wall = {}
    for r in rows:
        p = r["nprocs"]
        wall[f"p{p}_wall_s"] = r["wall_s"]
        if r.get("ckpt_wall_s") is not None:
            wall[f"p{p}_ckpt_wall_s"] = r["ckpt_wall_s"]
        for k in PHASE_KEYS:
            if k in r:
                wall[f"p{p}_{k}"] = r[k]
    return bench_report.make_report(name, config, deterministic, wall,
                                    extra=dict(points=rows))
