"""Deterministic fault injection + progress beacons for cluster workers.

Every failure mode the supervisor must survive is a *reproducible test
case*, not a flake: the `REPRO_FAULT` environment variable arms exactly
one fault on exactly one worker, keyed to an exact simulation step, and
the supervised launcher (`local.supervised_launch`) injects it into the
FIRST attempt only — recovery attempts run clean, so a recovered run
terminates and its outputs can be compared bit-for-bit against the
fault-free reference.

Injection grammar (`REPRO_FAULT=`):

    crash@step=N[:rank=R]      worker R hard-exits (os._exit, no atexit —
                               a process death, not an exception) at the
                               chunk boundary covering step N
    hang@step=N[:rank=R]       worker R blocks forever at that boundary;
                               its gang-mates stall in the next collective
                               and the parent's beacon stall detector —
                               not a blunt global deadline — catches it
    slow@step=N:ms=M[:rank=R]  worker R sleeps M ms once (a straggler);
                               a supervisor with an adequate stall budget
                               must NOT kill the gang for this
    corrupt_ckpt[@step=N]      after the periodic checkpoint at the first
                               epoch >= N is written, the writer truncates
                               it on disk and hard-exits: recovery must
                               detect the corruption (sha256) and fall
                               back to the previous epoch
    drop_result                the worker runs to completion but never
                               emits its CLUSTER_RESULT line (a lost
                               report, exit code 0)

Faults fire at chunk boundaries (the checkpoint/beacon cadence), which is
what makes them deterministic: "crash at step N" means "crash having
completed exactly the chunks before N", so the surviving state on disk is
a pure function of the spec.

This module is stdlib-only (jax-free): the parent imports it for the
grammar and the beacon reader, workers import it for the injector and the
beacon writer.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import tempfile
import time
from typing import Dict, Optional

ENV_FAULT = "REPRO_FAULT"          # injection spec, armed by the supervisor
ENV_BEACON_DIR = "REPRO_BEACON_DIR"  # per-attempt beacon directory
ENV_ATTEMPT = "REPRO_ATTEMPT"      # supervisor attempt index (0 = first)

EXIT_CRASH = 41                    # deliberate crash-fault exit code
EXIT_CORRUPT = 43                  # exit after corrupting a checkpoint

KINDS = ("crash", "hang", "slow", "corrupt_ckpt", "drop_result")
_GRAMMAR = ("crash@step=N[:rank=R] | hang@step=N[:rank=R] | "
            "slow@step=N:ms=M[:rank=R] | corrupt_ckpt[@step=N[:rank=R]] | "
            "drop_result[@rank=R]")

# routed through module globals so unit tests can intercept the
# irreversible actions without dying
_hard_exit = os._exit
_sleep = time.sleep


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One parsed fault: what, where (rank), and when (step)."""
    kind: str
    step: int = 0
    rank: int = 0
    ms: int = 0

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """`kind[@key=val[:key=val...]]` -> FaultSpec; ValueError names
        the grammar on any unknown kind/key or malformed value."""
        text = text.strip()
        kind, _, tail = text.partition("@")
        if kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} in {text!r}; grammar: "
                f"{_GRAMMAR}")
        kw = {}
        if tail:
            for part in tail.split(":"):
                key, eq, val = part.partition("=")
                if not eq or key not in ("step", "rank", "ms"):
                    raise ValueError(
                        f"bad fault parameter {part!r} in {text!r}; "
                        f"grammar: {_GRAMMAR}")
                try:
                    kw[key] = int(val)
                except ValueError:
                    raise ValueError(
                        f"fault parameter {key}={val!r} is not an integer "
                        f"({text!r})") from None
        if kind == "slow" and "ms" not in kw:
            raise ValueError(f"slow fault needs ms=M ({text!r}); grammar: "
                             f"{_GRAMMAR}")
        return cls(kind=kind, **kw)

    def spec(self) -> str:
        """Canonical grammar string (defaults omitted); parse(spec())
        round-trips."""
        parts = [f"{k}={v}" for k, v in (("step", self.step),
                                         ("ms", self.ms),
                                         ("rank", self.rank)) if v]
        return self.kind + ("@" + ":".join(parts) if parts else "")


class FaultInjector:
    """Worker-side hook points.  Disarmed (every hook a no-op) unless
    `REPRO_FAULT` is set AND this worker's rank matches the spec's."""

    def __init__(self, spec: Optional[FaultSpec], rank: int):
        self.spec = spec
        self.rank = rank
        self._fired = False

    @classmethod
    def from_env(cls, rank: int) -> "FaultInjector":
        raw = os.environ.get(ENV_FAULT, "").strip()
        return cls(FaultSpec.parse(raw) if raw else None, rank)

    @property
    def armed(self) -> bool:
        return (self.spec is not None and not self._fired
                and self.rank == self.spec.rank)

    def on_chunk(self, t_start: int, t_end: int) -> None:
        """Called at each chunk boundary BEFORE running [t_start, t_end).
        Fires crash/hang/slow whose step falls inside the chunk."""
        if not self.armed or self.spec.kind not in ("crash", "hang",
                                                    "slow"):
            return
        if not (t_start <= self.spec.step < t_end):
            return
        self._fired = True
        kind = self.spec.kind
        print(f"[fault] {self.spec.spec()} firing at chunk "
              f"[{t_start},{t_end}) on rank {self.rank}", flush=True)
        if kind == "crash":
            sys.stdout.flush()
            _hard_exit(EXIT_CRASH)
        elif kind == "hang":
            while True:                  # reaped by the parent, never returns
                _sleep(60.0)
        elif kind == "slow":
            _sleep(self.spec.ms / 1000.0)

    def on_checkpoint_written(self, path: str, t: int) -> None:
        """Called by the checkpoint WRITER after each periodic epoch hits
        disk.  corrupt_ckpt truncates the file (a simulated torn write /
        disk corruption the sha256 digest must catch) and hard-exits."""
        if not self.armed or self.spec.kind != "corrupt_ckpt":
            return
        if t < self.spec.step:
            return
        self._fired = True
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(1, size * 2 // 3))
        print(f"[fault] corrupt_ckpt truncated {path} "
              f"({size} -> {os.path.getsize(path)} bytes), exiting",
              flush=True)
        sys.stdout.flush()
        _hard_exit(EXIT_CORRUPT)

    def emit_result(self) -> bool:
        """False when the drop_result fault swallows this worker's
        CLUSTER_RESULT line."""
        if self.armed and self.spec.kind == "drop_result":
            self._fired = True
            print("[fault] drop_result swallowing CLUSTER_RESULT",
                  flush=True)
            return False
        return True


# -- progress beacons -----------------------------------------------------

class BeaconWriter:
    """Atomic per-worker progress file: `beacon_<rank>.json` in
    `REPRO_BEACON_DIR`, rewritten (tmp + os.replace — a reader never sees
    a torn write) at every phase transition and chunk boundary.  The
    jax-free parent derives liveness from CHANGE, not wall-clock content:
    a worker whose beacon stops changing for longer than the stall budget
    is hung, wherever its gang-mates happen to block."""

    def __init__(self, directory: Optional[str], rank: int):
        self.dir = directory
        self.rank = rank
        if directory:
            os.makedirs(directory, exist_ok=True)

    @classmethod
    def from_env(cls, rank: int) -> "BeaconWriter":
        return cls(os.environ.get(ENV_BEACON_DIR) or None, rank)

    def write(self, step: int, phase: str, **extra) -> None:
        if not self.dir:
            return
        payload = dict(proc=self.rank, step=int(step), phase=phase,
                       time=time.time(), **extra)
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, os.path.join(self.dir,
                                     f"beacon_{self.rank}.json"))


def read_beacons(directory: Optional[str]) -> Dict[int, dict]:
    """{rank: beacon dict} for every parseable beacon in `directory`.
    Tolerates missing dirs and torn/absent files (atomic writes make the
    latter transient)."""
    out: Dict[int, dict] = {}
    if not directory or not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if not (name.startswith("beacon_") and name.endswith(".json")):
            continue
        try:
            with open(os.path.join(directory, name)) as f:
                b = json.load(f)
            out[int(b["proc"])] = b
        except (OSError, ValueError, KeyError):
            continue
    return out
