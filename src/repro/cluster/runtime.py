"""`jax.distributed` bootstrap + host-gather helpers.

A worker joins the job from exactly three env variables (set by the
launcher — `repro._flags.cluster_env`) or from explicit arguments:

  REPRO_CLUSTER_COORD    "host:port" of process 0's coordinator service
  REPRO_CLUSTER_NPROCS   total process count
  REPRO_CLUSTER_PROC_ID  this worker's rank

`ensure_initialized()` is guarded three ways so single-process callers are
untouched: it is a no-op when the variables are absent, idempotent when
called twice, and must run before jax first initializes its backends
(call it at the top of `main()`, before any `jax.devices()`/`jnp` use).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax

from .._flags import ENV_COORD, ENV_NUM_PROCS, ENV_PROC_ID


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    coordinator: str
    num_processes: int
    process_id: int


def from_env() -> Optional[ClusterConfig]:
    """ClusterConfig from the REPRO_CLUSTER_* variables; None when not a
    cluster worker.  Half-set variables are an error, not a silent no-op —
    a worker that quietly ran single-process would deadlock its peers."""
    present = [v for v in (ENV_COORD, ENV_NUM_PROCS, ENV_PROC_ID)
               if os.environ.get(v)]
    if not present:
        return None
    if len(present) != 3:
        raise RuntimeError(
            f"partial cluster environment: have {present}, need all of "
            f"{[ENV_COORD, ENV_NUM_PROCS, ENV_PROC_ID]}")
    return ClusterConfig(coordinator=os.environ[ENV_COORD],
                         num_processes=int(os.environ[ENV_NUM_PROCS]),
                         process_id=int(os.environ[ENV_PROC_ID]))


_initialized = False


def ensure_initialized(cfg: Optional[ClusterConfig] = None) -> bool:
    """Join the distributed job described by `cfg` (default: env vars).

    Returns True when running multi-process-initialized, False for plain
    single-process callers.  Must be called before jax touches devices.
    """
    global _initialized
    if _initialized:
        return True
    cfg = cfg or from_env()
    if cfg is None:
        return False
    # CPU collectives for cross-process ppermute/all_gather.  The value
    # comes from JAX_CPU_COLLECTIVES_IMPLEMENTATION (an explicit operator
    # choice, e.g. "mpi", wins over the gloo default) but must be applied
    # via config.update — jax 0.4.37 does not read this env var itself.
    impl = os.environ.get("JAX_CPU_COLLECTIVES_IMPLEMENTATION", "gloo")
    try:
        jax.config.update("jax_cpu_collectives_implementation", impl)
    except (AttributeError, LookupError):
        pass
    jax.distributed.initialize(coordinator_address=cfg.coordinator,
                               num_processes=cfg.num_processes,
                               process_id=cfg.process_id)
    _initialized = True
    return True


def process_index() -> int:
    return jax.process_index()


def process_count() -> int:
    return jax.process_count()


def is_primary() -> bool:
    """True on the process that should own side effects (checkpoint
    writes, report files); all processes in a single-process job."""
    return jax.process_index() == 0


def is_distributed() -> bool:
    return jax.process_count() > 1


def gather(tree):
    """Host-local numpy copy of a tree of (possibly process-spanning)
    arrays.  A collective when multi-process — every process must call it
    with the same tree structure."""
    import numpy as np

    from ..dist import compat as dist_compat
    if jax.process_count() == 1:
        return jax.tree.map(np.asarray, tree)
    return dist_compat.process_allgather(tree)
