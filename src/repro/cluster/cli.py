"""`python -m repro.cluster` — run the engine across real processes.

  python -m repro.cluster run --nprocs 2 [--shards H] [--grid 2x2] ...
      one multi-process run on localhost; verifies the gathered raster is
      bit-identical to the single-process engine for the same config.

  python -m repro.cluster sweep [--nprocs-list 1,2] [--quick] [--out DIR]
      strong-scaling over process counts at fixed total shards H: every
      point must produce the identical raster (paper Table 1 across the
      process axis) and reports per-process phase A / exchange / phase B
      walls (paper Figs. 5-8), written as BENCH_cluster_scaling.json.
"""
from __future__ import annotations

import argparse
import sys

from . import local
from . import report as crep
from . import worker as cworker


def workload_namespace(**kw):
    """Workload namespace with worker defaults, overridden by `kw`."""
    ap = argparse.ArgumentParser()
    cworker.add_workload_args(ap)
    args = ap.parse_args([])
    for k, v in kw.items():
        setattr(args, k, v)
    return args


def run_point(args, nprocs: int, timeout: float = 900.0) -> dict:
    """Launch one `nprocs`-process run of the workload in `args`; returns
    the aggregated scaling row.  `args.tuned_env` (the `--tuned-env`
    flag) launches the workers under the tcmalloc/XLA host-tuning preset
    (`_flags.tuned_host_env`); the workers record it in their result
    JSON so A/B rows stay distinguishable.

    `args.supervise` routes through `local.supervised_launch`: beacon
    stall detection, fault-injection arming (`args.fault` or the ambient
    REPRO_FAULT, first attempt only), and gang relaunch under
    `args.max_restarts`; the restart history lands in the row's
    `recovery` dict."""
    H = args.shards
    if H % nprocs != 0:
        raise ValueError(f"shards {H} not divisible by nprocs {nprocs}")
    cmd = ["-m", "repro.cluster.worker", *cworker.workload_argv(args)]
    attempts = []
    if getattr(args, "supervise", False):
        outputs, attempts = local.supervised_launch(
            cmd, nprocs=nprocs, devices_per_proc=H // nprocs,
            timeout=timeout,
            stall_timeout=getattr(args, "stall_timeout", 120.0),
            max_restarts=getattr(args, "max_restarts", 2),
            fault=getattr(args, "fault", None),
            tuned_env=getattr(args, "tuned_env", False))
    else:
        outputs = local.launch(cmd, nprocs=nprocs,
                               devices_per_proc=H // nprocs,
                               timeout=timeout,
                               tuned_env=getattr(args, "tuned_env", False))
    return crep.summarize_point(crep.parse_worker_outputs(outputs),
                                attempts=attempts)


def run_plan_cell(cell: dict, timeout=None) -> dict:
    """Plan-driven sweep entry: one expanded experiment-plan cell
    (repro.bench.plans) as a real multi-process launch.  Maps the cell's
    axis values onto the worker workload contract and returns the
    aggregated scaling row (wall, per-phase maxima, raster signature) —
    the same shape `sweep` points carry, so plan results and
    BENCH_cluster_scaling history stay directly comparable."""
    args = workload_namespace(
        grid=cell["grid"],
        neurons_per_column=cell["neurons_per_column"],
        synapses=cell["synapses_per_neuron"],
        seed=cell["seed"],
        steps=cell["steps"],
        phase_steps=cell["phase_steps"],
        shards=cell["shards"],
        exchange=cell["exchange"],
        exchange_schedule=cell["exchange_schedule"],
        placement=cell["placement"],
        delivery=cell["delivery"],
        connectivity_mode=cell["connectivity"],
        profile=cell["profile"],
        stim_events=cell["stim_events"],
        stim_amplitude=cell["stim_amplitude"])
    from ..bench import subproc
    return run_point(args, cell["nprocs"],
                     timeout=subproc.resolve_timeout(timeout))


def reference_signatures(args) -> tuple:
    """(raster_sig, weights_sig) from the single-process vmap engine for
    the same (seed, grid) config — the ground truth `run --verify`
    compares with: a supervised run that crashed and recovered must match
    BOTH, the Table 1 invariant extended along the failure axis.  Runs on
    this process's single default device (logical shards only);
    dispatches on the workload's delivery backend like the workers do."""
    import numpy as np

    from ..core import EngineConfig, GridConfig, StepProgram, observables

    gx, gy = (int(v) for v in args.grid.split("x"))
    cfg = GridConfig(grid_x=gx, grid_y=gy,
                     neurons_per_column=args.neurons_per_column,
                     synapses_per_neuron=args.synapses, seed=args.seed,
                     connectivity=getattr(args, "profile", "ring3"),
                     stim_events_per_ms_per_column=getattr(
                         args, "stim_events", 1),
                     stim_amplitude=getattr(args, "stim_amplitude",
                                            20.0))
    eng = EngineConfig(n_shards=args.shards, exchange=args.exchange,
                       placement=args.placement,
                       delivery=getattr(args, "delivery", "dense"),
                       connectivity=getattr(args, "connectivity_mode",
                                            "materialized"))
    sp = StepProgram(cfg, eng)
    state, t0 = sp.init_state(), 0
    if getattr(args, "ckpt", None):
        state, t0 = sp.load(args.ckpt)
    state_f, raster, _ = sp.run(state, t0, args.steps)
    return (observables.raster_signature(np.asarray(raster),
                                         np.asarray(sp.plan.gid)).hex(),
            sp.weight_signature(state_f).hex())


def reference_signature(args) -> str:
    """Raster-only reference (see `reference_signatures`)."""
    return reference_signatures(args)[0]


def cmd_run(args) -> int:
    """`run`: one localhost multi-process job; prints the per-process
    phase walls and (unless --no-verify) checks the gathered raster AND
    final weights bit-match the single-process engine.  Exit 1 on a
    mismatch.  With --supervise, injected or real failures are recovered
    by gang relaunch from the newest valid epoch (see --ckpt-every) and
    the restart history is printed."""
    if args.shards is None:
        args.shards = args.nprocs
    if (getattr(args, "supervise", False) and args.ckpt_every > 0
            and not args.ckpt_dir):
        # recovery needs a place for epochs; default to a fresh temp dir
        import tempfile
        args.ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    row = run_point(args, args.nprocs, timeout=args.timeout)
    print(f"[cluster] {args.nprocs} procs x "
          f"{args.shards // args.nprocs} shards: wall {row['wall_s']}s, "
          f"rate {row['rate_hz']} Hz, raster {row['raster_sig'][:16]}..., "
          f"weights {row.get('weights_sig', '?')[:16]}...")
    for pp in row["per_proc"]:
        print(f"[cluster]   proc {pp['proc']}: " + ", ".join(
            f"{k}={pp[k]}" for k in pp if k != "proc"))
    rec = row.get("recovery", {})
    if rec.get("restarts"):
        print(f"[cluster] recovered after {rec['restarts']} restart(s); "
              f"resumed at t={rec.get('restored_t')} "
              f"({rec.get('recovered_steps', 0)} steps salvaged)")
        for a in rec.get("attempts", []):
            print(f"[cluster]   attempt {a['index']}: {a['reason']} "
                  f"(backoff {a['backoff_s']}s)")
    if args.verify:
        ref_r, ref_w = reference_signatures(args)
        fail = []
        if ref_r != row["raster_sig"]:
            fail.append(f"raster {row['raster_sig'][:16]} != {ref_r[:16]}")
        if row.get("weights_sig") and ref_w != row["weights_sig"]:
            fail.append(
                f"weights {row['weights_sig'][:16]} != {ref_w[:16]}")
        if fail:
            print(f"[cluster] FAIL: differs from single-process engine "
                  f"({'; '.join(fail)})")
            return 1
        print("[cluster] verify OK: raster and weights bit-identical to "
              "the single-process engine")
    return 0


def sweep_report(quick: bool = False, nprocs_list=None, out: str = None,
                 timeout: float = 900.0, profile: str = "ring3",
                 delivery: str = "dense", exchange_schedule: str = "sync",
                 tuned_env: bool = False, ckpt_every: int = 0) -> dict:
    """Run the strong-scaling sweep; returns (and optionally writes) the
    BENCH report.  Total shards H = max process count, so the 1-process
    point runs H local shards and the P-process point H/P each — the
    ISSUE's headline invariant.  `profile` selects the lateral-connectivity
    kernel (repro.core.profiles) and `delivery` the synaptic backend; the
    invariant must — and does — hold at every reach and for both
    backends.  `ckpt_every` > 0 adds periodic checkpointing (fresh epoch
    dir per point) so the rows carry `ckpt_wall_s` — the data behind the
    EXPERIMENTS.md recovery-overhead table."""
    import tempfile

    from ..bench import report as bench_report

    nprocs_list = sorted(nprocs_list or [1, 2])
    args = workload_namespace(
        grid="2x2",
        neurons_per_column=60 if quick else 150,
        synapses=25 if quick else 60,
        steps=60 if quick else 150,
        phase_steps=15 if quick else 40,
        shards=max(nprocs_list),
        profile=profile,
        delivery=delivery,
        exchange_schedule=exchange_schedule,
        tuned_env=tuned_env,
        ckpt_every=ckpt_every)
    rows = []
    for p in nprocs_list:
        if ckpt_every > 0:
            # fresh per point: a stale epoch would otherwise short-circuit
            # the run via the worker's self-resume
            args.ckpt_dir = tempfile.mkdtemp(prefix=f"repro_sweep_p{p}_")
        row = run_point(args, p, timeout=timeout)
        print(f"[cluster] point nprocs={p}: wall {row['wall_s']}s "
              f"sig {row['raster_sig'][:16]}", flush=True)
        rows.append(row)
    for key in ("raster_sig", "weights_sig"):
        sigs = {r[key] for r in rows if key in r}
        if len(sigs) > 1:
            raise RuntimeError(
                f"paper Table 1 invariant violated across the process "
                f"axis ({key}): "
                f"{[(r['nprocs'], r[key][:16]) for r in rows]}")
    config = dict(quick=quick, nprocs=nprocs_list, shards=args.shards,
                  grid=args.grid, neurons_per_column=args.neurons_per_column,
                  synapses=args.synapses, steps=args.steps,
                  phase_steps=args.phase_steps, exchange=args.exchange,
                  placement=args.placement, profile=args.profile,
                  delivery=args.delivery,
                  exchange_schedule=args.exchange_schedule,
                  tuned_env=tuned_env)
    if ckpt_every > 0:       # only when set: keeps old baselines comparable
        config["ckpt_every"] = ckpt_every
    rep = crep.scaling_report(rows, config)
    if out:
        path = bench_report.save(rep, out)
        print(f"[cluster] wrote {path}")
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.cluster",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("run", help="one multi-process run on localhost")
    rp.add_argument("--nprocs", type=int, default=2)
    cworker.add_workload_args(rp)
    rp.set_defaults(shards=None)
    rp.add_argument("--timeout", type=float, default=900.0)
    rp.add_argument("--no-verify", dest="verify", action="store_false",
                    help="skip the single-process bit-identity check")
    rp.add_argument("--tuned-env", action="store_true",
                    help="launch workers under the tcmalloc/XLA host-"
                         "tuning preset (_flags.tuned_host_env); recorded "
                         "in the result JSON for A/B comparison")
    rp.add_argument("--supervise", action="store_true",
                    help="beacon stall detection + gang relaunch from the "
                         "newest valid epoch on any failure (see "
                         "--ckpt-every / --max-restarts)")
    rp.add_argument("--fault", default=None,
                    help="deterministic fault to inject on the FIRST "
                         "attempt (repro.cluster.faults grammar, e.g. "
                         "crash@step=30:rank=1); default: the ambient "
                         "REPRO_FAULT variable")
    rp.add_argument("--max-restarts", type=int, default=2,
                    help="supervised restart budget (relaunches, not "
                         "counting the first attempt)")
    rp.add_argument("--stall-timeout", type=float, default=120.0,
                    help="supervised: declare the gang hung when no "
                         "worker beacon changes for this many seconds")

    sp = sub.add_parser("sweep", help="strong scaling over process counts")
    sp.add_argument("--nprocs-list", default="1,2",
                    help="comma-separated process counts (default 1,2)")
    sp.add_argument("--quick", action="store_true",
                    help="CI-sized workload")
    sp.add_argument("--out", default="results/cluster",
                    help="directory for BENCH_cluster_scaling.json")
    sp.add_argument("--timeout", type=float, default=900.0,
                    help="per-point launch timeout (seconds)")
    sp.add_argument("--profile", default="ring3",
                    help="lateral-connectivity profile spec "
                         "(repro.core.profiles)")
    sp.add_argument("--delivery", default="dense",
                    choices=["dense", "event"],
                    help="synaptic delivery backend for every sweep point")
    sp.add_argument("--exchange-schedule", default="sync",
                    choices=["sync", "pipelined"],
                    help="exchange issue order for every sweep point")
    sp.add_argument("--tuned-env", action="store_true",
                    help="launch workers under the tcmalloc/XLA host-"
                         "tuning preset")
    sp.add_argument("--ckpt-every", type=int, default=0,
                    help="periodic checkpoint period K for every point "
                         "(0 = off); rows then carry ckpt_wall_s — the "
                         "EXPERIMENTS.md recovery-overhead data")

    args = ap.parse_args(argv)
    if args.cmd == "run":
        return cmd_run(args)
    nprocs_list = [int(v) for v in args.nprocs_list.split(",") if v]
    sweep_report(quick=args.quick, nprocs_list=nprocs_list, out=args.out,
                 timeout=args.timeout, profile=args.profile,
                 delivery=args.delivery,
                 exchange_schedule=args.exchange_schedule,
                 tuned_env=args.tuned_env, ckpt_every=args.ckpt_every)
    return 0


if __name__ == "__main__":
    sys.exit(main())
