"""repro.cluster — true multi-process execution of the DPSNN engine.

The paper runs DPSNN-STDP as N communicating MPI processes on a commodity
cluster; everything else in this repo distributes *within* one process
(vmap logical shards, shard_map over forced host devices).  This package
crosses the process boundary:

  runtime — `jax.distributed` bootstrap from env vars (no-op for
      single-process callers) + host-gather helpers.
  local — a localhost process launcher: the paper's "small-scale commodity
      cluster" in miniature.  Spawns N workers with per-process env wiring
      (coordinator address, forced device counts), collects their stdout,
      reaps the survivors when any worker fails.
  worker — the per-process entry point: joins the job, builds its shards,
      runs the engine over the process-spanning `cells` mesh, reports
      per-phase timings and the globally-gathered raster signature.
  report — aggregates worker results into strong/weak-scaling rows and a
      BENCH-schema report (`repro.bench.report`), gated in CI.
  cli — `python -m repro.cluster run|sweep`.

The headline invariant is the paper's Table 1 check extended across the
process axis: rasters are bit-identical for 1 process x H shards vs
P processes x H/P shards (tests/test_cluster_smoke.py) — at every
lateral-connectivity profile (`--profile`, core.profiles) and for BOTH
delivery backends (`--delivery dense|event`, core.event_engine: the
paper's event-driven formulation runs under the same process-spanning
meshes and exchange wires).

Public API:

  runtime.ensure_initialized(cfg=None)   join the job from REPRO_CLUSTER_*
      env (the bootstrap; call before ANY jax computation; no-op outside
      a cluster job, idempotent inside one)
  runtime.gather(tree)       host-local numpy copy of process-spanning
      arrays (a collective when multi-process)
  runtime.is_primary() / is_distributed() / process_index() / count()
  local.launch(cmd, nprocs, devices_per_proc)   spawn + reap N workers
  cli: python -m repro.cluster run   one localhost multi-process job,
      verified bit-identical against the single-process engine
  cli: python -m repro.cluster sweep   strong scaling over process
      counts -> BENCH_cluster_scaling.json
"""
from . import local, report, runtime

__all__ = ["local", "report", "runtime"]
