"""Localhost process launcher — the paper's "small-scale commodity
cluster" in miniature.

Spawns N worker interpreters, each wired with the coordinator address and
its own forced host-device count (via the last-flag-wins `XLA_FLAGS`
append in `repro._flags`), collects their merged stdout/stderr, and reaps
the survivors as soon as any worker fails or the deadline passes — a hung
collective must never hang the parent.

This module is deliberately jax-free: the parent that launches a cluster
(pytest, the CLI, a bench suite) must keep its own single default device.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import tempfile
import time
from typing import List, Optional, Sequence

import repro
from .._flags import cluster_env

# src/ directory containing the `repro` package, exported on the child
# PYTHONPATH so workers import `repro` even when the parent runs
# uninstalled (same derivation as repro.bench.subproc).
SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))

_TAIL = 2000


class LaunchError(RuntimeError):
    """A worker failed or the launch timed out.

    Attributes: `returncodes` (per-process, None = still running when
    reaped) and `outputs` (per-process merged stdout/stderr, possibly
    partial)."""

    def __init__(self, msg: str, returncodes: Sequence[Optional[int]],
                 outputs: Sequence[str]):
        self.returncodes = list(returncodes)
        self.outputs = list(outputs)
        tails = "\n".join(
            f"--- proc {i} (rc={rc}) ---\n{out[-_TAIL:] or '<no output>'}"
            for i, (rc, out) in enumerate(zip(returncodes, outputs)))
        super().__init__(f"{msg}\n{tails}")


def free_port() -> int:
    """An OS-assigned free TCP port for the coordinator service."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn_supported() -> bool:
    """Static check that this platform can run the localhost cluster at
    all (tests additionally probe a live 2-process job before relying on
    it — see tests/test_cluster_smoke.py)."""
    return os.name == "posix" and bool(sys.executable)


def _reap(procs) -> None:
    """Terminate, then kill, every still-running worker."""
    for p in procs:
        if p.poll() is None:
            p.terminate()
    deadline = time.monotonic() + 5.0
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()


def launch(cmd: Sequence[str], nprocs: int, devices_per_proc: int = 1,
           timeout: float = 900.0, port: Optional[int] = None,
           extra_env: Optional[dict] = None, echo: bool = False,
           tuned_env: bool = False) -> List[str]:
    """Run `cmd` (argv after the interpreter, e.g. `["-m",
    "repro.cluster.worker", ...]`) as `nprocs` coordinated processes.

    Returns the per-process merged stdout/stderr once all exit 0.  On any
    nonzero exit or timeout, every surviving worker is reaped and a
    `LaunchError` carries the per-process exit codes and output tails.
    `tuned_env=True` launches every worker under the tcmalloc/logging
    host-tuning preset (`_flags.tuned_host_env`; numerics-neutral by
    construction, marked via REPRO_TUNED_ENV in the worker result).
    """
    if nprocs < 1:
        raise ValueError(f"nprocs must be >= 1, got {nprocs}")
    coordinator = f"127.0.0.1:{port or free_port()}"
    procs, files = [], []
    try:
        for pid in range(nprocs):
            env = cluster_env(devices_per_proc, SRC, coordinator=coordinator,
                              num_processes=nprocs, process_id=pid,
                              tuned=tuned_env)
            env.update(extra_env or {})
            f = tempfile.TemporaryFile(mode="w+", encoding="utf-8",
                                       errors="replace")
            files.append(f)
            procs.append(subprocess.Popen(
                [sys.executable, *cmd], stdout=f, stderr=subprocess.STDOUT,
                env=env, text=True))

        deadline = time.monotonic() + timeout
        pending = set(range(nprocs))
        failed = timed_out = False
        while pending and not failed:
            for i in sorted(pending):
                rc = procs[i].poll()
                if rc is not None:
                    pending.discard(i)
                    if rc != 0:
                        failed = True
                        break
            if pending and not failed:
                if time.monotonic() > deadline:
                    timed_out = True
                    break
                time.sleep(0.05)

        if failed or timed_out:
            _reap(procs)
        outputs = []
        for f in files:
            f.seek(0)
            outputs.append(f.read())
        if failed or timed_out:
            reason = (f"cluster launch timed out after {timeout:.0f}s"
                      if timed_out else "cluster worker failed")
            raise LaunchError(
                f"{reason} ({nprocs} procs x {devices_per_proc} devices, "
                f"cmd={list(cmd)!r})",
                [p.poll() for p in procs], outputs)
    finally:
        _reap(procs)
        for f in files:
            f.close()

    if echo:
        for i, out in enumerate(outputs):
            for line in out.splitlines():
                print(f"[p{i}] {line}")
    return outputs
