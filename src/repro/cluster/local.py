"""Localhost process launcher — the paper's "small-scale commodity
cluster" in miniature, now with a supervisor.

Spawns N worker interpreters, each wired with the coordinator address and
its own forced host-device count (via the last-flag-wins `XLA_FLAGS`
append in `repro._flags`), collects their merged stdout/stderr, and reaps
the survivors as soon as any worker fails, stalls, or the deadline passes
— a hung collective must never hang the parent.

Two launch modes:

  `launch`            one gang, one life: any worker failure raises
                      `LaunchError` (carrying exit codes, output tails,
                      any partial CLUSTER_RESULT payloads, and which
                      workers needed SIGKILL vs SIGTERM to die).
  `supervised_launch` production mode: per-worker file beacons replace
                      the single blunt deadline with *progress*-based
                      stall detection, and any gang failure triggers a
                      reap + full-gang relaunch with exponential backoff
                      under a bounded restart budget.  Workers self-resume
                      from the newest VALID epoch in their `--ckpt-dir`
                      (sha256-verified, corrupt epochs skipped), so a
                      restart costs at most one checkpoint period of
                      replay — and, by the reproducible-construction
                      property, changes no output bit.

This module is deliberately jax-free: the parent that launches a cluster
(pytest, the CLI, a bench suite) must keep its own single default device.
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional, Sequence, Tuple

import repro
from .._flags import cluster_env
from . import faults
from .worker import RESULT_PREFIX

# src/ directory containing the `repro` package, exported on the child
# PYTHONPATH so workers import `repro` even when the parent runs
# uninstalled (same derivation as repro.bench.subproc).
SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))

_TAIL = 2000

# stdout markers of a coordinator that lost the free_port() TOCTOU race
# (the probed port was re-taken before jax.distributed bound it)
_BIND_MARKERS = ("Address already in use", "address already in use",
                 "Failed to bind", "EADDRINUSE")


def partial_results(outputs: Sequence[str]) -> Dict[int, dict]:
    """{proc index: parsed CLUSTER_RESULT payload} for every worker that
    managed to emit one before the gang died — postmortem material that
    rides on `LaunchError`."""
    out: Dict[int, dict] = {}
    for i, text in enumerate(outputs):
        for ln in text.splitlines():
            if ln.startswith(RESULT_PREFIX):
                try:
                    out[i] = json.loads(ln[len(RESULT_PREFIX):])
                except ValueError:
                    pass
    return out


class LaunchError(RuntimeError):
    """A worker failed, stalled, or the launch timed out.

    Attributes: `returncodes` (per-process, None = still running when
    reaped), `outputs` (per-process merged stdout/stderr, possibly
    partial), `partial_results` ({proc: CLUSTER_RESULT dict} for workers
    that reported before dying), and `attempts` (supervised-launch
    restart history, [] outside supervision)."""

    def __init__(self, msg: str, returncodes: Sequence[Optional[int]],
                 outputs: Sequence[str],
                 attempts: Optional[List[dict]] = None):
        self.returncodes = list(returncodes)
        self.outputs = list(outputs)
        self.partial_results = partial_results(outputs)
        self.attempts = list(attempts or [])
        extra = ""
        if self.partial_results:
            extra += (f"\npartial CLUSTER_RESULT payloads recovered from "
                      f"proc(s) {sorted(self.partial_results)} "
                      f"(.partial_results)")
        if self.attempts:
            lines = [f"  attempt {a['index']}: {a['reason']} "
                     f"(rc={a['returncodes']}, backoff {a['backoff_s']}s)"
                     for a in self.attempts]
            extra += "\nrestart history:\n" + "\n".join(lines)
        tails = "\n".join(
            f"--- proc {i} (rc={rc}) ---\n{out[-_TAIL:] or '<no output>'}"
            for i, (rc, out) in enumerate(zip(returncodes, outputs)))
        super().__init__(f"{msg}{extra}\n{tails}")


def free_port() -> int:
    """An OS-assigned free TCP port for the coordinator service.  Probe
    and bind are separate processes, so this is inherently racy (TOCTOU);
    `launch` retries once on a fresh port when the coordinator loses."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn_supported() -> bool:
    """Static check that this platform can run the localhost cluster at
    all (tests additionally probe a live 2-process job before relying on
    it — see tests/test_cluster_smoke.py)."""
    return os.name == "posix" and bool(sys.executable)


def _reap(procs, total_timeout: float = 5.0) -> dict:
    """Terminate, then kill, every still-running worker.

    The grace wait is bounded by ONE shared deadline across the whole
    gang (per-proc timeouts previously stacked to nprocs x 0.1s minimum);
    returns {"terminated": [...], "killed": [...]} so the error tails can
    record which workers ignored SIGTERM and needed SIGKILL."""
    info = dict(terminated=[], killed=[])
    for i, p in enumerate(procs):
        if p.poll() is None:
            info["terminated"].append(i)
            p.terminate()
    deadline = time.monotonic() + total_timeout
    for i, p in enumerate(procs):
        if p.poll() is None:
            try:
                p.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                info["killed"].append(i)
                p.kill()
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                pass
    return info


def _reap_tail(info: dict) -> str:
    if not info.get("terminated") and not info.get("killed"):
        return ""
    return (f"; reaped: SIGTERM -> procs {info.get('terminated', [])}"
            f", SIGKILL needed -> procs {info.get('killed', [])}")


def _launch_attempt(cmd: Sequence[str], nprocs: int, devices_per_proc: int,
                    timeout: float, coordinator: str,
                    extra_env: Optional[dict], tuned_env: bool,
                    stall_timeout: Optional[float] = None,
                    beacon_dir: Optional[str] = None) -> List[str]:
    """One gang, one life: spawn, monitor, collect or raise.

    With `stall_timeout` set, per-worker beacon files (written by the
    workers into `beacon_dir`, see `cluster.faults.BeaconWriter`) provide
    progress-based liveness: the gang is declared stalled when NO beacon
    changes for `stall_timeout` seconds (a hang in any one worker freezes
    the whole gang at its next collective, so gang-level change is the
    right signal and per-rank cadence differences cannot false-positive).
    """
    procs, files = [], []
    try:
        for pid in range(nprocs):
            env = cluster_env(devices_per_proc, SRC, coordinator=coordinator,
                              num_processes=nprocs, process_id=pid,
                              tuned=tuned_env)
            env.update(extra_env or {})
            f = tempfile.TemporaryFile(mode="w+", encoding="utf-8",
                                       errors="replace")
            files.append(f)
            procs.append(subprocess.Popen(
                [sys.executable, *cmd], stdout=f, stderr=subprocess.STDOUT,
                env=env, text=True))

        start = time.monotonic()
        deadline = start + timeout
        pending = set(range(nprocs))
        failed = timed_out = False
        stalled: Optional[str] = None
        progress: Dict[int, Tuple[tuple, float]] = {}
        while pending and not failed:
            for i in sorted(pending):
                rc = procs[i].poll()
                if rc is not None:
                    pending.discard(i)
                    if rc != 0:
                        failed = True
                        break
            if pending and not failed:
                now = time.monotonic()
                if now > deadline:
                    timed_out = True
                    break
                if stall_timeout is not None:
                    for rank, b in faults.read_beacons(beacon_dir).items():
                        sig = (b.get("step"), b.get("phase"))
                        if progress.get(rank, (None, 0.0))[0] != sig:
                            progress[rank] = (sig, now)
                    last = max([t for _, t in progress.values()]
                               or [start])
                    if now - last > stall_timeout:
                        at = {r: s for r, (s, _) in progress.items()}
                        stalled = (f"gang stalled: no beacon progress for "
                                   f"{stall_timeout:.0f}s (last beacons "
                                   f"{at or 'none written'})")
                        break
                time.sleep(0.05)

        reap_info = {}
        if failed or timed_out or stalled:
            reap_info = _reap(procs)
        outputs = []
        for f in files:
            f.seek(0)
            outputs.append(f.read())
        if failed or timed_out or stalled:
            reason = stalled or (
                f"cluster launch timed out after {timeout:.0f}s"
                if timed_out else "cluster worker failed")
            raise LaunchError(
                f"{reason} ({nprocs} procs x {devices_per_proc} devices, "
                f"cmd={list(cmd)!r}{_reap_tail(reap_info)})",
                [p.poll() for p in procs], outputs)
    finally:
        _reap(procs)
        for f in files:
            f.close()
    return outputs


def _bind_failure(outputs: Sequence[str]) -> bool:
    return any(m in out for out in outputs for m in _BIND_MARKERS)


def launch(cmd: Sequence[str], nprocs: int, devices_per_proc: int = 1,
           timeout: float = 900.0, port: Optional[int] = None,
           extra_env: Optional[dict] = None, echo: bool = False,
           tuned_env: bool = False, stall_timeout: Optional[float] = None,
           beacon_dir: Optional[str] = None) -> List[str]:
    """Run `cmd` (argv after the interpreter, e.g. `["-m",
    "repro.cluster.worker", ...]`) as `nprocs` coordinated processes.

    Returns the per-process merged stdout/stderr once all exit 0.  On any
    nonzero exit or timeout, every surviving worker is reaped and a
    `LaunchError` carries the per-process exit codes, output tails, and
    any partial CLUSTER_RESULT payloads.  When the coordinator port was
    auto-assigned (`port=None`) and the failure looks like a lost
    bind race (`free_port`'s TOCTOU window), the launch retries ONCE on a
    fresh port after a short backoff.  `tuned_env=True` launches every
    worker under the tcmalloc/logging host-tuning preset
    (`_flags.tuned_host_env`; numerics-neutral by construction, marked
    via REPRO_TUNED_ENV in the worker result).
    """
    if nprocs < 1:
        raise ValueError(f"nprocs must be >= 1, got {nprocs}")
    try:
        outputs = _launch_attempt(
            cmd, nprocs, devices_per_proc, timeout,
            f"127.0.0.1:{port or free_port()}", extra_env, tuned_env,
            stall_timeout=stall_timeout, beacon_dir=beacon_dir)
    except LaunchError as e:
        if port is not None or not _bind_failure(e.outputs):
            raise
        time.sleep(0.5)
        outputs = _launch_attempt(
            cmd, nprocs, devices_per_proc, timeout,
            f"127.0.0.1:{free_port()}", extra_env, tuned_env,
            stall_timeout=stall_timeout, beacon_dir=beacon_dir)

    if echo:
        for i, out in enumerate(outputs):
            for line in out.splitlines():
                print(f"[p{i}] {line}")
    return outputs


def supervised_launch(cmd: Sequence[str], nprocs: int,
                      devices_per_proc: int = 1, *,
                      timeout: float = 900.0, stall_timeout: float = 120.0,
                      max_restarts: int = 2, backoff_s: float = 0.5,
                      fault: Optional[str] = None,
                      extra_env: Optional[dict] = None,
                      tuned_env: bool = False, expect_result: bool = True,
                      echo: bool = False) -> Tuple[List[str], List[dict]]:
    """Launch under supervision: beacon-based stall detection plus
    retry-with-exponential-backoff relaunch of the whole gang under a
    bounded restart budget.

    The relaunch command never changes: workers self-resume from the
    newest sha256-VALID epoch in their `--ckpt-dir` (corrupt epochs are
    skipped — `core.integrity.latest_valid`), so each restart replays at
    most one checkpoint period and, because chunked execution is
    bit-identical to unchunked, changes no output bit.

    `fault` (default: the ambient REPRO_FAULT variable) arms the
    deterministic injection harness (`cluster.faults`) on the FIRST
    attempt only; recovery attempts always run clean, which is what makes
    every injected failure a terminating, reproducible test case.

    `expect_result=True` additionally treats a worker that exits 0
    without emitting its CLUSTER_RESULT line (the drop_result fault, or a
    real lost report) as a failure to retry.

    Returns `(outputs, attempts)` where `attempts` is the restart history
    — one dict per FAILED attempt (reason, returncodes, last beacons,
    backoff applied); empty when the first attempt succeeded.  Raises
    `LaunchError` carrying the full history once the budget is exhausted.
    """
    fault = os.environ.get(faults.ENV_FAULT, "") if fault is None else fault
    if fault:
        faults.FaultSpec.parse(fault)      # fail fast on bad grammar
    attempts: List[dict] = []
    last: Optional[LaunchError] = None
    for attempt in range(max_restarts + 1):
        bdir = tempfile.mkdtemp(prefix=f"repro_beacon_a{attempt}_")
        env = dict(extra_env or {})
        env[faults.ENV_BEACON_DIR] = bdir
        env[faults.ENV_ATTEMPT] = str(attempt)
        # arm the fault on the first attempt only; explicit "" overrides
        # any ambient REPRO_FAULT the workers would otherwise inherit
        env[faults.ENV_FAULT] = fault if attempt == 0 else ""
        try:
            outputs = launch(cmd, nprocs, devices_per_proc,
                             timeout=timeout, extra_env=env, echo=echo,
                             tuned_env=tuned_env,
                             stall_timeout=stall_timeout, beacon_dir=bdir)
            if expect_result:
                missing = [
                    i for i, out in enumerate(outputs)
                    if sum(ln.startswith(RESULT_PREFIX)
                           for ln in out.splitlines()) != 1]
                if missing:
                    raise LaunchError(
                        f"worker(s) {missing} exited 0 without a "
                        f"CLUSTER_RESULT line", [0] * nprocs, outputs)
            return outputs, attempts
        except LaunchError as e:
            last = e
            backoff = backoff_s * (2 ** attempt)
            attempts.append(dict(
                index=attempt,
                reason=str(e).splitlines()[0],
                returncodes=e.returncodes,
                beacons=faults.read_beacons(bdir),
                backoff_s=backoff))
            if attempt < max_restarts:
                time.sleep(backoff)
    raise LaunchError(
        f"restart budget exhausted after {max_restarts + 1} attempts "
        f"(max_restarts={max_restarts})",
        last.returncodes, last.outputs, attempts=attempts)
