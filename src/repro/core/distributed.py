"""Distributed DPSNN runtime: the same phase-A/B step as `engine`, but with
real collectives under `shard_map` (via `repro.dist.compat`) over a
`cells` mesh axis.

Spike exchange modes (EngineConfig.exchange):

  'allgather' — every shard gathers all shards' spike masks and builds the
      global mask.  Simple, bandwidth ~ N_total bits/step; the right choice
      for small meshes and for `scatter` placement (whose halo is global).

  'halo' — the paper's two-phase sparse delivery, TPU-adapted: each shard
      packs a fixed-capacity AER buffer (ids + count lane, see core.aer) and
      `lax.ppermute`s it along the *static* set of shard offsets that the
      connectivity actually uses (discovered at build time, exactly like the
      paper's first construction step discovers the process subset).
      Received ids are matched against the local source table; the count
      lane is a compute-gating hint (processing cost scales with real
      spikes), while wire bytes are static — the SPMD trade documented in
      DESIGN.md §2.

Delivery modes (EngineConfig.delivery) — orthogonal to the exchange:

  'dense' — O(E) masked delivery (`engine.phase_a/phase_b`).
  'event' — O(spikes x fan) event lists (`event_engine.phase_a/phase_b`),
      the paper's actual computational model.  The exchange wire is
      UNCHANGED: its output `spiked_src` is exactly the event backend's
      phase_b input, so halo/allgather schedules compose with event
      delivery for free.  Callers pass the `EventPlan` (threaded through
      the jitted programs as an argument alongside the ShardPlan — closure
      constants cannot span processes) and an `EventState` whose extra
      leaves (ev_ring, ev_count, sat) ride the same `cells` specs.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import aer, engine, event_engine, stimulus, topology
from .engine import ShardPlan, ShardState, SimSpec
from ..dist import compat as dist_compat
from ..dist import mesh as dist_mesh
from ..dist import sharding as dist_sharding


def halo_offsets(spec: SimSpec, plan: ShardPlan) -> List[int]:
    """Static shard-to-shard offsets used by the connectivity.

    == the paper's construction-phase discovery of "the subset of processes
    that should be listened to", derived locally from the source tables.
    The source tables themselves are provisioned from the connectivity
    profile's `reach()` (topology.shard_halo_columns), so the exchange
    schedule follows the profile automatically: a ring1 kernel shrinks the
    offset set, a gaussian one widens it (DESIGN.md §Connectivity
    profiles) — no constant ring depth appears anywhere downstream.
    """
    H = spec.eng.n_shards
    src_gid = np.asarray(plan.src_gid)            # [H, S]
    offs = set()
    for h in range(H):
        s = src_gid[h]
        s = s[s >= 0]
        owners = np.unique(topology.owner_of(spec.cfg, s, H,
                                             spec.eng.placement))
        for o in owners.tolist():
            offs.add((h - o) % H)                 # sender o -> receiver h
    return sorted(offs)


def make_mesh(n_shards: int) -> Mesh:
    return dist_mesh.make_snn_mesh(n_shards)


def _spiked_src_allgather(spec, plan_gid_all, spiked, src_gid):
    spk_all = jax.lax.all_gather(spiked, "cells")            # [H, N]
    glob = jnp.zeros((spec.n_total,), bool).at[
        plan_gid_all.reshape(-1)].max(spk_all.reshape(-1), mode="drop")
    return glob.at[src_gid].get(mode="fill", fill_value=False) & (src_gid >= 0)


def _spiked_src_halo(spec, offsets, plan, spiked):
    """Sparse AER wire + dense local match.

    Wire: fixed-capacity AER buffers ppermute over the static halo offsets
    (the paper's two-phase delivery).  Match: received ids are scattered
    into a local [N_total] mask, then ONE gather by the source table — a
    per-offset searchsorted match measured 60x more HBM traffic
    (EXPERIMENTS.md §Perf, SNN iteration C)."""
    H = spec.eng.n_shards
    ids, _count = aer.pack(spiked, plan.gid, plan.gid.shape[0])
    received = []
    for d in offsets:
        if d == 0:
            received.append(ids)
        else:
            perm = [(i, (i + d) % H) for i in range(H)]
            received.append(jax.lax.ppermute(ids, "cells", perm=perm))
    # single scatter: one functional mask update instead of |offsets|
    # sequential ones (each re-copied the [N_total] mask: 25 MB/step at
    # 512 columns — §Perf SNN iteration D)
    all_ids = jnp.concatenate(received)
    mask = jnp.zeros((spec.n_total,), bool).at[all_ids].set(
        True, mode="drop")
    return mask.at[plan.src_gid].get(mode="fill", fill_value=False) \
        & (plan.src_gid >= 0)


def _make_exchange(spec: SimSpec, plan: ShardPlan):
    """Per-shard exchange callable (plan_1, spiked_1) -> spiked_src_1.

    Closes over host-side statics only (halo offsets / replicated gid
    table), so the returned callable is safe inside `shard_map` bodies on
    process-spanning meshes.  `plan` must be host-addressable."""
    if spec.eng.exchange == "halo":
        offsets = halo_offsets(spec, plan)
        return lambda p1, s1: _spiked_src_halo(spec, offsets, p1, s1)
    gid_all = jnp.asarray(np.asarray(plan.gid))   # replicated [H, N]
    return lambda p1, s1: _spiked_src_allgather(spec, gid_all, s1, p1.src_gid)


# ---------------------------------------------------------------------------
# delivery dispatch: both backends share the plan/state/exchange plumbing
# ---------------------------------------------------------------------------


def _is_event(spec: SimSpec) -> bool:
    return spec.eng.delivery == "event"


def _base_plan(planT):
    """The ShardPlan inside a delivery-dependent plan tree (event mode
    carries (ShardPlan, EventPlan); NamedTuples are tuples, so dispatch on
    the concrete type, not tuple-ness)."""
    return planT if isinstance(planT, ShardPlan) else planT[0]


def _plan_tree(spec: SimSpec, plan: ShardPlan, eplan):
    if not _is_event(spec):
        return plan
    if eplan is None:
        raise ValueError("delivery='event' needs the EventPlan: pass "
                         "eplan= (from event_engine.build)")
    return (plan, eplan)


def _delivery_phases(spec: SimSpec, stim_k, caps: Optional[dict] = None):
    """Per-shard (phase_a, phase_b) callables over the delivery-dependent
    plan tree.  Both backends share the signature
    (planT_1, state_1, ...) -> ... with phase_a returning
    (state', spiked, StepTimings)."""
    caps = caps or {}
    if _is_event(spec):
        c_post, c_src = caps.get("c_post"), caps.get("c_src")

        def pa(planT, st, t):
            p, ep = planT
            return event_engine.phase_a(spec, p, ep, st, t, stim_k,
                                        c_post=c_post)

        def pb(planT, st, ss, t):
            p, ep = planT
            return event_engine.phase_b(spec, p, ep, st, ss, t, c_src=c_src)

        return pa, pb

    def pa(planT, st, t):
        return engine.phase_a(spec, planT, st, t, stim_k)

    def pb(planT, st, ss, t):
        return engine.phase_b(spec, planT, st, ss, t)

    return pa, pb


def _specs(spec: SimSpec, planT):
    """(plan, state, per-step-timings) partition specs over `cells`."""
    pspec = P("cells")
    plan_specs = jax.tree.map(lambda _: pspec, planT)
    base = ShardState(*([pspec] * len(ShardState._fields)))
    if _is_event(spec):
        state_specs = event_engine.EventState(
            base=base, ev_ring=pspec, ev_count=pspec, sat=pspec)
    else:
        state_specs = base
    tm_specs = engine.StepTimings(spikes=pspec, arrivals=pspec)
    return pspec, plan_specs, state_specs, tm_specs


def _drop_lead(tree):
    """shard_map passes [1, ...] slices; drop the leading axis."""
    return jax.tree.map(lambda x: x[0], tree)


def make_sharded_run(spec: SimSpec, plan: ShardPlan, mesh: Mesh,
                     eplan=None, caps: Optional[dict] = None):
    """Returns run(state, t0, n_steps) -> (state, raster, timings), executing
    one shard per device of the `cells` mesh axis.

    `plan` must be HOST-addressable (the stacked tree `build` returns):
    halo discovery reads it with numpy, and it is then placed on `mesh`
    here and threaded through the jitted program as an *argument* — a
    closure constant cannot span processes, and even single-process it
    re-materializes ~50x slower on CPU (EXPERIMENTS.md §Perf).

    With spec.eng.delivery == 'event', `eplan` (host-addressable, from
    `event_engine.build`) rides along the same way and `state` must be an
    EventState; `caps` optionally overrides the event compaction
    capacities (dict with 'c_post'/'c_src' — tests force tiny ones)."""
    stim_k = stimulus.stim_key(spec.cfg)
    exchange = _make_exchange(spec, plan)
    planT = _plan_tree(spec, plan, eplan)
    pa, pb = _delivery_phases(spec, stim_k, caps)
    pspec, plan_specs, state_specs, tm_specs = _specs(spec, planT)
    plan_d = dist_sharding.shard_put(mesh, planT, "cells")

    def shard_body(plan_s, state_s, ts):
        plan_1 = _drop_lead(plan_s)
        state_1 = _drop_lead(state_s)

        def step(state, t):
            state, spiked, tm = pa(plan_1, state, t)
            spiked_src = exchange(_base_plan(plan_1), spiked)
            state = pb(plan_1, state, spiked_src, t)
            return state, (spiked, tm)

        state_1, (raster, tm) = jax.lax.scan(step, state_1, ts)
        out_state = jax.tree.map(lambda x: x[None], state_1)
        return (out_state, raster[:, None],
                jax.tree.map(lambda x: x[:, None], tm))

    # scan outputs carry a leading time axis in front of each per-call spec
    run = jax.jit(dist_compat.shard_map(
        shard_body, mesh,
        in_specs=(plan_specs, state_specs, P()),
        out_specs=(state_specs, P(None, *pspec),
                   jax.tree.map(lambda s: P(None, *s), tm_specs))))

    def runner(state, t0: int, n_steps: int):
        ts = dist_sharding.replicated_put(
            mesh, jnp.arange(t0, t0 + n_steps, dtype=jnp.int32))
        state, raster, tm = run(plan_d, state, ts)
        return state, raster, tm

    return runner


def make_phase_fns(spec: SimSpec, plan: ShardPlan, mesh: Mesh,
                   eplan=None, caps: Optional[dict] = None):
    """Separately-jitted shard_map'd phases over `mesh`:

        (phase_a(state, t), exchange(spiked), phase_b(state, spiked_src, t))

    — the real-collective analogue of `bench.profile.make_phase_fns`, used
    by `repro.cluster` to attribute wall-clock to phase A / spike exchange
    / phase B per process (paper Table 2, across the process axis).  The
    placed plan is bound into each returned fn as a jit argument; `plan`
    must be host-addressable, as in `make_sharded_run`.  Dispatches on
    spec.eng.delivery exactly like `make_sharded_run` (same `eplan`/`caps`
    contract), so per-phase walls are comparable across backends."""
    stim_k = stimulus.stim_key(spec.cfg)
    exchange = _make_exchange(spec, plan)
    planT = _plan_tree(spec, plan, eplan)
    pa, pb = _delivery_phases(spec, stim_k, caps)
    pspec, plan_specs, state_specs, tm_specs = _specs(spec, planT)
    plan_d = dist_sharding.shard_put(mesh, planT, "cells")

    def a_body(plan_s, state_s, t):
        state_1, spiked, tm = pa(_drop_lead(plan_s), _drop_lead(state_s), t)
        return (jax.tree.map(lambda x: x[None], state_1), spiked[None],
                jax.tree.map(lambda x: x[None], tm))

    def ex_body(plan_s, spiked_s):
        return exchange(_base_plan(_drop_lead(plan_s)), spiked_s[0])[None]

    def b_body(plan_s, state_s, spiked_src_s, t):
        state_1 = pb(_drop_lead(plan_s), _drop_lead(state_s),
                     spiked_src_s[0], t)
        return jax.tree.map(lambda x: x[None], state_1)

    sm = dist_compat.shard_map
    a_j = jax.jit(sm(a_body, mesh, in_specs=(plan_specs, state_specs, P()),
                     out_specs=(state_specs, pspec, tm_specs)))
    ex_j = jax.jit(sm(ex_body, mesh, in_specs=(plan_specs, pspec),
                      out_specs=pspec))
    b_j = jax.jit(sm(b_body, mesh,
                     in_specs=(plan_specs, state_specs, pspec, P()),
                     out_specs=state_specs))

    def tput(x):
        return dist_sharding.replicated_put(mesh, jnp.int32(x))

    phase_a = lambda state, t: a_j(plan_d, state, tput(t))
    exchange_fn = lambda spiked: ex_j(plan_d, spiked)
    phase_b = lambda state, spiked_src, t: b_j(plan_d, state, spiked_src,
                                               tput(t))
    return phase_a, exchange_fn, phase_b


def time_phases(phase_fns, state, t0: int, n_steps: int,
                collect_rasters: bool = False):
    """Per-step wall-clock attribution over `make_phase_fns` output — the
    paper's Table 2 split, shared by `repro.cluster.worker` and the
    `event_vs_dense` bench suite so the warmup/blocking discipline cannot
    drift between them.

    Returns (final_state, times, rasters): `times` accumulates
    phase_a_s/exchange_s/phase_b_s over `n_steps` steps (each phase
    `block_until_ready`-fenced), `rasters` is a list of per-step [H, N]
    numpy spike masks when `collect_rasters` else None.  The three
    programs are warmed up (compiled) on `state` first; `state` itself is
    never mutated."""
    phase_a, exchange, phase_b = phase_fns
    s_w, spk_w, _ = phase_a(state, t0)
    src_w = exchange(spk_w)
    jax.block_until_ready(phase_b(s_w, src_w, t0))

    times = dict(phase_a_s=0.0, exchange_s=0.0, phase_b_s=0.0)
    rasters = [] if collect_rasters else None
    s = state
    for t in range(t0, t0 + n_steps):
        c0 = time.perf_counter()
        s2, spiked, _ = phase_a(s, t)
        jax.block_until_ready(spiked)
        times["phase_a_s"] += time.perf_counter() - c0
        c0 = time.perf_counter()
        spiked_src = exchange(spiked)
        jax.block_until_ready(spiked_src)
        times["exchange_s"] += time.perf_counter() - c0
        c0 = time.perf_counter()
        s = phase_b(s2, spiked_src, t)
        jax.block_until_ready(s)
        times["phase_b_s"] += time.perf_counter() - c0
        if collect_rasters:
            rasters.append(np.asarray(spiked))
    return s, times, rasters


def shard_put(mesh: Mesh, tree):
    """Place a stacked [H, ...] tree with each shard on its device."""
    return dist_sharding.shard_put(mesh, tree, "cells")
