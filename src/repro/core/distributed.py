"""Distributed DPSNN runtime: the same phase-A/B step as `engine`, but with
real collectives under `shard_map` (via `repro.dist.compat`) over a
`cells` mesh axis.

Spike exchange modes (EngineConfig.exchange):

  'allgather' — every shard gathers all shards' spike masks and builds the
      global mask.  Simple, bandwidth ~ N_total bits/step; the right choice
      for small meshes and for `scatter` placement (whose halo is global).

  'halo' — the paper's two-phase sparse delivery, TPU-adapted: each shard
      packs a fixed-capacity AER buffer (ids + count lane, see core.aer) and
      `lax.ppermute`s it along the *static* set of shard offsets that the
      connectivity actually uses (discovered at build time, exactly like the
      paper's first construction step discovers the process subset).
      Received ids are matched against the local source table; the count
      lane is a compute-gating hint (processing cost scales with real
      spikes), while wire bytes are static — the SPMD trade documented in
      DESIGN.md §2.

  'hier' — two-level hierarchy matching the paper's cluster topology:
      level 1 is an intra-process `all_gather` restricted (via
      axis_index_groups) to the shards one OS process owns — shared-memory
      traffic, never crossing the NIC; level 2 AER-packs the whole group's
      spikes once and `ppermute`s the group buffer only along the *static
      group-stride* set the connectivity reaches (hier_offsets — the halo
      discovery re-run at process granularity).  Inter-process messages
      therefore go only to neighbouring processes, like the paper's
      subset-of-processes delivery, however many shards each process runs.

Exchange schedules (EngineConfig.exchange_schedule) — orthogonal to both:

  'sync'      — phase A -> exchange -> phase B in program order.
  'pipelined' — the exchange for step t is issued right after the
      dynamics half of phase A(t) (which produces the spike mask) and its
      result is consumed by a phase B(t) deferred into the NEXT loop
      iteration, double-buffered through the scan carry.  The collective
      therefore overlaps the LTP half of phase A plus the loop turnaround
      instead of exposing its full latency.  The per-step op sequence —
      B(t-1); A_dyn(t); X(t); A_plast(t) — is a rotation of the sync
      sequence with identical dataflow (A_plast writes {w, last_post},
      B writes the arrival rings; disjoint), so rasters AND weights are
      bit-identical to 'sync' (DESIGN.md §Pipelined exchange).

Delivery modes (EngineConfig.delivery) — orthogonal to the exchange:

  'dense' — O(E) masked delivery (`engine.phase_a/phase_b`).
  'event' — O(spikes x fan) event lists (`event_engine.phase_a/phase_b`),
      the paper's actual computational model.  The exchange wire is
      UNCHANGED: its output `spiked_src` is exactly the event backend's
      phase_b input, so halo/allgather schedules compose with event
      delivery for free.  Callers pass the `EventPlan` (threaded through
      the jitted programs as an argument alongside the ShardPlan — closure
      constants cannot span processes) and an `EventState` whose extra
      leaves (ev_ring, ev_count, sat) ride the same `cells` specs.
"""
from __future__ import annotations

import warnings
from typing import Callable, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import aer, engine, event_engine, stimulus, stream_engine, topology
from .engine import ShardPlan, ShardState, SimSpec
from ..dist import compat as dist_compat
from ..dist import mesh as dist_mesh
from ..dist import sharding as dist_sharding


def halo_offsets(spec: SimSpec, plan: ShardPlan) -> List[int]:
    """Static shard-to-shard offsets used by the connectivity.

    == the paper's construction-phase discovery of "the subset of processes
    that should be listened to", derived locally from the source tables.
    The source tables themselves are provisioned from the connectivity
    profile's `reach()` (topology.shard_halo_columns), so the exchange
    schedule follows the profile automatically: a ring1 kernel shrinks the
    offset set, a gaussian one widens it (DESIGN.md §Connectivity
    profiles) — no constant ring depth appears anywhere downstream.
    """
    H = spec.eng.n_shards
    src_gid = np.asarray(plan.src_gid)            # [H, S]
    offs = set()
    for h in range(H):
        s = src_gid[h]
        s = s[s >= 0]
        owners = np.unique(topology.owner_of(spec.cfg, s, H,
                                             spec.eng.placement))
        for o in owners.tolist():
            offs.add((h - o) % H)                 # sender o -> receiver h
    return sorted(offs)


def make_mesh(n_shards: int) -> Mesh:
    return dist_mesh.make_snn_mesh(n_shards)


def _spiked_src_allgather(spec, plan_gid_all, spiked, src_gid):
    spk_all = jax.lax.all_gather(spiked, "cells")            # [H, N]
    glob = jnp.zeros((spec.n_total,), bool).at[
        plan_gid_all.reshape(-1)].max(spk_all.reshape(-1), mode="drop")
    return glob.at[src_gid].get(mode="fill", fill_value=False) & (src_gid >= 0)


def _spiked_src_halo(spec, offsets, plan, spiked):
    """Sparse AER wire + dense local match.

    Wire: fixed-capacity AER buffers ppermute over the static halo offsets
    (the paper's two-phase delivery).  Match: received ids are scattered
    into a local [N_total] mask, then ONE gather by the source table — a
    per-offset searchsorted match measured 60x more HBM traffic
    (EXPERIMENTS.md §Perf, SNN iteration C)."""
    H = spec.eng.n_shards
    ids, _count = aer.pack(spiked, plan.gid, plan.gid.shape[0])
    received = []
    for d in offsets:
        if d == 0:
            received.append(ids)
        else:
            perm = [(i, (i + d) % H) for i in range(H)]
            received.append(jax.lax.ppermute(ids, "cells", perm=perm))
    # single scatter: one functional mask update instead of |offsets|
    # sequential ones (each re-copied the [N_total] mask: 25 MB/step at
    # 512 columns — §Perf SNN iteration D)
    all_ids = jnp.concatenate(received)
    mask = jnp.zeros((spec.n_total,), bool).at[all_ids].set(
        True, mode="drop")
    return mask.at[plan.src_gid].get(mode="fill", fill_value=False) \
        & (plan.src_gid >= 0)


def mesh_shard_groups(mesh: Mesh, n_shards: int) -> List[List[int]]:
    """Contiguous per-process shard groups of the `cells` axis.

    `jax.devices()` orders devices process-major, so a process's shards
    are a contiguous block of the axis; the hierarchical exchange needs
    that (and equal block sizes, an `axis_index_groups` requirement), so
    both are verified rather than assumed."""
    devs = list(mesh.devices.reshape(-1))[:n_shards]
    procs = [d.process_index for d in devs]
    groups: List[List[int]] = [[0]]
    for i in range(1, n_shards):
        if procs[i] == procs[i - 1]:
            groups[-1].append(i)
        else:
            groups.append([i])
    if len(groups) != len(set(procs)):
        raise ValueError(
            f"hier exchange needs contiguous per-process device blocks on "
            f"the cells axis; got process layout {procs}")
    if len({len(g) for g in groups}) != 1:
        raise ValueError(
            f"hier exchange needs equal shards per process; got "
            f"{[len(g) for g in groups]}")
    return groups


def hier_offsets(spec: SimSpec, plan: ShardPlan, group_size: int
                 ) -> List[int]:
    """`halo_offsets` at PROCESS-GROUP granularity: the static set of
    group strides the connectivity reaches.  Derived from the same source
    tables (provisioned from the profile's `reach()`), so a narrow kernel
    shrinks the inter-process neighbourhood and a wide one grows it."""
    H = spec.eng.n_shards
    G = H // group_size
    src_gid = np.asarray(plan.src_gid)
    offs = set()
    for h in range(H):
        s = src_gid[h]
        s = s[s >= 0]
        owners = np.unique(topology.owner_of(spec.cfg, s, H,
                                             spec.eng.placement))
        for o in owners.tolist():
            offs.add((h // group_size - o // group_size) % G)
    return sorted(offs)


def _spiked_src_hier(spec, groups, g_offsets, gid_all, plan, spiked):
    """Two-level exchange: intra-group all_gather, inter-group AER.

    Level 1 gathers the group's [L, N] spike block over shared memory
    (axis_index_groups keeps the collective inside one process).  Level 2
    packs ONE AER buffer for the whole group and ppermutes it at whole-
    group stride, so each inter-process message carries a process's full
    spike set and only neighbouring processes ever exchange bytes.
    Delivered mask == the allgather wire's, bit-for-bit."""
    H = spec.eng.n_shards
    L = len(groups[0])
    spk_grp = jax.lax.all_gather(spiked, "cells",
                                 axis_index_groups=groups)       # [L, N]
    g = jax.lax.axis_index("cells") // L
    gid_grp = jax.lax.dynamic_slice_in_dim(gid_all, g * L, L, axis=0)
    ids, _count = aer.pack(spk_grp.reshape(-1), gid_grp.reshape(-1),
                           gid_grp.size)
    received = [ids]                                  # own group (stride 0)
    for d in g_offsets:
        if d == 0:
            continue
        perm = [(i, (i + d * L) % H) for i in range(H)]
        received.append(jax.lax.ppermute(ids, "cells", perm=perm))
    all_ids = jnp.concatenate(received)
    mask = jnp.zeros((spec.n_total,), bool).at[all_ids].set(
        True, mode="drop")
    return mask.at[plan.src_gid].get(mode="fill", fill_value=False) \
        & (plan.src_gid >= 0)


def _resolve_groups(spec: SimSpec, mesh: Optional[Mesh],
                    hier_groups) -> List[List[int]]:
    """Shard groups for the 'hier' exchange: an explicit group count (for
    single-process emulation/tests), an explicit group list, or — the
    production path — the mesh's per-process device blocks."""
    H = spec.eng.n_shards
    if hier_groups is None:
        if mesh is None:
            raise ValueError("exchange='hier' needs a mesh (to derive "
                             "per-process groups) or hier_groups=")
        return mesh_shard_groups(mesh, H)
    if isinstance(hier_groups, int):
        G = hier_groups
        if G <= 0 or H % G:
            raise ValueError(f"hier_groups={G} must divide n_shards={H}")
        L = H // G
        return [list(range(g * L, (g + 1) * L)) for g in range(G)]
    return [list(g) for g in hier_groups]


def _make_exchange(spec: SimSpec, plan: ShardPlan,
                   groups: Optional[Sequence[Sequence[int]]] = None):
    """Per-shard exchange callable (plan_1, spiked_1) -> spiked_src_1.

    Closes over host-side statics only (halo/group offsets / replicated
    gid table), so the returned callable is safe inside `shard_map` bodies
    on process-spanning meshes.  `plan` must be host-addressable."""
    if spec.eng.exchange == "halo":
        offsets = halo_offsets(spec, plan)
        return lambda p1, s1: _spiked_src_halo(spec, offsets, p1, s1)
    gid_all = jnp.asarray(np.asarray(plan.gid))   # replicated [H, N]
    if spec.eng.exchange == "hier":
        if groups is None:
            raise ValueError("exchange='hier': no shard groups resolved")
        g_offsets = hier_offsets(spec, plan, len(groups[0]))
        return lambda p1, s1: _spiked_src_hier(spec, groups, g_offsets,
                                               gid_all, p1, s1)
    return lambda p1, s1: _spiked_src_allgather(spec, gid_all, s1, p1.src_gid)


# ---------------------------------------------------------------------------
# delivery dispatch: both backends share the plan/state/exchange plumbing
# ---------------------------------------------------------------------------


def _is_event(spec: SimSpec) -> bool:
    return spec.eng.delivery == "event"


def _is_streamed(spec: SimSpec) -> bool:
    return spec.stream is not None


def _base_plan(planT):
    """The ShardPlan inside a delivery-dependent plan tree (event mode
    carries (ShardPlan, EventPlan), streamed mode (ShardPlan,
    StreamedPlan); NamedTuples are tuples, so dispatch on the concrete
    type, not tuple-ness)."""
    return planT if isinstance(planT, ShardPlan) else planT[0]


def _plan_tree(spec: SimSpec, plan: ShardPlan, eplan, splan=None):
    if _is_streamed(spec):
        if splan is None:
            raise ValueError("streamed connectivity needs the StreamedPlan: "
                             "pass splan= (from stream_engine.build)")
        return (plan, splan)
    if not _is_event(spec):
        return plan
    if eplan is None:
        raise ValueError("delivery='event' needs the EventPlan: pass "
                         "eplan= (from event_engine.build)")
    return (plan, eplan)


class _Phases(NamedTuple):
    """Per-shard phase callables over the delivery-dependent plan tree.

    `pa` (full phase A) returns (state', spiked, StepTimings); the
    pipelined schedule uses its split halves `pa_dyn` (same return
    contract, LTP pending) + `pa_plast` instead — composing them is the
    definition of `pa`, so both schedules run the same ops."""
    pa: Callable
    pb: Callable
    pa_dyn: Callable
    pa_plast: Callable


def _delivery_phases(spec: SimSpec, stim_k,
                     caps: Optional[dict] = None) -> _Phases:
    """Phase callables with the signature (planT_1, state_1, ...) -> ...,
    dispatched on EngineConfig.delivery (+ streamed connectivity); all
    backends share it."""
    caps = caps or {}
    if _is_streamed(spec):
        def pa(planT, st, t):
            p, sp = planT
            return stream_engine.phase_a(spec, p, sp, st, t, stim_k)

        def pb(planT, st, ss, t):
            p, sp = planT
            return stream_engine.phase_b(spec, p, sp, st, ss, t)

        def pa_dyn(planT, st, t):
            p, sp = planT
            return stream_engine.phase_a_dynamics(spec, p, sp, st, t,
                                                  stim_k)

        def pa_plast(planT, st, spiked, t):
            p, sp = planT
            return stream_engine.phase_a_plasticity(spec, p, sp, st,
                                                    spiked, t)

        return _Phases(pa, pb, pa_dyn, pa_plast)
    if _is_event(spec):
        c_post, c_src = caps.get("c_post"), caps.get("c_src")

        def pa(planT, st, t):
            p, ep = planT
            return event_engine.phase_a(spec, p, ep, st, t, stim_k,
                                        c_post=c_post)

        def pb(planT, st, ss, t):
            p, ep = planT
            return event_engine.phase_b(spec, p, ep, st, ss, t, c_src=c_src)

        def pa_dyn(planT, st, t):
            p, ep = planT
            return event_engine.phase_a_dynamics(spec, p, ep, st, t, stim_k)

        def pa_plast(planT, st, spiked, t):
            p, ep = planT
            return event_engine.phase_a_plasticity(spec, p, ep, st, spiked,
                                                   t, c_post=c_post)

        return _Phases(pa, pb, pa_dyn, pa_plast)

    def pa(planT, st, t):
        return engine.phase_a(spec, planT, st, t, stim_k)

    def pb(planT, st, ss, t):
        return engine.phase_b(spec, planT, st, ss, t)

    def pa_dyn(planT, st, t):
        return engine.phase_a_dynamics(spec, planT, st, t, stim_k)

    def pa_plast(planT, st, spiked, t):
        return engine.phase_a_plasticity(spec, planT, st, spiked, t)

    return _Phases(pa, pb, pa_dyn, pa_plast)


def _specs(spec: SimSpec, planT):
    """(plan, state, per-step-timings) partition specs over `cells`."""
    pspec = P("cells")
    plan_specs = jax.tree.map(lambda _: pspec, planT)
    base = ShardState(*([pspec] * len(ShardState._fields)))
    if _is_event(spec):
        state_specs = event_engine.EventState(
            base=base, ev_ring=pspec, ev_count=pspec, sat=pspec)
    else:
        state_specs = base
    tm_specs = engine.StepTimings(spikes=pspec, arrivals=pspec)
    return pspec, plan_specs, state_specs, tm_specs


def _drop_lead(tree):
    """shard_map passes [1, ...] slices; drop the leading axis."""
    return jax.tree.map(lambda x: x[0], tree)


def _src_false(planT):
    """All-False spiked_src of the right per-shard width — the pipelined
    prologue buffer.  Phase B of an all-False mask is an exact no-op for
    both backends (dense: no hits; event: zero compacted sources, zero
    ranks, zero saturation), so priming the double buffer with it keeps
    step t0 bit-identical to the sync schedule."""
    S = _base_plan(planT).src_gid.shape[0]
    return jnp.zeros((S,), bool)


def make_run_program(spec: SimSpec, plan: ShardPlan, mesh: Mesh,
                     eplan=None, caps: Optional[dict] = None,
                     hier_groups=None, splan=None):
    """Returns run(state, t0, n_steps) -> (state, raster, timings), executing
    one shard per device of the `cells` mesh axis.  (Constructed via
    `core.StepProgram`; this is the machinery behind its `.run` handle.)

    `plan` must be HOST-addressable (the stacked tree `build` returns):
    halo discovery reads it with numpy, and it is then placed on `mesh`
    here and threaded through the jitted program as an *argument* — a
    closure constant cannot span processes, and even single-process it
    re-materializes ~50x slower on CPU (EXPERIMENTS.md §Perf).

    With spec.eng.delivery == 'event', `eplan` (host-addressable, from
    `event_engine.build`) rides along the same way and `state` must be an
    EventState; `caps` optionally overrides the event compaction
    capacities (dict with 'c_post'/'c_src' — tests force tiny ones).

    spec.eng.exchange_schedule selects the loop body: 'sync' is the
    program-order A -> X -> B step; 'pipelined' rotates it to
    B(t-1) -> A_dyn(t) -> X(t) -> A_plast(t) with the exchange result
    double-buffered through the scan carry (all-False prologue, epilogue
    flush after the scan), so X(t) is issued before the LTP pass it
    overlaps.  Identical op sequence per step => bit-identical outputs."""
    stim_k = stimulus.stim_key(spec.cfg)
    groups = (_resolve_groups(spec, mesh, hier_groups)
              if spec.eng.exchange == "hier" else None)
    exchange = _make_exchange(spec, plan, groups)
    planT = _plan_tree(spec, plan, eplan, splan)
    if spec.eng.exchange_schedule not in ("sync", "pipelined"):
        raise ValueError(
            f"unknown exchange_schedule {spec.eng.exchange_schedule!r}")
    ph = _delivery_phases(spec, stim_k, caps)
    pspec, plan_specs, state_specs, tm_specs = _specs(spec, planT)
    plan_d = dist_sharding.shard_put(mesh, planT, "cells")
    pipelined = spec.eng.exchange_schedule == "pipelined"

    def shard_body(plan_s, state_s, ts):
        plan_1 = _drop_lead(plan_s)
        state_1 = _drop_lead(state_s)

        def step_sync(state, t):
            state, spiked, tm = ph.pa(plan_1, state, t)
            spiked_src = exchange(_base_plan(plan_1), spiked)
            state = ph.pb(plan_1, state, spiked_src, t)
            return state, (spiked, tm)

        def step_pipelined(carry, t):
            state, ss_prev = carry
            state = ph.pb(plan_1, state, ss_prev, t - 1)  # deliver step t-1
            state, spiked, tm = ph.pa_dyn(plan_1, state, t)
            ss = exchange(_base_plan(plan_1), spiked)     # issued pre-LTP
            state = ph.pa_plast(plan_1, state, spiked, t)
            return (state, ss), (spiked, tm)

        if pipelined:
            carry0 = (state_1, _src_false(plan_1))
            (state_1, ss_last), (raster, tm) = jax.lax.scan(
                step_pipelined, carry0, ts)
            state_1 = ph.pb(plan_1, state_1, ss_last, ts[-1])  # flush
        else:
            state_1, (raster, tm) = jax.lax.scan(step_sync, state_1, ts)
        out_state = jax.tree.map(lambda x: x[None], state_1)
        return (out_state, raster[:, None],
                jax.tree.map(lambda x: x[:, None], tm))

    # scan outputs carry a leading time axis in front of each per-call spec
    run = jax.jit(dist_compat.shard_map(
        shard_body, mesh,
        in_specs=(plan_specs, state_specs, P()),
        out_specs=(state_specs, P(None, *pspec),
                   jax.tree.map(lambda s: P(None, *s), tm_specs))))

    def runner(state, t0: int, n_steps: int):
        ts = dist_sharding.replicated_put(
            mesh, jnp.arange(t0, t0 + n_steps, dtype=jnp.int32))
        state, raster, tm = run(plan_d, state, ts)
        return state, raster, tm

    return runner


class PhasePrograms(NamedTuple):
    """Separately-jitted shard_map'd phase handles over one mesh.

    `phase_a(state, t)` / `exchange(spiked)` / `phase_b(state, ss, t)` is
    the paper's Table 2 split; `phase_a_dynamics(state, t)` and
    `phase_a_plasticity(state, spiked, t)` are phase A's halves, timed
    separately under the pipelined schedule (the exchange is dispatched
    between them).  All five thread the placed plan as a jit argument."""
    phase_a: Callable
    exchange: Callable
    phase_b: Callable
    phase_a_dynamics: Callable
    phase_a_plasticity: Callable


def make_phase_programs(spec: SimSpec, plan: ShardPlan, mesh: Mesh,
                        eplan=None, caps: Optional[dict] = None,
                        hier_groups=None, splan=None) -> PhasePrograms:
    """Separately-jitted shard_map'd phases over `mesh` — the machinery
    behind `StepProgram.phase_fns` / `.time_phases`, used by
    `repro.cluster` and the bench suites to attribute wall-clock to
    phase A / spike exchange / phase B per process (paper Table 2,
    across the process axis).  The placed plan is bound into each
    returned fn as a jit argument; `plan` must be host-addressable and
    `eplan`/`caps` follow the `make_run_program` contract, so per-phase
    walls are comparable across backends and schedules."""
    stim_k = stimulus.stim_key(spec.cfg)
    groups = (_resolve_groups(spec, mesh, hier_groups)
              if spec.eng.exchange == "hier" else None)
    exchange = _make_exchange(spec, plan, groups)
    planT = _plan_tree(spec, plan, eplan, splan)
    ph = _delivery_phases(spec, stim_k, caps)
    pspec, plan_specs, state_specs, tm_specs = _specs(spec, planT)
    plan_d = dist_sharding.shard_put(mesh, planT, "cells")

    def a_body(plan_s, state_s, t):
        state_1, spiked, tm = ph.pa(_drop_lead(plan_s),
                                    _drop_lead(state_s), t)
        return (jax.tree.map(lambda x: x[None], state_1), spiked[None],
                jax.tree.map(lambda x: x[None], tm))

    def adyn_body(plan_s, state_s, t):
        state_1, spiked, tm = ph.pa_dyn(_drop_lead(plan_s),
                                        _drop_lead(state_s), t)
        return (jax.tree.map(lambda x: x[None], state_1), spiked[None],
                jax.tree.map(lambda x: x[None], tm))

    def aplast_body(plan_s, state_s, spiked_s, t):
        state_1 = ph.pa_plast(_drop_lead(plan_s), _drop_lead(state_s),
                              spiked_s[0], t)
        return jax.tree.map(lambda x: x[None], state_1)

    def ex_body(plan_s, spiked_s):
        return exchange(_base_plan(_drop_lead(plan_s)), spiked_s[0])[None]

    def b_body(plan_s, state_s, spiked_src_s, t):
        state_1 = ph.pb(_drop_lead(plan_s), _drop_lead(state_s),
                        spiked_src_s[0], t)
        return jax.tree.map(lambda x: x[None], state_1)

    sm = dist_compat.shard_map
    a_j = jax.jit(sm(a_body, mesh, in_specs=(plan_specs, state_specs, P()),
                     out_specs=(state_specs, pspec, tm_specs)))
    adyn_j = jax.jit(sm(adyn_body, mesh,
                        in_specs=(plan_specs, state_specs, P()),
                        out_specs=(state_specs, pspec, tm_specs)))
    aplast_j = jax.jit(sm(aplast_body, mesh,
                          in_specs=(plan_specs, state_specs, pspec, P()),
                          out_specs=state_specs))
    ex_j = jax.jit(sm(ex_body, mesh, in_specs=(plan_specs, pspec),
                      out_specs=pspec))
    b_j = jax.jit(sm(b_body, mesh,
                     in_specs=(plan_specs, state_specs, pspec, P()),
                     out_specs=state_specs))

    def tput(x):
        return dist_sharding.replicated_put(mesh, jnp.int32(x))

    return PhasePrograms(
        phase_a=lambda state, t: a_j(plan_d, state, tput(t)),
        exchange=lambda spiked: ex_j(plan_d, spiked),
        phase_b=lambda state, ss, t: b_j(plan_d, state, ss, tput(t)),
        phase_a_dynamics=lambda state, t: adyn_j(plan_d, state, tput(t)),
        phase_a_plasticity=lambda state, spiked, t: aplast_j(
            plan_d, state, spiked, tput(t)))


# ---------------------------------------------------------------------------
# deprecated entry points (PR 6 API redesign): use core.StepProgram
# ---------------------------------------------------------------------------


def _warn_deprecated(old: str) -> None:
    warnings.warn(
        f"core.distributed.{old} is deprecated; construct a "
        f"core.StepProgram instead (its .run / .phase_fns handles cover "
        f"this, plus the pipelined schedule and hier exchange)",
        DeprecationWarning, stacklevel=3)


def make_sharded_run(spec: SimSpec, plan: ShardPlan, mesh: Mesh,
                     eplan=None, caps: Optional[dict] = None):
    """Deprecated alias of the `StepProgram.run` machinery."""
    _warn_deprecated("make_sharded_run")
    return make_run_program(spec, plan, mesh, eplan=eplan, caps=caps)


def make_phase_fns(spec: SimSpec, plan: ShardPlan, mesh: Mesh,
                   eplan=None, caps: Optional[dict] = None):
    """Deprecated: returns the legacy (phase_a, exchange, phase_b) triple
    of what is now `StepProgram.phase_fns`."""
    _warn_deprecated("make_phase_fns")
    pp = make_phase_programs(spec, plan, mesh, eplan=eplan, caps=caps)
    return pp.phase_a, pp.exchange, pp.phase_b


def shard_put(mesh: Mesh, tree):
    """Place a stacked [H, ...] tree with each shard on its device."""
    return dist_sharding.shard_put(mesh, tree, "cells")
