"""Distributed DPSNN runtime: the same phase-A/B step as `engine`, but with
real collectives under `shard_map` (via `repro.dist.compat`) over a
`cells` mesh axis.

Spike exchange modes (EngineConfig.exchange):

  'allgather' — every shard gathers all shards' spike masks and builds the
      global mask.  Simple, bandwidth ~ N_total bits/step; the right choice
      for small meshes and for `scatter` placement (whose halo is global).

  'halo' — the paper's two-phase sparse delivery, TPU-adapted: each shard
      packs a fixed-capacity AER buffer (ids + count lane, see core.aer) and
      `lax.ppermute`s it along the *static* set of shard offsets that the
      connectivity actually uses (discovered at build time, exactly like the
      paper's first construction step discovers the process subset).
      Received ids are matched against the local source table; the count
      lane is a compute-gating hint (processing cost scales with real
      spikes), while wire bytes are static — the SPMD trade documented in
      DESIGN.md §2.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import aer, engine, stimulus, topology
from .engine import ShardPlan, ShardState, SimSpec
from ..dist import compat as dist_compat
from ..dist import mesh as dist_mesh
from ..dist import sharding as dist_sharding


def halo_offsets(spec: SimSpec, plan: ShardPlan) -> List[int]:
    """Static shard-to-shard offsets used by the connectivity.

    == the paper's construction-phase discovery of "the subset of processes
    that should be listened to", derived locally from the source tables.
    """
    H = spec.eng.n_shards
    src_gid = np.asarray(plan.src_gid)            # [H, S]
    offs = set()
    for h in range(H):
        s = src_gid[h]
        s = s[s >= 0]
        owners = np.unique(topology.owner_of(spec.cfg, s, H,
                                             spec.eng.placement))
        for o in owners.tolist():
            offs.add((h - o) % H)                 # sender o -> receiver h
    return sorted(offs)


def make_mesh(n_shards: int) -> Mesh:
    return dist_mesh.make_snn_mesh(n_shards)


def _spiked_src_allgather(spec, plan_gid_all, spiked, src_gid):
    spk_all = jax.lax.all_gather(spiked, "cells")            # [H, N]
    glob = jnp.zeros((spec.n_total,), bool).at[
        plan_gid_all.reshape(-1)].max(spk_all.reshape(-1), mode="drop")
    return glob.at[src_gid].get(mode="fill", fill_value=False) & (src_gid >= 0)


def _spiked_src_halo(spec, offsets, plan, spiked):
    """Sparse AER wire + dense local match.

    Wire: fixed-capacity AER buffers ppermute over the static halo offsets
    (the paper's two-phase delivery).  Match: received ids are scattered
    into a local [N_total] mask, then ONE gather by the source table — a
    per-offset searchsorted match measured 60x more HBM traffic
    (EXPERIMENTS.md §Perf, SNN iteration C)."""
    H = spec.eng.n_shards
    ids, _count = aer.pack(spiked, plan.gid, plan.gid.shape[0])
    received = []
    for d in offsets:
        if d == 0:
            received.append(ids)
        else:
            perm = [(i, (i + d) % H) for i in range(H)]
            received.append(jax.lax.ppermute(ids, "cells", perm=perm))
    # single scatter: one functional mask update instead of |offsets|
    # sequential ones (each re-copied the [N_total] mask: 25 MB/step at
    # 512 columns — §Perf SNN iteration D)
    all_ids = jnp.concatenate(received)
    mask = jnp.zeros((spec.n_total,), bool).at[all_ids].set(
        True, mode="drop")
    return mask.at[plan.src_gid].get(mode="fill", fill_value=False) \
        & (plan.src_gid >= 0)


def make_sharded_run(spec: SimSpec, plan: ShardPlan, mesh: Mesh):
    """Returns run(state, t0, n_steps) -> (state, raster, timings), executing
    one shard per device of the `cells` mesh axis."""
    stim_k = stimulus.stim_key(spec.cfg)
    offsets = halo_offsets(spec, plan) if spec.eng.exchange == "halo" else None
    gid_all = jnp.asarray(plan.gid)               # replicated [H, N]

    def shard_body(plan_s, state_s, ts):
        # shard_map passes [1, ...] slices; drop the leading axis.
        plan_1 = jax.tree.map(lambda x: x[0], plan_s)
        state_1 = jax.tree.map(lambda x: x[0], state_s)

        def step(state, t):
            state, spiked, tm = engine.phase_a(spec, plan_1, state, t, stim_k)
            if spec.eng.exchange == "halo":
                spiked_src = _spiked_src_halo(spec, offsets, plan_1, spiked)
            else:
                spiked_src = _spiked_src_allgather(spec, gid_all, spiked,
                                                   plan_1.src_gid)
            state = engine.phase_b(spec, plan_1, state, spiked_src, t)
            return state, (spiked, tm)

        state_1, (raster, tm) = jax.lax.scan(step, state_1, ts)
        out_state = jax.tree.map(lambda x: x[None], state_1)
        return (out_state, raster[:, None],
                jax.tree.map(lambda x: x[:, None], tm))

    pspec = P("cells")
    plan_specs = jax.tree.map(lambda _: pspec, plan)
    state_specs = ShardState(*([pspec] * len(ShardState._fields)))
    tm_specs = engine.StepTimings(spikes=P(None, "cells"),
                                  arrivals=P(None, "cells"))

    smapped = dist_compat.shard_map(
        shard_body, mesh,
        in_specs=(plan_specs, state_specs, P()),
        out_specs=(state_specs, P(None, "cells"), tm_specs))

    @jax.jit
    def run(state, ts):
        return smapped(plan, state, ts)

    def runner(state, t0: int, n_steps: int):
        ts = jnp.arange(t0, t0 + n_steps, dtype=jnp.int32)
        state, raster, tm = run(state, ts)
        return state, raster, tm

    return runner


def shard_put(mesh: Mesh, tree):
    """Place a stacked [H, ...] tree with each shard on its device."""
    return dist_sharding.shard_put(mesh, tree, "cells")
