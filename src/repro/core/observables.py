"""Production of observables (paper §Methods).

The DPSNN-STDP code "can produce files tracing several observables (list of
individual spiking times and spiking neuron identity, mean spiking rates,
membrane potentials, synaptic values)".  Here: raster <-> (t, gid) event
lists, per-window rates, and text/CSV dumps used by the examples and the
streaming tenants of `repro.simserve` (chunk-at-a-time event extraction +
append-mode CSV flushes).
"""
from __future__ import annotations

import os
from typing import Tuple

import numpy as np


def raster_events(raster: np.ndarray, gid: np.ndarray, t0: int = 0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """raster [T, H, N] bool + gid [H, N] -> sorted (times, gids) events.

    `t0` offsets the time axis: a chunk of a longer run streamed from step
    t0 produces the same absolute event times the full-run extraction
    would."""
    t, h, n = np.nonzero(np.asarray(raster))
    g = np.asarray(gid)[h, n]
    order = np.lexsort((g, t))
    return t[order] + t0, g[order]


def events_signature(times: np.ndarray, gids: np.ndarray) -> bytes:
    """Digest of an already-extracted (times, gids) event list.

    `raster_signature` delegates here, so a signature accumulated from
    streamed chunks (concatenate each chunk's `raster_events` output in
    chunk order — time is non-decreasing across chunks, so the
    concatenation IS the canonical order) is bit-equal to the full-run
    signature by construction."""
    import hashlib
    return hashlib.sha256(
        np.stack([np.asarray(times).astype(np.int64),
                  np.asarray(gids).astype(np.int64)]).tobytes()).digest()


def raster_signature(raster: np.ndarray, gid: np.ndarray) -> bytes:
    """Order-canonical digest of the full spike list; equal signatures mean
    the paper's 'identical spiking neurons and timings' check passes."""
    return events_signature(*raster_events(raster, gid))


def mean_rate_hz(raster: np.ndarray, n_neurons: int, dt_ms: float = 1.0
                 ) -> float:
    """Mean firing rate over the run, in Hz."""
    r = np.asarray(raster)
    t_seconds = r.shape[0] * dt_ms / 1000.0
    return float(r.sum() / (n_neurons * t_seconds))


def rate_per_window(raster: np.ndarray, n_neurons: int, window: int = 100,
                    dt_ms: float = 1.0) -> np.ndarray:
    r = np.asarray(raster).reshape(raster.shape[0], -1).sum(axis=1)
    T = (r.shape[0] // window) * window
    per = r[:T].reshape(-1, window).sum(axis=1)
    return per / (n_neurons * window * dt_ms / 1000.0)


def dump_events_csv(path: str, raster: np.ndarray, gid: np.ndarray,
                    append: bool = False, t0: int = 0) -> None:
    """Write (or, with append=True, extend) a spike-event CSV.

    Streaming tenants flush one raster chunk per round: pass the chunk's
    absolute start step as `t0` and append=True; the resulting file is
    byte-identical to a single full-run dump."""
    t, g = raster_events(raster, gid, t0=t0)
    mode = "a" if append else "w"
    header = not append or not os.path.exists(path) \
        or os.path.getsize(path) == 0
    with open(path, mode) as f:
        if header:
            f.write("time_ms,neuron_gid\n")
        for ti, gi in zip(t.tolist(), g.tolist()):
            f.write(f"{ti},{gi}\n")
