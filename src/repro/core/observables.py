"""Production of observables (paper §Methods).

The DPSNN-STDP code "can produce files tracing several observables (list of
individual spiking times and spiking neuron identity, mean spiking rates,
membrane potentials, synaptic values)".  Here: raster <-> (t, gid) event
lists, per-window rates, and text/CSV dumps used by the examples.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def raster_events(raster: np.ndarray, gid: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """raster [T, H, N] bool + gid [H, N] -> sorted (times, gids) events."""
    t, h, n = np.nonzero(np.asarray(raster))
    g = np.asarray(gid)[h, n]
    order = np.lexsort((g, t))
    return t[order], g[order]


def raster_signature(raster: np.ndarray, gid: np.ndarray) -> bytes:
    """Order-canonical digest of the full spike list; equal signatures mean
    the paper's 'identical spiking neurons and timings' check passes."""
    import hashlib
    t, g = raster_events(raster, gid)
    return hashlib.sha256(
        np.stack([t.astype(np.int64), g.astype(np.int64)]).tobytes()).digest()


def mean_rate_hz(raster: np.ndarray, n_neurons: int, dt_ms: float = 1.0
                 ) -> float:
    """Mean firing rate over the run, in Hz."""
    r = np.asarray(raster)
    t_seconds = r.shape[0] * dt_ms / 1000.0
    return float(r.sum() / (n_neurons * t_seconds))


def rate_per_window(raster: np.ndarray, n_neurons: int, window: int = 100,
                    dt_ms: float = 1.0) -> np.ndarray:
    r = np.asarray(raster).reshape(raster.shape[0], -1).sum(axis=1)
    T = (r.shape[0] // window) * window
    per = r[:T].reshape(-1, window).sum(axis=1)
    return per / (n_neurons * window * dt_ms / 1000.0)


def dump_events_csv(path: str, raster: np.ndarray, gid: np.ndarray) -> None:
    t, g = raster_events(raster, gid)
    with open(path, "w") as f:
        f.write("time_ms,neuron_gid\n")
        for ti, gi in zip(t.tolist(), g.tolist()):
            f.write(f"{ti},{gi}\n")
