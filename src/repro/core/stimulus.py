"""Reproducible distributed "thalamic" external stimulus.

Paper: "generate patterns of external thalamic stimulus to the network,
e.g. prescribing the number of events per ms per neural column", identically
for any distribution of the network over processes.

Each event k of column c at step t targets neuron
    n = uniform_hash(seed, c, t, k) mod neurons_per_column
and injects `stim_amplitude` mV into that neuron's summed current.  The hash
is jax.random.fold_in (threefry counter mode), so any shard that owns any
part of column c derives the same events with no communication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import GridConfig


def stim_key(cfg: GridConfig) -> jax.Array:
    return jax.random.key(cfg.seed ^ 0x57D11)


def column_events(cfg: GridConfig, key: jax.Array, columns: jnp.ndarray,
                  t: jnp.ndarray) -> jnp.ndarray:
    """Target gids of this step's events for `columns` ([C] int32, pad -1).

    Returns [C, K] int64-compatible int32 gids (garbage rows where col < 0;
    caller masks by ownership, and col -1 yields negative gids, never owned).
    """
    kt = jax.random.fold_in(key, t)

    def one(col):
        k = jax.random.fold_in(kt, col)
        n = jax.random.randint(k, (cfg.stim_events_per_ms_per_column,), 0,
                               cfg.neurons_per_column, dtype=jnp.int32)
        return col * cfg.neurons_per_column + n

    return jax.vmap(one)(columns)


def stim_current(cfg: GridConfig, key: jax.Array, columns: jnp.ndarray,
                 t: jnp.ndarray, gid_to_local, n_local: int) -> jnp.ndarray:
    """[n_local] fp32 external current for this shard at step t.

    `gid_to_local(gids) -> (local_idx, owned_mask)` is the shard's ownership
    map (placement-specific, from the engine plan).
    """
    gids = column_events(cfg, key, columns, t).reshape(-1)
    owned_col = jnp.repeat(columns >= 0, cfg.stim_events_per_ms_per_column)
    local_idx, owned = gid_to_local(gids)
    amp = jnp.where(owned & owned_col, jnp.float32(cfg.stim_amplitude), 0.0)
    return jnp.zeros((n_local,), jnp.float32).at[local_idx].add(amp,
                                                                mode="drop")
