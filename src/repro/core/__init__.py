"""DPSNN-STDP core: distributed simulation of polychronous and plastic
spiking neural networks (Paolucci et al., 2013), adapted to JAX/TPU."""

from .params import (EngineConfig, GridConfig, IzhikevichParams, StdpParams,
                     DEFAULT_IZH, DEFAULT_STDP)
from .engine import (ShardPlan, ShardState, SimSpec, build, init_state,
                     make_step_fn, run)
from . import (aer, checkpoint, connectivity, distributed, observables,
               profiles, stimulus, topology)

__all__ = [
    "EngineConfig", "GridConfig", "IzhikevichParams", "StdpParams",
    "DEFAULT_IZH", "DEFAULT_STDP", "ShardPlan", "ShardState", "SimSpec",
    "build", "init_state", "make_step_fn", "run", "aer", "checkpoint",
    "connectivity", "distributed", "observables", "profiles", "stimulus",
    "topology",
]
