"""DPSNN-STDP core: distributed simulation of polychronous and plastic
spiking neural networks (Paolucci et al., 2013), adapted to JAX/TPU."""

from .params import (EngineConfig, GridConfig, IzhikevichParams, StdpParams,
                     DEFAULT_IZH, DEFAULT_STDP)
from .engine import (ShardPlan, ShardState, SimSpec, build, init_state,
                     make_step_fn, run)
from . import (aer, checkpoint, connectivity, distributed, observables,
               profiles, stimulus, topology)


def build_delivery(cfg, eng, izh=None, stdp=None):
    """Backend-generic build, dispatching on `eng.delivery`.

    Returns (spec, plan, eplan, state, cap_ev): for the dense backend
    eplan/cap_ev are None and state is a ShardState; for the event
    backend they are the EventPlan and ring capacity, state an
    EventState.  `cap_ev` is exactly what `checkpoint.load` needs, so
    callers stay delivery-agnostic end to end (launch/snn, cluster
    worker/cli all build through here)."""
    from .params import DEFAULT_IZH, DEFAULT_STDP
    izh, stdp = izh or DEFAULT_IZH, stdp or DEFAULT_STDP
    if eng.delivery == "event":
        from . import event_engine
        spec, plan, eplan, state = event_engine.build(cfg, eng, izh, stdp)
        return spec, plan, eplan, state, state.ev_ring.shape[-1]
    spec, plan, state = build(cfg, eng, izh, stdp)
    return spec, plan, None, state, None


def run_delivery(spec, plan, eplan, state, t0, n_steps):
    """Backend-generic single-device driver: (state, raster, timings) via
    `engine.run` or `event_engine.run` depending on `eplan`."""
    if eplan is not None:
        from . import event_engine
        return event_engine.run(spec, plan, eplan, state, t0, n_steps)
    return run(spec, plan, state, t0, n_steps)


__all__ = [
    "EngineConfig", "GridConfig", "IzhikevichParams", "StdpParams",
    "DEFAULT_IZH", "DEFAULT_STDP", "ShardPlan", "ShardState", "SimSpec",
    "build", "build_delivery", "init_state", "make_step_fn", "run",
    "run_delivery", "aer", "checkpoint", "connectivity", "distributed",
    "observables", "profiles", "stimulus", "topology",
]
