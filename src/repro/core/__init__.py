"""DPSNN-STDP core: distributed simulation of polychronous and plastic
spiking neural networks (Paolucci et al., 2013), adapted to JAX/TPU."""

from .params import (EngineConfig, GridConfig, IzhikevichParams, StdpParams,
                     DEFAULT_IZH, DEFAULT_STDP)
from .engine import (ShardPlan, ShardState, SimSpec, build, init_state,
                     make_step_fn, run)
from . import (aer, checkpoint, connectivity, distributed, observables,
               profiles, stimulus, topology)
from .step_program import StepProgram


def _warn_deprecated(old: str) -> None:
    import warnings
    warnings.warn(
        f"core.{old} is deprecated; construct a core.StepProgram — its "
        f"spec/plan/eplan/init_state()/cap_ev and .run handle replace the "
        f"build_delivery/run_delivery pair for both backends",
        DeprecationWarning, stacklevel=3)


def build_delivery(cfg, eng, izh=None, stdp=None):
    """Deprecated: use `core.StepProgram(cfg, eng)`.

    Returns the legacy (spec, plan, eplan, state, cap_ev) tuple by
    delegating to StepProgram (dense: eplan/cap_ev are None and state a
    ShardState; event: the EventPlan, ring capacity, an EventState)."""
    _warn_deprecated("build_delivery")
    sp = StepProgram(cfg, eng, izh=izh, stdp=stdp)
    return sp.spec, sp.plan, sp.eplan, sp.init_state(), sp.cap_ev


def run_delivery(spec, plan, eplan, state, t0, n_steps):
    """Deprecated: use `core.StepProgram(...).run` (or
    `StepProgram.from_parts(spec, plan, eplan).run`).  Backend-generic
    single-device driver: (state, raster, timings)."""
    _warn_deprecated("run_delivery")
    return StepProgram.from_parts(spec, plan, eplan).run(state, t0,
                                                         n_steps)


__all__ = [
    "EngineConfig", "GridConfig", "IzhikevichParams", "StdpParams",
    "DEFAULT_IZH", "DEFAULT_STDP", "ShardPlan", "ShardState", "SimSpec",
    "StepProgram", "build", "build_delivery", "init_state", "make_step_fn",
    "run", "run_delivery", "aer", "checkpoint", "connectivity",
    "distributed", "observables", "profiles", "stimulus", "topology",
]
