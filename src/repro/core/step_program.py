"""`StepProgram`: the one constructor for the DPSNN step.

Every execution surface of the simulator used to be reached through a
quartet of near-duplicate entry points (`core.build_delivery` +
`core.run_delivery` + `distributed.make_sharded_run` +
`distributed.make_phase_fns`), each re-implementing the
delivery/exchange/placement dispatch.  `StepProgram` replaces them with a
single object:

    sp = StepProgram(cfg, eng)                  # single-device reference
    sp = StepProgram(cfg, eng, mesh=mesh)       # shard_map, real collectives
    state = sp.place(sp.init_state())
    state, raster, tm = sp.run(state, 0, 500)   # fused scan
    pa, ex, pb = sp.phase_fns()[:3]             # Table 2 phase split
    state, times, rasters, counts = sp.time_phases(state, 0, 100)

One dispatch point means every caller — the snn launcher, the cluster
worker, the profiler, the bench suites — constructs and times the SAME
compiled programs, and new execution knobs (`exchange_schedule`,
`exchange='hier'`) appear everywhere at once.

Two execution modes share the phase callables (`distributed` dispatches
them on EngineConfig.delivery):

  mesh=None — logical shards via `vmap` on one device; the exchange is
      emulated (allgather/hier: global spike mask; halo: `jnp.roll` of
      packed AER buffers over the shard axis), preserving each wire's
      compute graph so per-phase profiles are meaningful without a
      multi-device platform.  `run` here is the reference scan that
      defines the physics — schedules are execution layouts, so it is
      schedule-independent by construction.
  mesh=Mesh — one shard per device of the `cells` axis via `shard_map`;
      collectives, schedules and the hier exchange are all real.

Plans are threaded through every jitted program as ARGUMENTS, never
closures (a closure constant cannot span processes, and even
single-process it re-materializes ~50x slower on CPU — EXPERIMENTS.md
§Perf); `planT` and `fused` are exposed for HLO cost analysis under the
same rule.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import (aer, connectivity, distributed, engine, event_engine,
               stimulus, stream_engine)
from .engine import ShardPlan, SimSpec
from .params import (DEFAULT_IZH, DEFAULT_STDP, EngineConfig, GridConfig,
                     IzhikevichParams, StdpParams)
from ..dist import sharding as dist_sharding


class StepProgram:
    """Run/phase/timing handles for one (GridConfig, EngineConfig, mesh).

    Construct from configs (builds connectivity + initial state) or wrap
    prebuilt parts with `from_parts` (bench suites sweeping knobs over one
    expensive build).  All handles are built lazily and cached, so
    constructing a StepProgram compiles nothing by itself."""

    def __init__(self, cfg: GridConfig, eng: EngineConfig, *,
                 mesh: Optional[Mesh] = None,
                 izh: Optional[IzhikevichParams] = None,
                 stdp: Optional[StdpParams] = None,
                 caps: Optional[dict] = None,
                 hier_groups=None):
        izh, stdp = izh or DEFAULT_IZH, stdp or DEFAULT_STDP
        mode, _ = connectivity.parse_mode(eng.connectivity)
        splan = None
        if mode == "streamed":
            spec, plan, splan, state = stream_engine.build(cfg, eng, izh,
                                                           stdp)
            eplan = None
        elif eng.delivery == "event":
            spec, plan, eplan, state = event_engine.build(cfg, eng, izh,
                                                          stdp)
        else:
            spec, plan, state = engine.build(cfg, eng, izh, stdp)
            eplan = None
        self._init(spec, plan, eplan, state, mesh, caps, hier_groups,
                   splan=splan)

    @classmethod
    def from_parts(cls, spec: SimSpec, plan: ShardPlan, eplan=None, *,
                   state0=None, mesh: Optional[Mesh] = None,
                   caps: Optional[dict] = None, hier_groups=None,
                   splan=None) -> "StepProgram":
        """Wrap an already-built (spec, plan[, eplan][, splan][, state])
        without re-running connectivity construction."""
        sp = cls.__new__(cls)
        sp._init(spec, plan, eplan, state0, mesh, caps, hier_groups,
                 splan=splan)
        return sp

    def _init(self, spec, plan, eplan, state0, mesh, caps, hier_groups,
              splan=None):
        if spec.eng.delivery == "event" and eplan is None:
            raise ValueError("delivery='event' needs an EventPlan")
        if spec.stream is not None and splan is None:
            raise ValueError("streamed connectivity needs a StreamedPlan")
        self.spec: SimSpec = spec
        self.plan: ShardPlan = plan
        self.eplan = eplan
        self.splan = splan
        self.mesh = mesh
        self.caps = caps or {}
        self.hier_groups = hier_groups
        self._state0 = state0
        self._run = None
        self._phases = None
        self._fused = None
        self._stim_k = stimulus.stim_key(spec.cfg)

    # -- construction-time data ------------------------------------------

    @property
    def cap_ev(self) -> Optional[int]:
        """Event-ring capacity (what `checkpoint.load` needs); None for
        the dense backend."""
        if self._state0 is not None and self.eplan is not None:
            return int(self._state0.ev_ring.shape[-1])
        return None

    @property
    def planT(self):
        """The delivery-dependent plan tree every jitted program takes as
        its first argument (dense: ShardPlan; event: (ShardPlan,
        EventPlan); streamed: (ShardPlan, StreamedPlan))."""
        return distributed._plan_tree(self.spec, self.plan, self.eplan,
                                      self.splan)

    def init_state(self):
        """The freshly-built initial state (host-side, unplaced)."""
        if self._state0 is None:
            raise ValueError(
                "no initial state: this StepProgram wraps prebuilt parts "
                "(from_parts without state0) — pass state0= or construct "
                "from configs")
        return self._state0

    def place(self, state):
        """Shard `state` over the mesh (identity when mesh=None)."""
        if self.mesh is None:
            return state
        return dist_sharding.shard_put(self.mesh, state, "cells")

    def load(self, path: str):
        """Restore (state, t0) from a checkpoint into this layout."""
        from . import checkpoint
        return checkpoint.load(path, self.spec, self.plan,
                               cap_ev=self.cap_ev)

    def weight_signature(self, state) -> bytes:
        """sha256 over the valid synapse weights in canonical per-shard
        order — the plastic-state counterpart of the raster signature
        (comparable across connectivity residency modes: both lay valid
        weights out in (tgt_gid, src_gid, j) order per shard).  `state`
        must be host-addressable (gather first on a multi-process mesh).
        """
        import hashlib
        w = np.asarray(state.base.w if hasattr(state, "base") else state.w)
        h = hashlib.sha256()
        if self.splan is not None:
            e_start = np.asarray(self.splan.e_start)   # [H, n_chunks + 1]
            for hh in range(w.shape[0]):
                h.update(w[hh, :int(e_start[hh, -1])].tobytes())
        else:
            valid = np.asarray(self.plan.syn_valid)
            for hh in range(w.shape[0]):
                h.update(w[hh][valid[hh]].tobytes())
        return h.digest()

    # -- run handle ------------------------------------------------------

    def run(self, state, t0: int, n_steps: int):
        """Fused scan: (state, raster[T, H, N], timings).

        mesh=None runs the single-device reference driver (vmap shards,
        global-mask exchange — the physics definition both schedules must
        reproduce); with a mesh it is the shard_map program honouring
        exchange/schedule."""
        if self.mesh is None:
            if self.splan is not None:
                return stream_engine.run(self.spec, self.plan, self.splan,
                                         state, t0, n_steps)
            if self.eplan is not None:
                return event_engine.run(
                    self.spec, self.plan, self.eplan, state, t0, n_steps,
                    c_post=self.caps.get("c_post"),
                    c_src=self.caps.get("c_src"))
            return engine.run(self.spec, self.plan, state, t0, n_steps)
        if self._run is None:
            self._run = distributed.make_run_program(
                self.spec, self.plan, self.mesh, eplan=self.eplan,
                caps=self.caps, hier_groups=self.hier_groups,
                splan=self.splan)
        return self._run(state, t0, n_steps)

    # -- phase handles (paper Table 2 split) -----------------------------

    def phase_fns(self) -> distributed.PhasePrograms:
        """Separately-jitted phase handles with unified signatures:

            phase_a(state, t) -> (state, spiked, tm)
            exchange(spiked) -> spiked_src
            phase_b(state, spiked_src, t) -> state
            phase_a_dynamics(state, t) / phase_a_plasticity(state, spiked, t)

        — identical shapes in both execution modes, so profiling code is
        mesh-agnostic."""
        if self._phases is None:
            if self.mesh is None:
                self._phases = self._vmap_phase_programs()
            else:
                self._phases = distributed.make_phase_programs(
                    self.spec, self.plan, self.mesh, eplan=self.eplan,
                    caps=self.caps, hier_groups=self.hier_groups,
                    splan=self.splan)
        return self._phases

    def _vmap_exchange(self):
        """Single-device emulation of the exchange wire over stacked
        [H, ...] arrays, preserving each mode's compute graph."""
        spec, plan = self.spec, self.plan

        def ex_allgather(planT, spiked):
            p = distributed._base_plan(planT)
            glob = engine._global_spike_mask(spec, p, spiked)
            return jax.vmap(
                lambda p1: glob.at[p1.src_gid].get(
                    mode="fill", fill_value=False) & (p1.src_gid >= 0))(p)

        if spec.eng.exchange == "halo":
            offsets = distributed.halo_offsets(spec, plan)

            def ex_halo(planT, spiked):
                p = distributed._base_plan(planT)
                ids_all, _ = jax.vmap(
                    lambda p1, s: aer.pack(s, p1.gid, p1.gid.shape[0])
                )(p, spiked)
                # receiver h hears sender (h - d) % H: the single-device
                # analogue of distributed._spiked_src_halo's ppermute
                received = [jnp.roll(ids_all, d, axis=0) for d in offsets]
                all_ids = jnp.concatenate(received, axis=1)

                def match(p1, ids_row):
                    mask = jnp.zeros((spec.n_total,), bool).at[
                        ids_row].set(True, mode="drop")
                    return mask.at[p1.src_gid].get(
                        mode="fill", fill_value=False) & (p1.src_gid >= 0)

                return jax.vmap(match)(p, all_ids)

            return ex_halo

        if spec.eng.exchange == "hier":
            groups = distributed._resolve_groups(spec, None,
                                                 self.hier_groups)
            L = len(groups[0])
            G = len(groups)
            g_offsets = distributed.hier_offsets(spec, plan, L)

            def ex_hier(planT, spiked):
                p = distributed._base_plan(planT)
                N = spiked.shape[-1]
                # level 1: group-local gather == reshape on one device
                gid_g = p.gid.reshape(G, L * N)
                spk_g = spiked.reshape(G, L * N)
                ids, _ = jax.vmap(
                    lambda s, g: aer.pack(s, g, g.shape[0]))(spk_g, gid_g)
                # level 2: whole-group roll at the static group strides
                received = [jnp.roll(ids, d, axis=0) for d in g_offsets]
                all_ids = jnp.repeat(jnp.concatenate(received, axis=1),
                                     L, axis=0)           # [H, ...]

                def match(p1, ids_row):
                    mask = jnp.zeros((spec.n_total,), bool).at[
                        ids_row].set(True, mode="drop")
                    return mask.at[p1.src_gid].get(
                        mode="fill", fill_value=False) & (p1.src_gid >= 0)

                return jax.vmap(match)(p, all_ids)

            return ex_hier

        return ex_allgather

    def _vmap_phase_programs(self) -> distributed.PhasePrograms:
        spec = self.spec
        ph = distributed._delivery_phases(spec, self._stim_k, self.caps)
        exchange = self._vmap_exchange()
        planT = self.planT

        a_j = jax.jit(lambda pT, s, t: jax.vmap(
            ph.pa, in_axes=(0, 0, None))(pT, s, t))
        adyn_j = jax.jit(lambda pT, s, t: jax.vmap(
            ph.pa_dyn, in_axes=(0, 0, None))(pT, s, t))
        aplast_j = jax.jit(lambda pT, s, spk, t: jax.vmap(
            ph.pa_plast, in_axes=(0, 0, 0, None))(pT, s, spk, t))
        ex_j = jax.jit(exchange)
        b_j = jax.jit(lambda pT, s, ss, t: jax.vmap(
            ph.pb, in_axes=(0, 0, 0, None))(pT, s, ss, t))

        ti = jnp.int32
        return distributed.PhasePrograms(
            phase_a=lambda state, t: a_j(planT, state, ti(t)),
            exchange=lambda spiked: ex_j(planT, spiked),
            phase_b=lambda state, ss, t: b_j(planT, state, ss, ti(t)),
            phase_a_dynamics=lambda state, t: adyn_j(planT, state, ti(t)),
            phase_a_plasticity=lambda state, spiked, t: aplast_j(
                planT, state, spiked, ti(t)))

    @property
    def fused(self):
        """Jitted fused step (planT, state, t) -> (state, spiked, tm) —
        for HLO cost analysis (`fused.lower(sp.planT, state, t)`); the
        plan stays an argument per the no-closure-constants rule."""
        if self._fused is None:
            spec = self.spec
            ph = distributed._delivery_phases(spec, self._stim_k,
                                              self.caps)
            exchange = (self._vmap_exchange() if self.mesh is None
                        else None)
            if exchange is None:
                raise ValueError("fused is a single-device (mesh=None) "
                                 "analysis handle; use run() on a mesh")

            def _fused(planT, state, t):
                state, spiked, tm = jax.vmap(
                    ph.pa, in_axes=(0, 0, None))(planT, state, t)
                ss = exchange(planT, spiked)
                state = jax.vmap(
                    ph.pb, in_axes=(0, 0, 0, None))(planT, state, ss, t)
                return state, spiked, tm

            self._fused = jax.jit(_fused)
        return self._fused

    # -- timing handle (per-phase wall-clock attribution) ----------------

    def time_phases(self, state, t0: int, n_steps: int,
                    collect_rasters: bool = False):
        """Per-step wall-clock attribution — the paper's Table 2 split,
        shared by the cluster worker, the profiler and the bench suites
        so the warmup/blocking discipline cannot drift between them.

        Returns (final_state, times, rasters, counts): `times` accumulates
        phase_a_s / exchange_s / phase_b_s over `n_steps` (each phase
        `block_until_ready`-fenced), `rasters` is a list of per-step
        [H, N] numpy spike masks when `collect_rasters` else None, and
        `counts` totals the deterministic spike/arrival counters.

        Schedule-aware: under 'sync' the exchange is fenced between A and
        B, so exchange_s is its full exposed latency.  Under 'pipelined'
        the exchange is DISPATCHED between the two phase-A halves and
        only blocked on right before the phase B that consumes it (one
        step later, mirroring the fused program's rotated order), so
        exchange_s records just the dispatch + residual wait — the
        exposed remainder after hiding behind the LTP half.  Keys are
        identical across schedules, so hidden-vs-exposed comparisons are
        direct."""
        if self.spec.eng.exchange_schedule == "pipelined":
            return self._time_phases_pipelined(state, t0, n_steps,
                                               collect_rasters)
        return self._time_phases_sync(state, t0, n_steps, collect_rasters)

    def _time_phases_sync(self, state, t0, n_steps, collect_rasters):
        pp = self.phase_fns()
        s_w, spk_w, _ = pp.phase_a(state, t0)
        src_w = pp.exchange(spk_w)
        jax.block_until_ready(pp.phase_b(s_w, src_w, t0))

        times = dict(phase_a_s=0.0, exchange_s=0.0, phase_b_s=0.0)
        counts = dict(spikes=0, arrivals=0)
        rasters = [] if collect_rasters else None
        s = state
        for t in range(t0, t0 + n_steps):
            c0 = time.perf_counter()
            s2, spiked, tm = pp.phase_a(s, t)
            jax.block_until_ready(spiked)
            times["phase_a_s"] += time.perf_counter() - c0
            c0 = time.perf_counter()
            spiked_src = pp.exchange(spiked)
            jax.block_until_ready(spiked_src)
            times["exchange_s"] += time.perf_counter() - c0
            c0 = time.perf_counter()
            s = pp.phase_b(s2, spiked_src, t)
            jax.block_until_ready(s)
            times["phase_b_s"] += time.perf_counter() - c0
            self._tally(counts, rasters, spiked, tm)
        return s, times, rasters, counts

    def _time_phases_pipelined(self, state, t0, n_steps, collect_rasters):
        pp = self.phase_fns()
        # warmup: compile all four programs on throwaway outputs
        s_w, spk_w, _ = pp.phase_a_dynamics(state, t0)
        src_w = pp.exchange(spk_w)
        s_w = pp.phase_a_plasticity(s_w, spk_w, t0)
        jax.block_until_ready(pp.phase_b(s_w, src_w, t0))

        times = dict(phase_a_s=0.0, exchange_s=0.0, phase_b_s=0.0)
        counts = dict(spikes=0, arrivals=0)
        rasters = [] if collect_rasters else None
        s = state
        # all-False prologue buffer (phase B of it is an exact no-op)
        H, S = np.asarray(self.plan.src_gid).shape
        ss_buf = self.place(jnp.zeros((H, S), bool))
        for t in range(t0, t0 + n_steps):
            # residual exchange wait surfaces only here, right before the
            # consuming phase B — everything since dispatch was hidden
            c0 = time.perf_counter()
            jax.block_until_ready(ss_buf)
            times["exchange_s"] += time.perf_counter() - c0
            c0 = time.perf_counter()
            s = pp.phase_b(s, ss_buf, t - 1)
            jax.block_until_ready(s)
            times["phase_b_s"] += time.perf_counter() - c0
            c0 = time.perf_counter()
            s, spiked, tm = pp.phase_a_dynamics(s, t)
            jax.block_until_ready(spiked)
            times["phase_a_s"] += time.perf_counter() - c0
            c0 = time.perf_counter()
            ss_buf = pp.exchange(spiked)       # dispatch, do NOT block
            times["exchange_s"] += time.perf_counter() - c0
            c0 = time.perf_counter()
            s = pp.phase_a_plasticity(s, spiked, t)
            jax.block_until_ready(s)
            times["phase_a_s"] += time.perf_counter() - c0
            self._tally(counts, rasters, spiked, tm)
        # epilogue flush: deliver the last step's spikes
        c0 = time.perf_counter()
        jax.block_until_ready(ss_buf)
        times["exchange_s"] += time.perf_counter() - c0
        c0 = time.perf_counter()
        s = pp.phase_b(s, ss_buf, t0 + n_steps - 1)
        jax.block_until_ready(s)
        times["phase_b_s"] += time.perf_counter() - c0
        return s, times, rasters, counts

    @staticmethod
    def _tally(counts, rasters, spiked, tm):
        # in a multi-process job the per-step arrays span non-addressable
        # devices; workers gather what they need themselves
        # (cluster.runtime.gather), so tally only process-local arrays
        if not getattr(tm.spikes, "is_fully_addressable", True):
            return
        counts["spikes"] += int(np.asarray(tm.spikes).sum())
        counts["arrivals"] += int(np.asarray(tm.arrivals).sum())
        if rasters is not None:
            rasters.append(np.asarray(spiked))
