"""Fault-tolerant checkpointing with *elastic resharding* for the SNN engine.

The paper's reproducible-construction property (connectivity is a pure
function of gids, not of the process layout) means a checkpoint is
layout-free: we store neuron state keyed by gid and synapse state keyed by
the canonical (tgt_gid, src_gid, j) triple.  A run checkpointed at H shards
restores bit-identically at any H' / placement' (tested in
tests/test_checkpoint.py) — node-count changes on restart are free.

Writes are crash-safe: tmp file + atomic rename; `latest()` finds the newest
complete checkpoint, so a kill at any point leaves a loadable state.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Optional, Tuple

import numpy as np

from . import connectivity, engine, profiles
from .engine import ShardPlan, ShardState, SimSpec


def _global_keys(spec: SimSpec, plan: ShardPlan):
    """Canonical per-synapse key arrays (tgt_gid, src_gid, j), per shard."""
    gid = np.asarray(plan.gid)            # [H, N]
    src_gid = np.asarray(plan.src_gid)    # [H, S]
    H = gid.shape[0]
    tables = connectivity.build_all_shards(spec.cfg, spec.eng)
    tgt, src, j, valid = [], [], [], []
    for h in range(H):
        t = tables[h]
        tgt.append(gid[h][t.tgt_local])
        src.append(src_gid[h][t.src_idx])
        j.append(t.j)
        valid.append(t.valid)
    return (np.stack(tgt), np.stack(src), np.stack(j), np.stack(valid))


def save(path: str, spec: SimSpec, plan: ShardPlan, state: ShardState,
         t: int) -> str:
    """Write a layout-free checkpoint; returns the final path."""
    tgt, src, j, valid = _global_keys(spec, plan)
    m = valid.reshape(-1)

    gid = np.asarray(plan.gid).reshape(-1)
    nmask = gid >= 0
    order = np.argsort(gid[nmask], kind="stable")

    def neuron(a):
        return np.asarray(a).reshape(-1)[nmask][order]

    # synapses in global canonical order (tgt, src, j)
    key_order = np.lexsort((j.reshape(-1)[m], src.reshape(-1)[m],
                            tgt.reshape(-1)[m]))

    def syn(a):
        return np.asarray(a).reshape(-1)[m][key_order]

    D = spec.cfg.n_delay_slots
    arr = np.asarray(state.arr_ring)               # [H, D, E]
    arr = np.moveaxis(arr, 1, 0).reshape(D, -1)    # [D, H*E]
    arr = arr[:, m][:, key_order]

    payload = dict(
        gid=gid[nmask][order],
        v=neuron(state.v), u=neuron(state.u),
        last_post=neuron(state.last_post),
        tgt=tgt.reshape(-1)[m][key_order], src=src.reshape(-1)[m][key_order],
        j=j.reshape(-1)[m][key_order],
        w=syn(state.w), last_arr=syn(state.last_arr), arr_ring=arr,
        t=np.int64(t))
    prof = profiles.from_config(spec.cfg)
    meta = dict(grid_x=spec.cfg.grid_x, grid_y=spec.cfg.grid_y,
                neurons_per_column=spec.cfg.neurons_per_column,
                synapses_per_neuron=spec.cfg.synapses_per_neuron,
                seed=spec.cfg.seed, connectivity=spec.cfg.connectivity,
                ring_masses=list(prof.ring_masses()), t=int(t))

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez_compressed(f, meta=json.dumps(meta), **payload)
    os.replace(tmp, path)                          # atomic
    return path


def load(path: str, spec: SimSpec, plan: ShardPlan
         ) -> Tuple[ShardState, int]:
    """Restore into an arbitrary (possibly different) layout."""
    z = np.load(path, allow_pickle=False)
    meta = json.loads(str(z["meta"]))
    for k, v in (("grid_x", spec.cfg.grid_x), ("grid_y", spec.cfg.grid_y),
                 ("neurons_per_column", spec.cfg.neurons_per_column),
                 ("synapses_per_neuron", spec.cfg.synapses_per_neuron),
                 ("seed", spec.cfg.seed)):
        assert meta[k] == v, f"checkpoint {k} mismatch: {meta[k]} != {v}"
    # Profile mismatch means different synapse keys — restoring would
    # silently produce garbage.  Gate on the resolved kernel (per-ring
    # masses fully determine the draws given seed/grid/M), NOT the raw
    # spec string: "ring:max_ring=3" == "ring3" must load, while "ring3"
    # under different GridConfig.ring_fractions must not.  Checkpoints
    # from before this key carried whatever kernel the loading config
    # implies (the old guard never checked), so absence skips the check.
    if "ring_masses" in meta:
        cur = list(profiles.from_config(spec.cfg).ring_masses())
        assert meta["ring_masses"] == cur, \
            f"checkpoint connectivity profile mismatch: saved " \
            f"{meta.get('connectivity')!r} (ring masses " \
            f"{meta['ring_masses']}) != current " \
            f"{spec.cfg.connectivity!r} ({cur})"

    # neurons: direct gid lookup
    gid = np.asarray(plan.gid)                     # [H, N]
    ok = gid >= 0
    safe = np.where(ok, gid, 0)
    state = engine.init_state(spec, plan)

    def neuron(name, init):
        a = np.asarray(init).copy()
        a[ok] = z[name][safe[ok]]
        return a

    # synapses: locate each local key in the stored canonical order
    tgt, src, j, valid = _global_keys(spec, plan)
    H, E = valid.shape
    stored = (z["tgt"].astype(np.int64), z["src"].astype(np.int64),
              z["j"].astype(np.int64))
    # rank local keys among stored keys via lexicographic searchsorted on a
    # packed key (tgt, src, j are all < 2**21 in any practical run)
    def pack(t_, s_, j_):
        return (t_.astype(np.int64) << 42) | (s_.astype(np.int64) << 21) \
            | j_.astype(np.int64)
    skey = pack(*stored)                           # ascending by construction
    lkey = pack(tgt.reshape(-1), src.reshape(-1), j.reshape(-1))
    pos = np.searchsorted(skey, lkey)
    m = valid.reshape(-1)
    pos = np.where(m, np.clip(pos, 0, skey.shape[0] - 1), 0)
    assert np.array_equal(skey[pos][m], lkey[m]), "synapse key mismatch"

    def syn(name, init):
        a = np.asarray(init).reshape(-1).copy()
        a[m] = z[name][pos[m]]
        return a.reshape(H, E)

    D = spec.cfg.n_delay_slots
    arr = np.zeros((H * E, D), dtype=bool)
    arr[m] = z["arr_ring"].T[pos[m]]
    arr = np.moveaxis(arr.reshape(H, E, D), 2, 1)  # [H, D, E]

    import jax.numpy as jnp
    new = ShardState(
        v=jnp.asarray(neuron("v", state.v)),
        u=jnp.asarray(neuron("u", state.u)),
        last_post=jnp.asarray(neuron("last_post", state.last_post)),
        w=jnp.asarray(syn("w", state.w)),
        last_arr=jnp.asarray(syn("last_arr", state.last_arr)),
        arr_ring=jnp.asarray(arr))
    return new, int(z["t"])


def latest(directory: str, prefix: str = "ckpt_") -> Optional[str]:
    """Newest complete checkpoint in `directory` (crash-safe discovery)."""
    if not os.path.isdir(directory):
        return None
    cands = [f for f in os.listdir(directory)
             if f.startswith(prefix) and f.endswith(".npz")]
    if not cands:
        return None
    step = lambda f: int(f[len(prefix):-4])
    return os.path.join(directory, max(cands, key=step))
