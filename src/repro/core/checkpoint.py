"""Fault-tolerant checkpointing with *elastic resharding* for the SNN engine.

The paper's reproducible-construction property (connectivity is a pure
function of gids, not of the process layout) means a checkpoint is
layout-free: we store neuron state keyed by gid and synapse state keyed by
the canonical (tgt_gid, src_gid, j) triple.  A run checkpointed at H shards
restores bit-identically at any H' / placement' (tested in
tests/test_checkpoint.py) — node-count changes on restart are free.

Writes are crash-safe: tmp file + atomic rename with a sha256 payload
digest embedded (`core.integrity`); `load` verifies the digest and raises
`CheckpointCorrupt` — never deserializes garbage — on a truncated or
bit-flipped file.  `latest()` finds the newest complete checkpoint and
`latest_valid()` the newest that VERIFIES (falling back past corrupted
epochs), so a kill or disk corruption at any point leaves a loadable
state.  A checkpoint may optionally carry the run's cumulative spike
events (`raster_events=`): a supervised cluster run restarted from a
mid-run epoch recovers the raster-so-far and its final full-run
signature stays bit-identical to the fault-free run.

Both delivery backends are covered by ONE on-disk format: the event
backend's ring of per-slot synapse-id lists maps onto the dense backend's
[D, E] per-synapse ring layout (a synapse can be pending at most once per
slot — delays < D guarantee it), except the event entries are within-slot
RANKS rather than booleans: phase_a's fp32 scatter-add accumulates in
list order, so `load` must rebuild each slot list in the exact order the
live ring held (same-layout restarts stay bit-identical); a resharded
restore merges by the saved ranks (deterministic, same-source relative
order preserved).  The checkpoint records which backend wrote it and
`load` guards a mode mismatch like connectivity: the two backends' states
are intentionally NOT interchangeable (their fp32 summation orders
differ, so silently continuing under the other backend would
un-reproducibly fork the trajectory).
"""
from __future__ import annotations

import json
import os
from typing import Optional, Tuple

import numpy as np

from . import connectivity, engine, event_engine, integrity, profiles
from .engine import ShardPlan, ShardState, SimSpec
from .event_engine import EventState
from .integrity import CheckpointCorrupt  # noqa: F401  (public re-export)


def _global_keys(spec: SimSpec, plan: ShardPlan):
    """Canonical per-synapse key arrays (tgt_gid, src_gid, j), per shard.

    Streamed mode carries weight state in chunk-concatenated canonical
    order — a contiguous valid prefix of each shard's [e_pad] axis — so
    its keys come from `connectivity.streamed_shard_keys` padded to the
    same layout; the on-disk format (global canonical order) is shared
    with materialized mode."""
    gid = np.asarray(plan.gid)            # [H, N]
    src_gid = np.asarray(plan.src_gid)    # [H, S]
    H = gid.shape[0]
    if spec.stream is not None:
        e_pad = spec.stream.e_pad
        tgt = np.zeros((H, e_pad), np.int64)
        src = np.zeros((H, e_pad), np.int64)
        j = np.zeros((H, e_pad), np.int64)
        valid = np.zeros((H, e_pad), bool)
        for h in range(H):
            t_, s_, j_ = connectivity.streamed_shard_keys(
                spec.cfg, spec.eng, h, spec.stream.chunk_cols)
            n = t_.shape[0]
            tgt[h, :n], src[h, :n], j[h, :n] = t_, s_, j_
            valid[h, :n] = True
        return tgt, src, j, valid
    tables = connectivity.build_all_shards(spec.cfg, spec.eng)
    tgt, src, j, valid = [], [], [], []
    for h in range(H):
        t = tables[h]
        tgt.append(gid[h][t.tgt_local])
        src.append(src_gid[h][t.src_idx])
        j.append(t.j)
        valid.append(t.valid)
    return (np.stack(tgt), np.stack(src), np.stack(j), np.stack(valid))


def _event_ring_to_ranks(state: EventState, e_cap: int) -> np.ndarray:
    """[H, D, cap_ev] event-id lists -> [H, D, E] per-synapse slot RANKS
    (0 = not pending, k = k-th event of its slot list).

    Rides the dense ring's per-synapse persistence layout (a synapse is
    pending at most once per slot, delays < D) but keeps the within-slot
    ORDER: phase_a's fp32 scatter-add accumulates in list order, so a
    restore that re-canonicalized the lists would fork the trajectory
    bitwise whenever >= 3 same-slot events share a target."""
    ring = np.asarray(state.ev_ring)
    H, D, cap = ring.shape
    ranks = np.zeros((H, D, e_cap), dtype=np.int32)
    pos = np.arange(1, cap + 1, dtype=np.int32)
    for h in range(H):
        for d in range(D):
            ids = ring[h, d]
            ranks[h, d, ids[ids >= 0]] = pos[ids >= 0]
    return ranks


def _ranks_to_event_ring(ranks: np.ndarray, cap_ev: int):
    """Inverse of `_event_ring_to_ranks`: per-slot lists ordered by the
    saved ranks.  Same-layout restore reproduces the live list exactly
    (bit-identical continuation); a resharded restore merges each new
    shard's pending events by their old ranks (stable, ascending-id
    ties), which is deterministic and preserves every same-source
    relative order."""
    H, D, _ = ranks.shape
    ring = np.full((H, D, cap_ev), -1, dtype=np.int32)
    count = np.zeros((H, D), dtype=np.int32)
    for h in range(H):
        for d in range(D):
            ids = np.nonzero(ranks[h, d])[0]
            if ids.shape[0] > cap_ev:
                raise ValueError(
                    f"checkpoint slot holds {ids.shape[0]} pending events "
                    f"> cap_ev {cap_ev}; restore with a larger cap_ev")
            ids = ids[np.argsort(ranks[h, d, ids], kind="stable")]
            ring[h, d, :ids.shape[0]] = ids
            count[h, d] = ids.shape[0]
    return ring, count


def save(path: str, spec: SimSpec, plan: ShardPlan, state, t: int,
         raster_events: Optional[Tuple[np.ndarray, np.ndarray]] = None
         ) -> str:
    """Write a layout-free checkpoint; returns the final path.

    `state` is a ShardState (delivery='dense') or an EventState
    (delivery='event'); the mode is recorded and guarded on load.
    `raster_events=(times, gids)` optionally persists the run's
    cumulative spike events so a restarted run can reconstruct the
    full-run raster signature (`load_raster_events` reads them back);
    events are already layout-free (absolute step, global id)."""
    delivery, sat_total = "dense", 0
    if isinstance(state, EventState):
        delivery = "event"
        sat_total = int(np.asarray(state.sat).sum())
        ranks = _event_ring_to_ranks(state, state.base.w.shape[-1])
        state = state.base._replace(arr_ring=ranks)
    tgt, src, j, valid = _global_keys(spec, plan)
    m = valid.reshape(-1)

    gid = np.asarray(plan.gid).reshape(-1)
    nmask = gid >= 0
    order = np.argsort(gid[nmask], kind="stable")

    def neuron(a):
        return np.asarray(a).reshape(-1)[nmask][order]

    # synapses in global canonical order (tgt, src, j)
    key_order = np.lexsort((j.reshape(-1)[m], src.reshape(-1)[m],
                            tgt.reshape(-1)[m]))

    def syn(a):
        return np.asarray(a).reshape(-1)[m][key_order]

    D = spec.cfg.n_delay_slots
    arr = np.asarray(state.arr_ring)               # [H, D, E]
    arr = np.moveaxis(arr, 1, 0).reshape(D, -1)    # [D, H*E]
    arr = arr[:, m][:, key_order]

    payload = dict(
        gid=gid[nmask][order],
        v=neuron(state.v), u=neuron(state.u),
        last_post=neuron(state.last_post),
        tgt=tgt.reshape(-1)[m][key_order], src=src.reshape(-1)[m][key_order],
        j=j.reshape(-1)[m][key_order],
        w=syn(state.w), last_arr=syn(state.last_arr), arr_ring=arr,
        t=np.int64(t))
    if raster_events is not None:
        ev_t, ev_g = raster_events
        payload["ev_t"] = np.asarray(ev_t, dtype=np.int64)
        payload["ev_g"] = np.asarray(ev_g, dtype=np.int64)
    prof = profiles.from_config(spec.cfg)
    meta = dict(grid_x=spec.cfg.grid_x, grid_y=spec.cfg.grid_y,
                neurons_per_column=spec.cfg.neurons_per_column,
                synapses_per_neuron=spec.cfg.synapses_per_neuron,
                seed=spec.cfg.seed, connectivity=spec.cfg.connectivity,
                ring_masses=list(prof.ring_masses()), t=int(t),
                delivery=delivery, sat=sat_total,
                connectivity_mode=("streamed" if spec.stream is not None
                                   else "materialized"),
                n_events=(0 if raster_events is None
                          else int(payload["ev_t"].shape[0])))

    # atomic tmp+rename write with the sha256 payload digest embedded —
    # load() re-derives it and refuses truncated/bit-flipped files
    payload["meta"] = np.array(json.dumps(meta))
    return integrity.write_verified(path, payload)


def load(path: str, spec: SimSpec, plan: ShardPlan,
         cap_ev: Optional[int] = None) -> Tuple[ShardState, int]:
    """Restore into an arbitrary (possibly different) layout.

    Returns (ShardState, t) for delivery='dense' and (EventState, t) for
    delivery='event' (then `cap_ev` sizes the rebuilt ring — pass
    `state.ev_ring.shape[-1]` from `event_engine.build`).

    Raises `CheckpointCorrupt` (never deserializes garbage) when the file
    is truncated, undecodable, or fails its sha256 payload digest."""
    z = integrity.read_verified(path)
    meta = json.loads(str(z["meta"]))
    for k, v in (("grid_x", spec.cfg.grid_x), ("grid_y", spec.cfg.grid_y),
                 ("neurons_per_column", spec.cfg.neurons_per_column),
                 ("synapses_per_neuron", spec.cfg.synapses_per_neuron),
                 ("seed", spec.cfg.seed)):
        assert meta[k] == v, f"checkpoint {k} mismatch: {meta[k]} != {v}"
    # Delivery-mode guard, same shape as the connectivity guard below: the
    # backends' states are semantically convertible but their fp32
    # summation orders differ, so a silent cross-mode restore would fork
    # the trajectory un-reproducibly.  Checkpoints from before this key
    # were all written by the dense engine.
    saved_mode = meta.get("delivery", "dense")
    assert saved_mode == spec.eng.delivery, \
        f"checkpoint delivery mode mismatch: saved {saved_mode!r} != " \
        f"configured {spec.eng.delivery!r}"
    # Connectivity-residency guard (mode ONLY, not chunk size): a streamed
    # checkpoint restores into any shard count AND any chunk size — both
    # are execution layouts over the same canonical key order — but
    # streamed <-> materialized is refused: the two modes size every
    # synapse-state buffer differently, and a silent cross-mode restore
    # would hide a misconfigured run.  Checkpoints from before this key
    # were all written by materialized mode.
    saved_cm = meta.get("connectivity_mode", "materialized")
    cur_cm = "streamed" if spec.stream is not None else "materialized"
    assert saved_cm == cur_cm, \
        f"checkpoint connectivity mode mismatch: saved {saved_cm!r} != " \
        f"configured {cur_cm!r} — streamed and materialized checkpoints " \
        f"are not interchangeable; re-save under the target mode"
    # Profile mismatch means different synapse keys — restoring would
    # silently produce garbage.  Gate on the resolved kernel (per-ring
    # masses fully determine the draws given seed/grid/M), NOT the raw
    # spec string: "ring:max_ring=3" == "ring3" must load, while "ring3"
    # under different GridConfig.ring_fractions must not.  Checkpoints
    # from before this key carried whatever kernel the loading config
    # implies (the old guard never checked), so absence skips the check.
    if "ring_masses" in meta:
        cur = list(profiles.from_config(spec.cfg).ring_masses())
        assert meta["ring_masses"] == cur, \
            f"checkpoint connectivity profile mismatch: saved " \
            f"{meta.get('connectivity')!r} (ring masses " \
            f"{meta['ring_masses']}) != current " \
            f"{spec.cfg.connectivity!r} ({cur})"

    # neurons: direct gid lookup
    gid = np.asarray(plan.gid)                     # [H, N]
    ok = gid >= 0
    safe = np.where(ok, gid, 0)
    state = engine.init_state(spec, plan)

    def neuron(name, init):
        a = np.asarray(init).copy()
        a[ok] = z[name][safe[ok]]
        return a

    # synapses: locate each local key in the stored canonical order
    tgt, src, j, valid = _global_keys(spec, plan)
    H, E = valid.shape
    stored = (z["tgt"].astype(np.int64), z["src"].astype(np.int64),
              z["j"].astype(np.int64))
    # rank local keys among stored keys via lexicographic searchsorted on a
    # packed key (tgt, src, j are all < 2**21 in any practical run)
    def pack(t_, s_, j_):
        return (t_.astype(np.int64) << 42) | (s_.astype(np.int64) << 21) \
            | j_.astype(np.int64)
    skey = pack(*stored)                           # ascending by construction
    lkey = pack(tgt.reshape(-1), src.reshape(-1), j.reshape(-1))
    pos = np.searchsorted(skey, lkey)
    m = valid.reshape(-1)
    pos = np.where(m, np.clip(pos, 0, skey.shape[0] - 1), 0)
    assert np.array_equal(skey[pos][m], lkey[m]), "synapse key mismatch"

    def syn(name, init):
        a = np.asarray(init).reshape(-1).copy()
        a[m] = z[name][pos[m]]
        return a.reshape(H, E)

    # per-slot ring, re-keyed like every synapse field: bool arrival flags
    # for the dense backend, int32 within-slot ranks for the event one
    D = spec.cfg.n_delay_slots
    arr = np.zeros((H * E, D), dtype=z["arr_ring"].dtype)
    arr[m] = z["arr_ring"].T[pos[m]]
    arr = np.moveaxis(arr.reshape(H, E, D), 2, 1)  # [H, D, E]

    import jax.numpy as jnp
    event = saved_mode == "event"
    base = ShardState(
        v=jnp.asarray(neuron("v", state.v)),
        u=jnp.asarray(neuron("u", state.u)),
        last_post=jnp.asarray(neuron("last_post", state.last_post)),
        w=jnp.asarray(syn("w", state.w)),
        last_arr=jnp.asarray(syn("last_arr", state.last_arr)),
        arr_ring=jnp.zeros_like(state.arr_ring) if event
        else jnp.asarray(arr))
    if not event:
        return base, int(z["t"])
    if cap_ev is None:
        raise ValueError("loading an event-mode checkpoint needs cap_ev= "
                         "(the ring capacity from event_engine.build)")
    ring, count = _ranks_to_event_ring(arr, cap_ev)
    sat = np.zeros((H,), np.int32)
    sat[0] = int(meta.get("sat", 0))       # layout-free total, on shard 0
    new = event_engine.EventState(
        base=base, ev_ring=jnp.asarray(ring),
        ev_count=jnp.asarray(count), sat=jnp.asarray(sat))
    return new, int(z["t"])


def load_raster_events(path: str
                       ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Cumulative (times, gids) spike events saved with the checkpoint,
    or None when it was written without `raster_events=`.  Verified like
    `load` (raises `CheckpointCorrupt`)."""
    z = integrity.read_verified(path)
    if "ev_t" not in z:
        return None
    return z["ev_t"].astype(np.int64), z["ev_g"].astype(np.int64)


def saved_t(path: str) -> int:
    """The step a (verified) checkpoint was taken at."""
    z = integrity.read_verified(path)
    return int(json.loads(str(z["meta"]))["t"])


def latest(directory: str, prefix: str = "ckpt_") -> Optional[str]:
    """Newest complete checkpoint in `directory` (crash-safe discovery)."""
    steps = integrity.checkpoint_steps(directory, prefix)
    return steps[-1][1] if steps else None


def latest_valid(directory: str, prefix: str = "ckpt_") -> Optional[str]:
    """Newest checkpoint that passes sha256 verification, falling back
    past corrupted epochs (the supervisor's restart anchor)."""
    return integrity.latest_valid(directory, prefix)
