"""Izhikevich neuron dynamics (time-driven part of the simulation).

Canonical Izhikevich (2003) form with two half-steps for the membrane
equation (as in the published reference implementation the paper follows):

    v' = 0.04 v^2 + 5 v + 140 - u + I      (two dt/2 Euler substeps)
    u' = a (b v - u)                        (one dt step)
    if v >= v_peak:  record spike, v <- c, u <- u + d

State is fp32: the reset discontinuity makes the system stiff near
threshold, and bf16 perturbs spike timings enough to break the paper's
bit-identical-raster property.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from .params import IzhikevichParams


class NeuronState(NamedTuple):
    v: jnp.ndarray       # [N] fp32 membrane potential (mV)
    u: jnp.ndarray       # [N] fp32 recovery variable


def init_state(exc_mask: jnp.ndarray, p: IzhikevichParams) -> NeuronState:
    """Paper/Izhikevich init: v = v_init, u = b * v."""
    v = jnp.full(exc_mask.shape, p.v_init, dtype=jnp.float32)
    b = jnp.where(exc_mask, p.b_exc, p.b_inh).astype(jnp.float32)
    return NeuronState(v=v, u=b * v)


def step(state: NeuronState, current: jnp.ndarray, exc_mask: jnp.ndarray,
         p: IzhikevichParams) -> Tuple[NeuronState, jnp.ndarray]:
    """One dt step.  Returns (new_state, spiked[N] bool)."""
    v, u = state.v, state.u
    current = current.astype(jnp.float32)
    a = jnp.where(exc_mask, p.a_exc, p.a_inh).astype(jnp.float32)
    b = jnp.where(exc_mask, p.b_exc, p.b_inh).astype(jnp.float32)
    c = jnp.where(exc_mask, p.c_exc, p.c_inh).astype(jnp.float32)
    d = jnp.where(exc_mask, p.d_exc, p.d_inh).astype(jnp.float32)

    h = jnp.float32(p.dt / p.v_substeps)
    for _ in range(p.v_substeps):
        v = v + h * (0.04 * v * v + 5.0 * v + 140.0 - u + current)
    u = u + jnp.float32(p.dt) * a * (b * v - u)

    spiked = v >= jnp.float32(p.v_peak)
    v = jnp.where(spiked, c, v)
    u = jnp.where(spiked, u + d, u)
    return NeuronState(v=v, u=u), spiked
