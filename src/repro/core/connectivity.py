"""Distributed, reproducible connectivity generation.

The paper's requirement: "the capability to initialize in a distributed manner
an identical network ... distributed over a varying number of software
processes and hardware processors".  Each forward synapse of neuron `g` at
slot `j` is a pure function of (seed, g, j, grid shape), computed with a
counter-based hash (splitmix64).  Any shard can therefore regenerate exactly
the incoming synapses it owns with **zero communication** — this replaces the
paper's O(P^2) MPI_Alltoall synapse-counter + MPI_Alltoallv synapse-list
construction phase (see DESIGN.md §2).

Canonical synapse order: sorted by (tgt_gid, src_gid, j).  Because every
synapse lives wholly on its target's owner shard, per-target accumulation
order is identical for every shard count / placement, which is what makes the
simulated rasters bit-identical across distributions (paper Table 1 check).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from . import profiles, topology
from .params import EngineConfig, GridConfig

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer; input/output uint64 (wrapping)."""
    with np.errstate(over="ignore"):
        x = (x + _GOLDEN).astype(np.uint64)
        x = (x ^ (x >> np.uint64(30))) * _MIX1
        x = (x ^ (x >> np.uint64(27))) * _MIX2
        return x ^ (x >> np.uint64(31))


def _stream(seed: int, counter: np.ndarray, lane: int) -> np.ndarray:
    """k-th independent uint64 draw for each counter value."""
    with np.errstate(over="ignore"):
        s = splitmix64(np.uint64(seed) + _GOLDEN * np.uint64(lane + 1))
    return splitmix64(counter.astype(np.uint64) ^ s)


def _uniform01(bits: np.ndarray) -> np.ndarray:
    return (bits >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


@dataclasses.dataclass
class ForwardSynapses:
    """Forward synapses of a set of source neurons; all arrays [G, M]."""

    src_gid: np.ndarray       # [G]
    tgt_gid: np.ndarray       # [G, M]
    delay: np.ndarray         # [G, M] int32, in steps (1..delay_max)
    weight: np.ndarray        # [G, M] float32 initial value
    plastic: np.ndarray       # [G, M] bool


def forward_synapses(cfg: GridConfig, src_gids: np.ndarray) -> ForwardSynapses:
    """Generate the M forward synapses of each source gid (vectorized).

    The lateral kernel is pluggable (`core.profiles`): the profile supplies
    the per-ring cumulative target fractions and the flattened ring-offset
    tables up to its reach; the four splitmix64 draw lanes are identical
    for every profile, and for the default `ring3` profile this whole
    function is bit-identical to the paper's hard-coded kernel.
    """
    g = np.asarray(src_gids, dtype=np.int64)
    M = cfg.synapses_per_neuron
    counter = (g[:, None] * np.int64(M) + np.arange(M, dtype=np.int64)[None, :])
    c = counter.astype(np.uint64)

    r_ring = _uniform01(_stream(cfg.seed, c, 0))
    r_member = _stream(cfg.seed, c, 1)
    r_tgt = _stream(cfg.seed, c, 2)
    r_delay = _stream(cfg.seed, c, 3)

    exc = topology.is_excitatory(cfg, g)[:, None]     # [G, 1]
    src_col = topology.gid_column(cfg, g)             # [G]
    cx, cy = topology.column_coords(cfg, src_col)

    # --- excitatory: ring via cumulative fractions, member within ring ---
    prof = profiles.from_config(cfg)
    reach = prof.reach()
    off_tab, start = profiles.offset_tables(reach)
    fr = prof.cum_fractions()
    ring = np.searchsorted(fr, r_ring, side="right").clip(0, reach)  # [G, M]
    ring_size = (start[ring + 1] - start[ring])
    member = (r_member % ring_size.astype(np.uint64)).astype(np.int64)
    off = off_tab[start[ring] + member]               # [G, M, 2]
    tcol_exc = topology.wrap_column(cfg, cx[:, None] + off[..., 0],
                                    cy[:, None] + off[..., 1])
    n_exc_tgt = (r_tgt % np.uint64(cfg.neurons_per_column)).astype(np.int64)
    tgt_exc = tcol_exc * cfg.neurons_per_column + n_exc_tgt
    delay_exc = 1 + (r_delay % np.uint64(cfg.delay_max - cfg.delay_min + 1)
                     ).astype(np.int64) + (cfg.delay_min - 1)

    # --- inhibitory: same column, excitatory targets only, min delay ---
    n_inh_tgt = (r_tgt % np.uint64(cfg.n_exc_per_column)).astype(np.int64)
    tgt_inh = src_col[:, None] * cfg.neurons_per_column + n_inh_tgt
    delay_inh = np.full_like(delay_exc, cfg.delay_min)

    excb = np.broadcast_to(exc, tgt_exc.shape)
    tgt = np.where(excb, tgt_exc, tgt_inh)
    delay = np.where(excb, delay_exc, delay_inh).astype(np.int32)
    weight = np.where(excb, cfg.w_exc_init, cfg.w_inh_init).astype(np.float32)
    plastic = excb.copy()
    return ForwardSynapses(g, tgt, delay, weight, plastic)


@dataclasses.dataclass
class ShardSynapses:
    """Incoming synapses of one shard, canonical order (tgt_gid, src_gid, j).

    Padded to static capacities; `n_valid` / `n_src` give true counts.
    """

    # source table: sorted unique source gids with >=1 incoming synapse here
    src_gid: np.ndarray        # [S_cap] int64 (pad: -1)
    n_src: int
    # synapse arrays, flat, canonical order (pad: valid=False)
    src_idx: np.ndarray        # [E_cap] int32 -> index into src_gid
    tgt_local: np.ndarray      # [E_cap] int32 -> owned-neuron local index
    j: np.ndarray              # [E_cap] int32 forward-slot index (checkpoint key)
    delay: np.ndarray          # [E_cap] int32
    weight0: np.ndarray        # [E_cap] float32
    plastic: np.ndarray        # [E_cap] bool
    valid: np.ndarray          # [E_cap] bool
    n_valid: int


def candidate_sources(cfg: GridConfig, eng: EngineConfig, shard: int
                      ) -> np.ndarray:
    """All gids that may project a synapse onto this shard's neurons."""
    halo_cols = topology.shard_halo_columns(cfg, shard, eng.n_shards,
                                            eng.placement)
    npc = cfg.neurons_per_column
    nexc = cfg.n_exc_per_column
    # excitatory neurons of all halo columns
    exc = (halo_cols[:, None] * npc + np.arange(nexc)[None, :]).ravel()
    # inhibitory neurons of columns containing local targets (they project
    # only intra-column); own columns are a subset of the halo
    gids = topology.owned_gids(cfg, shard, eng.n_shards, eng.placement)
    own_cols = np.unique(topology.gid_column(cfg, gids))
    inh = (own_cols[:, None] * npc + np.arange(nexc, npc)[None, :]).ravel()
    return np.unique(np.concatenate([exc, inh]))


def build_shard(cfg: GridConfig, eng: EngineConfig, shard: int,
                e_cap: Optional[int] = None, s_cap: Optional[int] = None
                ) -> ShardSynapses:
    """Regenerate (locally, no communication) this shard's incoming synapses."""
    gids = topology.owned_gids(cfg, shard, eng.n_shards, eng.placement)
    cand = candidate_sources(cfg, eng, shard)
    fwd = forward_synapses(cfg, cand)

    owner = topology.owner_of(cfg, fwd.tgt_gid.ravel(), eng.n_shards,
                              eng.placement)
    keep = owner == shard
    src = np.repeat(cand, cfg.synapses_per_neuron)[keep]
    j = np.tile(np.arange(cfg.synapses_per_neuron, dtype=np.int64),
                cand.shape[0])[keep]
    tgt = fwd.tgt_gid.ravel()[keep]
    delay = fwd.delay.ravel()[keep]
    weight = fwd.weight.ravel()[keep]
    plastic = fwd.plastic.ravel()[keep]

    # canonical order: (tgt_gid, src_gid, j)
    order = np.lexsort((j, src, tgt))
    src, j, tgt, delay, weight, plastic = (a[order] for a in
                                           (src, j, tgt, delay, weight, plastic))

    # local target index: position of tgt gid within owned gid list
    tgt_local = np.searchsorted(gids, tgt).astype(np.int32)
    assert np.array_equal(gids[tgt_local], tgt), "target must be owned"

    src_table = np.unique(src)
    src_idx = np.searchsorted(src_table, src).astype(np.int32)

    E, S = src.shape[0], src_table.shape[0]
    e_cap = E if e_cap is None else e_cap
    s_cap = S if s_cap is None else s_cap
    assert e_cap >= E and s_cap >= S

    def padE(a, fill=0):
        out = np.full((e_cap,), fill, dtype=a.dtype)
        out[:E] = a
        return out

    src_gid_p = np.full((s_cap,), -1, dtype=np.int64)
    src_gid_p[:S] = src_table
    return ShardSynapses(
        src_gid=src_gid_p, n_src=S,
        src_idx=padE(src_idx), tgt_local=padE(tgt_local),
        j=padE(j.astype(np.int32)),
        delay=padE(delay.astype(np.int32), 1),
        weight0=padE(weight), plastic=padE(plastic),
        valid=padE(np.ones(E, dtype=bool)), n_valid=E)


def repad_shard(t: ShardSynapses, e_cap: int, s_cap: int) -> ShardSynapses:
    """Grow a shard table to new static capacities (no recompute)."""
    assert e_cap >= t.n_valid and s_cap >= t.n_src

    def padE(a, fill=0):
        out = np.full((e_cap,), fill, dtype=a.dtype)
        out[:t.n_valid] = a[:t.n_valid]
        return out

    src_gid = np.full((s_cap,), -1, dtype=np.int64)
    src_gid[:t.n_src] = t.src_gid[:t.n_src]
    return ShardSynapses(
        src_gid=src_gid, n_src=t.n_src,
        src_idx=padE(t.src_idx), tgt_local=padE(t.tgt_local),
        j=padE(t.j), delay=padE(t.delay, 1), weight0=padE(t.weight0),
        plastic=padE(t.plastic), valid=padE(t.valid), n_valid=t.n_valid)


def build_all_shards(cfg: GridConfig, eng: EngineConfig) -> List[ShardSynapses]:
    """Build every shard with uniform (max) capacities, for stacking."""
    raw = [build_shard(cfg, eng, h) for h in range(eng.n_shards)]
    e_cap = _round_up(max(r.n_valid for r in raw), 8)
    s_cap = _round_up(max(r.n_src for r in raw), 8)
    return [repad_shard(r, e_cap, s_cap) for r in raw]


def _round_up(x: int, m: int) -> int:
    return max(m, -(-x // m) * m)


# ---------------------------------------------------------------------------
# Streamed residency (EngineConfig.connectivity = 'streamed:chunk=<K>')
#
# The same counter-based draw lanes that make materialized construction
# communication-free also make it CHUNKABLE: the canonical synapse list of a
# shard, restricted to any contiguous range of owned target neurons, is a pure
# function of (seed, grid, range) and can be regenerated at will.  The host
# builder below only ever materializes one chunk at a time; the jitted
# counterpart lives in `core.stream_engine` and must stay bit-identical to
# `_chunk_synapses` (tests/test_stream_connectivity.py walls this off).


def parse_mode(spec: str) -> Tuple[str, Optional[int]]:
    """Parse an EngineConfig.connectivity spec.

    Returns ('materialized', None) or ('streamed', chunk_cols).
    """
    s = str(spec).strip()
    if s == "materialized":
        return "materialized", None
    name, _, body = s.partition(":")
    if name != "streamed":
        raise ValueError(
            f"unknown connectivity mode {spec!r}: expected 'materialized' "
            f"or 'streamed:chunk=<K>'")
    chunk = 1
    for item in filter(None, (p.strip() for p in body.split(","))):
        key, eq, val = item.partition("=")
        if key != "chunk" or not eq:
            raise ValueError(
                f"bad streamed connectivity option {item!r} in {spec!r}: "
                f"the only option is 'chunk=<K>' (target columns per "
                f"regenerated chunk)")
        chunk = int(val)
    if chunk < 1:
        raise ValueError(f"streamed chunk size must be >= 1, got {chunk}")
    return "streamed", chunk


def stream_geometry(cfg: GridConfig, eng: EngineConfig, chunk_cols: int
                    ) -> Tuple[int, int, int]:
    """(n_cap, q, n_chunks): uniform across shards (n_cap is uniform).

    q = owned-neuron slots per chunk; the last chunk may cover fewer real
    neurons (non-dividing K) — its tail slots simply never match a target.
    """
    n_cap = topology.max_local_size(cfg, eng.n_shards, eng.placement)
    q = chunk_cols * cfg.neurons_per_column
    n_chunks = -(-n_cap // q)
    return n_cap, q, n_chunks


def chunk_candidates(cfg: GridConfig, eng: EngineConfig, shard: int,
                     lo: int, hi: int) -> np.ndarray:
    """Sorted unique gids that may project onto owned local indices [lo, hi).

    Subset of `candidate_sources(cfg, eng, shard)` by construction (the
    chunk's columns are a subset of the shard's, so their halo is too).
    """
    gids = topology.owned_gids(cfg, shard, eng.n_shards, eng.placement)
    sel = gids[lo:min(hi, gids.shape[0])]
    if sel.size == 0:
        return np.empty((0,), dtype=np.int64)
    cols = np.unique(topology.gid_column(cfg, sel))
    halos = np.unique(np.concatenate(
        [topology.neighbour_columns(cfg, int(c)) for c in cols]))
    npc = cfg.neurons_per_column
    nexc = cfg.n_exc_per_column
    exc = (halos[:, None] * npc + np.arange(nexc)[None, :]).ravel()
    inh = (cols[:, None] * npc + np.arange(nexc, npc)[None, :]).ravel()
    return np.unique(np.concatenate([exc, inh]))


@dataclasses.dataclass
class ChunkSynapses:
    """One chunk's incoming synapses, canonical (tgt_gid, src_gid, j) order."""

    src_gid: np.ndarray       # [e] int64
    tgt_gid: np.ndarray       # [e] int64
    tgt_local: np.ndarray     # [e] int32 (shard-local target index)
    j: np.ndarray             # [e] int32
    delay: np.ndarray         # [e] int32
    weight0: np.ndarray       # [e] float32
    plastic: np.ndarray       # [e] bool


def _chunk_synapses(cfg: GridConfig, eng: EngineConfig, shard: int,
                    cand: np.ndarray, lo: int, hi: int) -> ChunkSynapses:
    """Host reference for one chunk: the [lo, hi) target-local-index slice of
    the shard's canonical synapse list (bit-equal to `build_shard`'s slice)."""
    gids = topology.owned_gids(cfg, shard, eng.n_shards, eng.placement)
    fwd = forward_synapses(cfg, cand)
    tgt = fwd.tgt_gid.ravel()
    owner = topology.owner_of(cfg, tgt, eng.n_shards, eng.placement)
    keep = owner == shard
    src = np.repeat(cand, cfg.synapses_per_neuron)[keep]
    j = np.tile(np.arange(cfg.synapses_per_neuron, dtype=np.int64),
                cand.shape[0])[keep]
    tgt = tgt[keep]
    delay = fwd.delay.ravel()[keep]
    weight = fwd.weight.ravel()[keep]
    plastic = fwd.plastic.ravel()[keep]
    tl = np.searchsorted(gids, tgt)
    assert np.array_equal(gids[tl], tgt), "target must be owned"
    sel = (tl >= lo) & (tl < hi)
    src, j, tgt, tl, delay, weight, plastic = (
        a[sel] for a in (src, j, tgt, tl, delay, weight, plastic))
    order = np.lexsort((j, src, tgt))
    return ChunkSynapses(
        src_gid=src[order], tgt_gid=tgt[order],
        tgt_local=tl[order].astype(np.int32), j=j[order].astype(np.int32),
        delay=delay[order].astype(np.int32), weight0=weight[order],
        plastic=plastic[order])


@dataclasses.dataclass
class StreamedShard:
    """Streamed-mode shard metadata: O(chunk) synapse residency.

    Only `weight0` is O(E) (it seeds the weight STATE, which is O(E) in
    either mode); the synapse TABLES are never held whole — `cand` rows name
    which source-table entries feed each chunk and `e_start` locates each
    chunk's slice of the canonical synapse order.
    """

    src_gid: np.ndarray       # [S_cap] int64 (pad -1) — full candidate table
    n_src: int
    cand: np.ndarray          # [n_chunks, C_cap] int32 src_gid rows (pad -1)
    e_start: np.ndarray       # [n_chunks + 1] int64 canonical chunk offsets
    weight0: np.ndarray       # [n_valid] float32, canonical order (unpadded)
    n_valid: int
    chunk_cols: int
    q: int
    n_chunks: int


def build_streamed_shard(cfg: GridConfig, eng: EngineConfig, shard: int,
                         chunk_cols: int) -> StreamedShard:
    """Build one shard's streamed metadata, one chunk resident at a time."""
    src_table = candidate_sources(cfg, eng, shard)
    n_cap, q, n_chunks = stream_geometry(cfg, eng, chunk_cols)
    cands: List[np.ndarray] = []
    counts: List[int] = []
    w0: List[np.ndarray] = []
    for c in range(n_chunks):
        cand = chunk_candidates(cfg, eng, shard, c * q, (c + 1) * q)
        sidx = np.searchsorted(src_table, cand)
        assert np.array_equal(src_table[sidx], cand), \
            "chunk candidates must be a subset of the shard source table"
        syn = _chunk_synapses(cfg, eng, shard, cand, c * q, (c + 1) * q)
        cands.append(sidx.astype(np.int32))
        counts.append(int(syn.src_gid.shape[0]))
        w0.append(syn.weight0)
    c_cap = _round_up(max((c.shape[0] for c in cands), default=1), 8)
    cand_p = np.full((n_chunks, c_cap), -1, dtype=np.int32)
    for c, sidx in enumerate(cands):
        cand_p[c, :sidx.shape[0]] = sidx
    e_start = np.concatenate(
        [[0], np.cumsum(np.asarray(counts, dtype=np.int64))])
    weight0 = (np.concatenate(w0) if w0
               else np.empty((0,), dtype=np.float32))
    S = src_table.shape[0]
    s_cap = _round_up(S, 8)
    src_gid_p = np.full((s_cap,), -1, dtype=np.int64)
    src_gid_p[:S] = src_table
    return StreamedShard(
        src_gid=src_gid_p, n_src=S, cand=cand_p,
        e_start=e_start, weight0=weight0.astype(np.float32),
        n_valid=int(e_start[-1]), chunk_cols=chunk_cols, q=q,
        n_chunks=n_chunks)


def build_all_streamed(cfg: GridConfig, eng: EngineConfig, chunk_cols: int
                       ) -> List[StreamedShard]:
    """Build every shard with uniform (max) caps, for stacking."""
    raw = [build_streamed_shard(cfg, eng, h, chunk_cols)
           for h in range(eng.n_shards)]
    s_cap = max(r.src_gid.shape[0] for r in raw)
    c_cap = max(r.cand.shape[1] for r in raw)
    out = []
    for r in raw:
        src_gid = np.full((s_cap,), -1, dtype=np.int64)
        src_gid[:r.n_src] = r.src_gid[:r.n_src]
        cand = np.full((r.n_chunks, c_cap), -1, dtype=np.int32)
        cand[:, :r.cand.shape[1]] = r.cand
        out.append(dataclasses.replace(r, src_gid=src_gid, cand=cand))
    return out


def streamed_shard_keys(cfg: GridConfig, eng: EngineConfig, shard: int,
                        chunk_cols: int
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(tgt_gid, src_gid, j) int64 arrays in canonical order, chunk-wise.

    Used by checkpointing to key each weight-state position without ever
    holding more than one chunk's synapse tables live.
    """
    _, q, n_chunks = stream_geometry(cfg, eng, chunk_cols)
    tgts, srcs, js = [], [], []
    for c in range(n_chunks):
        cand = chunk_candidates(cfg, eng, shard, c * q, (c + 1) * q)
        syn = _chunk_synapses(cfg, eng, shard, cand, c * q, (c + 1) * q)
        tgts.append(syn.tgt_gid)
        srcs.append(syn.src_gid)
        js.append(syn.j.astype(np.int64))
    empty = np.empty((0,), dtype=np.int64)
    return (np.concatenate(tgts) if tgts else empty,
            np.concatenate(srcs) if srcs else empty,
            np.concatenate(js) if js else empty)
