"""Pluggable lateral-connectivity profiles (DESIGN.md §Connectivity
profiles).

The paper fixes synaptic projection to the first/second/third Chebyshev
neighbour rings of a source column (76/12/8/4%).  The follow-up study on
the same simulator (Pastorelli et al., arXiv:1803.08833) replaces that
kernel with Gaussian / exponential distance decay and shows the
compute/communication balance shifts with connectivity reach.  This
module makes the kernel a first-class, pluggable object so the repo can
measure that trade-off instead of hard-coding one point of it.

A `ConnectivityProfile` is *one* thing: a vector of unnormalized target
masses per Chebyshev ring of the column grid,

    ring_masses()[r]  ~  P(forward synapse targets a column at ring r),

plus its derived `reach()` (the largest ring with nonzero mass).  Every
profile draws from the SAME four counter-based `splitmix64` streams as
the paper kernel (`connectivity.forward_synapses`): lane 0 picks the
ring from the cumulative mass fractions, lane 1 the member column within
the ring, lanes 2/3 the target neuron and delay.  Because the draws are
a pure function of (seed, source gid, slot), connectivity — and hence
the simulated raster — is independent of shard count, placement and
process count for EVERY profile, exactly as for the paper default
(`tests/test_profiles.py`, `tests/test_determinism_scaling.py`).

Out-degree stays fixed at M synapses per neuron for all profiles (the
engine's static shapes and the canonical synapse order depend on it);
"connection probability" is therefore the per-synapse target-column
distribution, the fixed-fan-out formulation of the decaying kernels.

`reach()` is the single number the distribution layer needs: the halo of
a shard is the union of `reach`-ring neighbourhoods of its columns
(`topology.shard_halo_columns`), from which `distributed.halo_offsets`
derives the static shard-to-shard exchange schedule.  A wider kernel
widens the halo and the exchange cost; the `connectivity_sweep` bench
suite measures exactly that.

Profile specs (CLI `--profile`, `GridConfig.connectivity`):

    ring3                        paper default (bit-identical legacy kernel)
    ring1 / ring2 / ring5 ...    variable-radius ring kernel
    ring:max_ring=5              same, explicit form
    gaussian:sigma=1.5           ring mass ~ ring_size * exp(-r^2 / 2 sigma^2)
    gaussian:sigma=1.5,cutoff=3  truncated at reach = ceil(cutoff * sigma)
    exponential:lambda=1.0       ring mass ~ ring_size * exp(-r / lambda)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Tuple

import numpy as np

#: The paper's self / 1st / 2nd / 3rd-ring target fractions (main text).
PAPER_RING_FRACTIONS: Tuple[float, ...] = (0.76, 0.12, 0.08, 0.04)

#: Spec string of the default profile (the paper's exact kernel).
DEFAULT_SPEC = "ring3"


def ring_size(r: int) -> int:
    """Number of columns at Chebyshev distance exactly `r` (8r, or 1 at 0)."""
    return 1 if r == 0 else 8 * r


@dataclasses.dataclass(frozen=True)
class ConnectivityProfile:
    """Base class: a lateral kernel as per-ring target masses.

    Subclasses implement `ring_masses` and `spec`; everything else
    (`reach`, normalized cumulative fractions, offset tables) derives
    from those.  Instances are frozen dataclasses — hashable, comparable,
    and safe to embed in `SimSpec`-adjacent static config.
    """

    def ring_masses(self) -> Tuple[float, ...]:
        """Unnormalized target mass per ring, index 0..reach."""
        raise NotImplementedError

    def reach(self) -> int:
        """Largest Chebyshev ring this profile can target — the halo depth
        the distribution layer must provision (DESIGN.md §Connectivity
        profiles)."""
        return len(self.ring_masses()) - 1

    def spec(self) -> str:
        """Canonical spec string; `parse(p.spec())` round-trips."""
        raise NotImplementedError

    def cum_fractions(self) -> np.ndarray:
        """Normalized cumulative ring fractions, float64 [reach + 1].

        This is the exact quantity the legacy kernel computed from
        `GridConfig.ring_fractions` (cumsum then divide by the last
        entry), so the paper profile reproduces the historical draws
        bit-for-bit."""
        fr = np.cumsum(np.asarray(self.ring_masses(), dtype=np.float64))
        return fr / fr[-1]


@dataclasses.dataclass(frozen=True)
class RingProfile(ConnectivityProfile):
    """Uniform-within-ring kernel with explicit per-ring fractions.

    `RingProfile()` is the paper's exact 3-ring kernel; `with_radius(R)`
    derives a variable-radius variant from the paper fractions
    (truncate + implicit renormalization for R < 3, extend by halving the
    last fraction for R > 3 — and R == 3 returns the paper fractions
    unchanged, keeping `ring:max_ring=3` bit-identical to `ring3`).
    """

    fractions: Tuple[float, ...] = PAPER_RING_FRACTIONS

    def ring_masses(self) -> Tuple[float, ...]:
        return self.fractions

    def spec(self) -> str:
        if self.fractions == PAPER_RING_FRACTIONS:
            return "ring3"
        return f"ring:max_ring={len(self.fractions) - 1}"

    @classmethod
    def with_radius(cls, max_ring: int,
                    base: Tuple[float, ...] = PAPER_RING_FRACTIONS
                    ) -> "RingProfile":
        if max_ring < 0:
            raise ValueError(f"max_ring must be >= 0, got {max_ring}")
        fr = list(base[:max_ring + 1])
        while len(fr) < max_ring + 1:
            fr.append(fr[-1] / 2.0)
        return cls(fractions=tuple(fr))


@dataclasses.dataclass(frozen=True)
class GaussianProfile(ConnectivityProfile):
    """Gaussian distance decay (arXiv:1803.08833): per-column target
    probability ~ exp(-r² / 2σ²), truncated at reach = ceil(cutoff·σ).

    Ring mass multiplies the per-column decay by the ring population
    (8r columns at ring r), so the kernel decays per *column*, not per
    ring — most synapses land in the near rings but the mode moves
    outward with σ, as in the reference study.
    """

    sigma: float = 1.5
    cutoff: float = 3.0

    def reach(self) -> int:
        return max(1, int(math.ceil(self.cutoff * self.sigma)))

    def ring_masses(self) -> Tuple[float, ...]:
        s2 = 2.0 * self.sigma * self.sigma
        return tuple(ring_size(r) * math.exp(-(r * r) / s2)
                     for r in range(self.reach() + 1))

    def spec(self) -> str:
        return f"gaussian:sigma={self.sigma:g},cutoff={self.cutoff:g}"


@dataclasses.dataclass(frozen=True)
class ExponentialProfile(ConnectivityProfile):
    """Exponential distance decay (arXiv:1803.08833): per-column target
    probability ~ exp(-r / λ), truncated at reach = ceil(cutoff·λ)."""

    lam: float = 1.0
    cutoff: float = 5.0

    def reach(self) -> int:
        return max(1, int(math.ceil(self.cutoff * self.lam)))

    def ring_masses(self) -> Tuple[float, ...]:
        return tuple(ring_size(r) * math.exp(-r / self.lam)
                     for r in range(self.reach() + 1))

    def spec(self) -> str:
        return f"exponential:lambda={self.lam:g},cutoff={self.cutoff:g}"


# ----------------------------------------------------------------------------
# spec parsing
# ----------------------------------------------------------------------------

_ALIASES = {"paper": "ring3", "default": "ring3", "exp": "exponential"}


def _kwargs(body: str) -> dict:
    out = {}
    for item in body.split(","):
        if not item:
            continue
        k, _, v = item.partition("=")
        if not _:
            raise ValueError(f"malformed profile parameter {item!r} "
                             f"(expected key=value)")
        out[k.strip()] = v.strip()
    return out


def parse(spec: str,
          ring_fractions: Tuple[float, ...] = PAPER_RING_FRACTIONS
          ) -> ConnectivityProfile:
    """Parse a profile spec string (see module docstring grammar).

    `ring_fractions` supplies the paper fractions for the ring family so
    `GridConfig.ring_fractions` overrides keep working (`from_config`).
    """
    s = spec.strip().lower()
    name, _, body = s.partition(":")
    name = _ALIASES.get(name, name)

    if name.startswith("ring") and name[4:].isdigit():
        radius = int(name[4:])
        if body:
            raise ValueError(f"ring{radius} takes no parameters: {spec!r}")
        if radius == len(ring_fractions) - 1:
            return RingProfile(fractions=tuple(ring_fractions))
        return RingProfile.with_radius(radius, tuple(ring_fractions))

    kw = _kwargs(body)
    try:
        if name == "ring":
            radius = int(kw.pop("max_ring"))
            _reject_extra(kw, spec)
            if radius == len(ring_fractions) - 1:
                return RingProfile(fractions=tuple(ring_fractions))
            return RingProfile.with_radius(radius, tuple(ring_fractions))
        if name == "gaussian":
            sigma = float(kw.pop("sigma", 1.5))
            cutoff = float(kw.pop("cutoff", 3.0))
            _reject_extra(kw, spec)
            if sigma <= 0 or cutoff <= 0:
                raise ValueError("sigma and cutoff must be > 0")
            return GaussianProfile(sigma=sigma, cutoff=cutoff)
        if name == "exponential":
            if "lambda" in kw and "lam" in kw:
                raise ValueError(f"profile {spec!r}: give lambda= or lam=, "
                                 f"not both")
            if "lambda" in kw:
                lam = float(kw.pop("lambda"))
            else:
                lam = float(kw.pop("lam", 1.0))
            cutoff = float(kw.pop("cutoff", 5.0))
            _reject_extra(kw, spec)
            if lam <= 0 or cutoff <= 0:
                raise ValueError("lambda and cutoff must be > 0")
            return ExponentialProfile(lam=lam, cutoff=cutoff)
    except KeyError as e:
        raise ValueError(f"profile {spec!r} missing parameter {e}") from None
    raise ValueError(
        f"unknown connectivity profile {spec!r}; expected one of "
        f"ring3 | ringN | ring:max_ring=N | gaussian:sigma=S[,cutoff=C] "
        f"| exponential:lambda=L[,cutoff=C]")


def _reject_extra(kw: dict, spec: str) -> None:
    if kw:
        raise ValueError(f"unknown parameters {sorted(kw)} in profile "
                         f"{spec!r}")


def from_config(cfg) -> ConnectivityProfile:
    """The profile a `GridConfig` selects (`cfg.connectivity` spec string,
    with `cfg.ring_fractions` feeding the ring family)."""
    return parse(cfg.connectivity, tuple(cfg.ring_fractions))


# ----------------------------------------------------------------------------
# flattened ring-offset tables, shared by connectivity generation
# ----------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def offset_tables(reach: int):
    """(off [K, 2] int64, start [reach + 2] int64): the (dx, dy) offsets of
    rings 0..reach flattened in canonical order, and per-ring start
    indices.  Cached per reach — identical tables for identical reach, so
    repeated builds don't re-enumerate offsets."""
    from . import topology
    off = np.concatenate([np.asarray(topology.ring_offsets(r),
                                     dtype=np.int64).reshape(-1, 2)
                          for r in range(reach + 1)])
    start = np.concatenate([[0], np.cumsum([ring_size(r)
                                            for r in range(reach + 1)])]
                           ).astype(np.int64)
    return off, start
