"""Address Event Representation (AER) spike packing.

Paper: "we send 'axonal spike' messages that carry the identifiers of spiking
neurons and are packed in groups that have the same spike emission time and
the same target process".

SPMD adaptation (DESIGN.md §2): messages are fixed-capacity int32 buffers of
spiking gids, ascending, padded with INVALID; slot 0 of the companion lane is
the spike count (the paper's single-word counter phase rides inside the same
buffer instead of a separate rendezvous round-trip).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

INVALID = jnp.int32(2 ** 31 - 1)


def pack(spiked: jnp.ndarray, gid: jnp.ndarray, capacity: int
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(spiked[N] bool, gid[N]) -> (ids[capacity] ascending, count).

    Padding entries are INVALID (sorted to the tail).  capacity >= N always
    holds when capacity == N (every neuron can spike at most once per step,
    the refractory reset guarantees it).
    """
    ids = jnp.where(spiked & (gid >= 0), gid.astype(jnp.int32), INVALID)
    ids = jnp.sort(ids)
    count = (ids != INVALID).sum(dtype=jnp.int32)
    return ids[:capacity], count


def match_sources(ids: jnp.ndarray, src_gid: jnp.ndarray) -> jnp.ndarray:
    """Mark which local sources appear in a received AER buffer.

    ids: [C] ascending spike gids (INVALID padded);
    src_gid: [S] ascending local source table (-1 padded at *front* is not
    allowed; -1 pads are at arbitrary positions masked by >= 0).
    Returns [S] bool.
    """
    pos = jnp.searchsorted(ids, src_gid.astype(jnp.int32))
    pos = jnp.clip(pos, 0, ids.shape[0] - 1)
    hit = ids[pos] == src_gid
    return hit & (src_gid >= 0)
