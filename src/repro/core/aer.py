"""Address Event Representation (AER) spike packing.

Paper: "we send 'axonal spike' messages that carry the identifiers of spiking
neurons and are packed in groups that have the same spike emission time and
the same target process".

SPMD adaptation (DESIGN.md §2): messages are fixed-capacity int32 buffers of
spiking gids, ascending, padded with INVALID; slot 0 of the companion lane is
the spike count (the paper's single-word counter phase rides inside the same
buffer instead of a separate rendezvous round-trip).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

INVALID = jnp.int32(2 ** 31 - 1)


def compact_indices(mask: jnp.ndarray, cap: int, fill: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-free compaction: ascending indices of True entries of `mask`,
    padded with `fill` to static length `cap`.  Rank = exclusive cumsum of
    the mask, so the scatter preserves index order — identical output to
    `jnp.sort(where(mask, iota, fill))[:cap]` at O(N) instead of
    O(N log N).  The single compaction primitive behind both the AER wire
    (`pack`) and the event backend's spike/source lists
    (`event_engine`).  Returns (ids[cap], n_dropped)."""
    n = mask.shape[0]
    rank = jnp.cumsum(mask) - 1                        # rank among selected
    idx = jnp.where(mask & (rank < cap), rank, cap)    # cap == oob -> drop
    ids = jnp.full((cap,), fill, jnp.int32).at[idx].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    dropped = jnp.maximum(0, mask.sum(dtype=jnp.int32) - cap)
    return ids, dropped


def pack(spiked: jnp.ndarray, gid: jnp.ndarray, capacity: int
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(spiked[N] bool, gid[N]) -> (ids[capacity] ascending, count).

    Padding entries are INVALID (at the tail).  capacity >= N always holds
    when capacity == N (every neuron can spike at most once per step, the
    refractory reset guarantees it).

    The per-shard gid table is ascending by local index for every
    placement (`topology.owned_gids` sorts), so the order-preserving
    `compact_indices` keeps the ascending order `match_sources`'
    searchsorted needs.
    """
    n = gid.shape[0]
    sel = spiked & (gid >= 0)
    idx, dropped = compact_indices(sel, capacity, fill=n)
    ids = jnp.where(idx < n, gid[jnp.minimum(idx, n - 1)].astype(jnp.int32),
                    INVALID)
    count = sel.sum(dtype=jnp.int32) - dropped
    return ids, count


def match_sources(ids: jnp.ndarray, src_gid: jnp.ndarray) -> jnp.ndarray:
    """Mark which local sources appear in a received AER buffer.

    ids: [C] ascending spike gids (INVALID padded);
    src_gid: [S] ascending local source table (-1 padded at *front* is not
    allowed; -1 pads are at arbitrary positions masked by >= 0).
    Returns [S] bool.
    """
    pos = jnp.searchsorted(ids, src_gid.astype(jnp.int32))
    pos = jnp.clip(pos, 0, ids.shape[0] - 1)
    hit = ids[pos] == src_gid
    return hit & (src_gid >= 0)
