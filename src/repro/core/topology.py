"""Column-grid topology: neighbour rings, periodic wrap, gid numbering,
shard ownership (block / scatter placements).

Global neuron id (gid) layout:  gid = column_id * neurons_per_column + n,
column_id = cy * grid_x + cx  (row-major), n in [0, neurons_per_column);
neuron n is excitatory iff n < n_exc_per_column.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .params import GridConfig


def ring_offsets(ring: int) -> List[Tuple[int, int]]:
    """(dx, dy) offsets at Chebyshev distance == ring, deterministic order."""
    if ring == 0:
        return [(0, 0)]
    out = []
    for dy in range(-ring, ring + 1):
        for dx in range(-ring, ring + 1):
            if max(abs(dx), abs(dy)) == ring:
                out.append((dx, dy))
    return out


RING_SIZES = (1, 8, 16, 24)  # Chebyshev rings 0..3


def column_coords(cfg: GridConfig, col: np.ndarray):
    cx = col % cfg.grid_x
    cy = col // cfg.grid_x
    return cx, cy


def wrap_column(cfg: GridConfig, cx: np.ndarray, cy: np.ndarray) -> np.ndarray:
    """Periodic boundary conditions (paper: used for all scaling runs)."""
    return (cy % cfg.grid_y) * cfg.grid_x + (cx % cfg.grid_x)


def profile_reach(cfg: GridConfig) -> int:
    """Halo depth of the connectivity profile `cfg` selects (the largest
    Chebyshev ring a forward synapse can target — `profiles.reach()`)."""
    from . import profiles
    return profiles.from_config(cfg).reach()


def neighbour_columns(cfg: GridConfig, col: int,
                      max_ring: Optional[int] = None) -> np.ndarray:
    """Unique columns within `max_ring` Chebyshev rings of `col` (periodic).

    `max_ring=None` derives the depth from the connectivity profile the
    config selects (`profile_reach`) — the default for every caller that
    provisions halos.  Note that on small grids periodic wrap can alias
    several offsets onto the same column (the paper's single-column case
    projects everything to itself); the returned array is deduplicated.
    """
    if max_ring is None:
        max_ring = profile_reach(cfg)
    cx, cy = column_coords(cfg, np.asarray(col))
    cols = []
    for r in range(max_ring + 1):
        for dx, dy in ring_offsets(r):
            cols.append(wrap_column(cfg, cx + dx, cy + dy))
    return np.unique(np.asarray(cols, dtype=np.int64))


def gid_column(cfg: GridConfig, gid: np.ndarray) -> np.ndarray:
    return gid // cfg.neurons_per_column


def gid_local_n(cfg: GridConfig, gid: np.ndarray) -> np.ndarray:
    return gid % cfg.neurons_per_column


def is_excitatory(cfg: GridConfig, gid: np.ndarray) -> np.ndarray:
    return gid_local_n(cfg, gid) < cfg.n_exc_per_column


# ----------------------------------------------------------------------------
# Shard ownership.  The key property (paper: "global and local identities of
# neurons can be easily computed using the local identifiers of processes and
# neurons") is that ownership is a pure function of (gid, H, placement).
# ----------------------------------------------------------------------------


def shard_bounds_block(n_neurons: int, n_shards: int) -> np.ndarray:
    """Start offsets of each block shard; fair share N/H (paper wording)."""
    base, rem = divmod(n_neurons, n_shards)
    sizes = np.full(n_shards, base, dtype=np.int64)
    sizes[:rem] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


def owner_of(cfg: GridConfig, gid: np.ndarray, n_shards: int, placement: str
             ) -> np.ndarray:
    gid = np.asarray(gid, dtype=np.int64)
    if placement == "block":
        bounds = shard_bounds_block(cfg.n_neurons, n_shards)
        return np.searchsorted(bounds, gid, side="right") - 1
    elif placement == "scatter":
        return gid % n_shards
    raise ValueError(f"unknown placement {placement!r}")


def owned_gids(cfg: GridConfig, shard: int, n_shards: int, placement: str
               ) -> np.ndarray:
    """The gids owned by `shard`, in canonical (ascending gid) order."""
    if placement == "block":
        bounds = shard_bounds_block(cfg.n_neurons, n_shards)
        return np.arange(bounds[shard], bounds[shard + 1], dtype=np.int64)
    elif placement == "scatter":
        return np.arange(shard, cfg.n_neurons, n_shards, dtype=np.int64)
    raise ValueError(f"unknown placement {placement!r}")


def local_size(cfg: GridConfig, shard: int, n_shards: int, placement: str) -> int:
    return int(owned_gids(cfg, shard, n_shards, placement).shape[0])


def max_local_size(cfg: GridConfig, n_shards: int, placement: str) -> int:
    """Static per-shard capacity (same for all shards; pads the remainder)."""
    return -(-cfg.n_neurons // n_shards)


def shard_halo_columns(cfg: GridConfig, shard: int, n_shards: int,
                       placement: str,
                       max_ring: Optional[int] = None) -> np.ndarray:
    """All columns whose neurons may project onto this shard's neurons.

    == union of `reach`-ring neighbourhoods of the columns this shard owns
    neurons in, where reach comes from the connectivity profile when
    `max_ring` is None (profile-derived halo depth, DESIGN.md
    §Connectivity profiles).  Excitatory kernels are symmetric (ring r of
    c contains c' iff ring r of c' contains c), so the same union bounds
    incoming sources; inhibitory sources are intra-column, already
    included.
    """
    if max_ring is None:
        max_ring = profile_reach(cfg)
    gids = owned_gids(cfg, shard, n_shards, placement)
    my_cols = np.unique(gid_column(cfg, gids))
    halos = [neighbour_columns(cfg, int(c), max_ring) for c in my_cols]
    return np.unique(np.concatenate(halos))
