"""Model parameters for the DPSNN-STDP benchmark (Paolucci et al., 2013).

All constants default to the values stated in the paper:
  - Izhikevich RS excitatory (a=0.02, b=0.2, c=-65, d=8), FS inhibitory
    (a=0.1, b=0.2, c=-65, d=2), v_peak = 30 mV, 80/20 E/I mix.
  - M = 200 forward synapses per neuron, delays 1..5 ms (inhibitory: 1 ms).
  - 2-D grid of 1000-neuron columns; excitatory ring fractions
    76% / 12% / 8% / 4% (self / 1st / 2nd / 3rd Chebyshev neighbours).
  - Nearest-spike additive STDP (Song et al. 2000).

The paper writes the membrane equation in a shorthand (dv/dt = v^2 - u + I); we
use the canonical Izhikevich (2003) form it cites, which is the one its RS/FS
parameter values belong to:  dv/dt = 0.04 v^2 + 5 v + 140 - u + I.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class IzhikevichParams:
    """Per-population Izhikevich parameters (excitatory RS / inhibitory FS)."""

    a_exc: float = 0.02
    b_exc: float = 0.2
    c_exc: float = -65.0
    d_exc: float = 8.0
    a_inh: float = 0.1
    b_inh: float = 0.2
    c_inh: float = -65.0
    d_inh: float = 2.0
    v_peak: float = 30.0
    v_init: float = -65.0
    # dt in ms; the membrane update uses two half-steps (Izhikevich 2003 code).
    dt: float = 1.0
    v_substeps: int = 2


@dataclasses.dataclass(frozen=True)
class StdpParams:
    """Nearest-spike additive STDP (Song et al., 2000).

    dt_pairing = t_post - (t_pre + d_axon)
      dt >= 0:  dW = +a_plus  * exp(-dt / tau_plus)    (LTP)
      dt <  0:  dW = -a_minus * exp(+dt / tau_minus)   (LTD)
    Weights of plastic (excitatory) synapses clip to [w_min, w_max].
    Inhibitory synapses are non-plastic.
    """

    a_plus: float = 0.1
    a_minus: float = 0.12
    tau_plus: float = 20.0
    tau_minus: float = 20.0
    w_min: float = 0.0
    w_max: float = 10.0


@dataclasses.dataclass(frozen=True)
class GridConfig:
    """A bidimensional grid of neural columns (paper Fig. 2-1 / Table 1)."""

    grid_x: int = 1
    grid_y: int = 1
    neurons_per_column: int = 1000
    exc_fraction: float = 0.8
    synapses_per_neuron: int = 200          # M, fixed for all neurons
    delay_min: int = 1                      # ms == steps at dt=1
    delay_max: int = 5
    # self / 1st / 2nd / 3rd Chebyshev neighbour ring target fractions.
    # (Main text values; the figure caption's 3/2/1% per-column variant is
    # inconsistent with the text and is not used.)
    ring_fractions: Tuple[float, float, float, float] = (0.76, 0.12, 0.08, 0.04)
    # Lateral-connectivity profile spec (core.profiles): "ring3" is the
    # paper's exact kernel above (bit-identical legacy behaviour); other
    # specs — "ring:max_ring=R", "gaussian:sigma=S", "exponential:lambda=L"
    # — swap the kernel and with it the halo reach the distribution layer
    # provisions.  The ring family reads `ring_fractions`.
    connectivity: str = "ring3"
    # The paper sets initial weights "to a high strength" without giving the
    # value.  5.6 calibrates the initial-activity band to the paper's
    # Table 1 across all geometries (1x1: ~37, 2x2: 13.5, 4x4: 28.4,
    # 8x4: 24.6, 8x8: 27.0 Hz vs the paper's 20-48 Hz band); 6.0 tips
    # multi-column grids into re-entrant runaway (~480 Hz) and 5.75 leaves
    # a 2x2 outlier — the transition is steep and chaotic
    # (EXPERIMENTS.md §Reproduction calibration note).
    w_exc_init: float = 5.6
    w_inh_init: float = -5.0
    # thalamic stimulus: number of events per ms per column, amplitude in mV
    stim_events_per_ms_per_column: int = 1
    stim_amplitude: float = 20.0
    seed: int = 2013

    @property
    def n_columns(self) -> int:
        return self.grid_x * self.grid_y

    @property
    def n_neurons(self) -> int:
        return self.n_columns * self.neurons_per_column

    @property
    def n_exc_per_column(self) -> int:
        return int(round(self.neurons_per_column * self.exc_fraction))

    @property
    def n_synapses(self) -> int:
        return self.n_neurons * self.synapses_per_neuron

    @property
    def n_delay_slots(self) -> int:
        return self.delay_max + 1  # ring needs delay_max+1 slots for mod logic


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Execution knobs (distribution layout, backends)."""

    n_shards: int = 1
    # 'block': shard h owns a contiguous gid range (may split columns, like
    #          the paper's 1/8-column processes).
    # 'scatter': gid -> shard (gid % H); the paper's Discussion-section
    #          load-balancing proposal (neurons of one column spread over
    #          many processes).
    placement: str = "block"
    # spike exchange: 'allgather' (global mask), 'halo' (ppermute over the
    # static 3rd-neighbour shard halo; paper's sparse two-phase analogue),
    # or 'hier' (two-level: intra-process all_gather over the shards each
    # process owns, then neighbourhood-only inter-process ppermute at
    # whole-group stride — the paper's cluster topology made explicit).
    exchange: str = "allgather"
    # exchange issue order: 'sync' runs phase A -> exchange -> phase B in
    # program order; 'pipelined' issues the exchange for step t right after
    # the dynamics half of phase A(t) so it overlaps the plasticity half,
    # with deferred delivery B(t) double-buffered into the next loop
    # iteration.  Both schedules execute the identical op sequence per
    # step, so rasters AND weights are bit-identical (Table 1 invariant).
    exchange_schedule: str = "sync"
    # current/STDP delivery backend: 'dense' (O(E) masked vector ops,
    # TPU-idiomatic, bit-reproducible) or 'event' (O(spikes x fan) gathered
    # rows; Pallas kernel target).
    delivery: str = "dense"
    # synapse-table residency: 'materialized' stores every shard's incoming
    # synapse tables for the whole run (O(E) live bytes per shard);
    # 'streamed:chunk=<K>' keeps only per-chunk tables live — each jitted
    # step scans over fixed chunks of K target columns and regenerates that
    # chunk's tables from the same counter-based splitmix64 draw lanes, so
    # live table bytes are O(K * neighbourhood * M) regardless of grid size
    # while rasters AND weights stay bit-identical to materialized mode
    # (weight state is carried in the same canonical synapse order).
    # Streamed requires delivery='dense' (the event backend's row tables
    # are an O(E) synapse-id permutation, contradicting O(chunk) residency).
    connectivity: str = "materialized"
    use_pallas: bool = False


DEFAULT_IZH = IzhikevichParams()
DEFAULT_STDP = StdpParams()
