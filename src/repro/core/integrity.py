"""Checkpoint integrity: sha256 payload digests, verified reads, and
corrupt-tolerant discovery.

Deliberately jax-free (stdlib + numpy only) so the cluster supervisor —
a parent process that must never initialize jax devices
(`repro.cluster.local`) — can validate checkpoints before deciding which
epoch to restart a gang from.  `repro.core.checkpoint` routes every
write and load through here, so ALL checkpoint paths (the SNN launcher,
the cluster worker's periodic epochs, simserve evictions) share one
integrity contract:

  * writes are atomic (tmp + `os.replace`) and embed a sha256 digest of
    the payload arrays as an extra npz member (`_SHA_KEY`);
  * loads re-derive the digest and raise `CheckpointCorrupt` — never
    deserialize garbage — on a truncated file, an undecodable zip, or a
    digest mismatch;
  * `latest_valid` walks a checkpoint directory newest-first and returns
    the newest checkpoint that VERIFIES, falling back past corrupted
    epochs (the supervisor's restart-from-last-good-epoch primitive).

Checkpoints written before this module carry no digest member; they load
with verification skipped (the structural zip checks still apply) so old
on-disk states stay readable.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
import zipfile
import zlib
from typing import Dict, Optional

import numpy as np

#: npz member holding the hex digest; excluded from its own digest.
_SHA_KEY = "payload_sha256"


class CheckpointCorrupt(RuntimeError):
    """A checkpoint file is truncated, undecodable, or fails its sha256
    payload digest.  Callers fall back to an earlier epoch (supervisor)
    or surface the path and reason (everything else)."""

    def __init__(self, path: str, reason: str):
        self.path = path
        self.reason = reason
        super().__init__(f"corrupt checkpoint {path}: {reason}")


def payload_digest(arrays: Dict[str, np.ndarray]) -> str:
    """Canonical sha256 over named arrays: sorted by name, each hashed as
    (name, dtype, shape, raw bytes).  np.savez round-trips dtype/shape
    exactly, so the digest recomputed from a loaded npz matches the one
    computed at save time iff every payload byte survived."""
    h = hashlib.sha256()
    for name in sorted(arrays):
        if name == _SHA_KEY:
            continue
        a = np.asarray(arrays[name])
        h.update(name.encode())
        h.update(str(a.dtype.str).encode())
        h.update(json.dumps(list(a.shape)).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def write_verified(path: str, arrays: Dict[str, np.ndarray]) -> str:
    """Atomic npz write with the payload digest embedded; returns `path`.

    tmp + `os.replace` in the destination directory, so a crash at ANY
    point leaves either the previous complete file or none — no torn
    writes are ever visible under the final name."""
    digest = payload_digest(arrays)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, **arrays,
                                **{_SHA_KEY: np.array(digest)})
        os.replace(tmp, path)                      # atomic
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def read_verified(path: str) -> Dict[str, np.ndarray]:
    """Load an npz and verify its embedded digest.

    Raises `CheckpointCorrupt` on truncation (bad zip / short reads), on any
    member that fails to decompress, and on a digest mismatch.  Files
    written before digests existed (no `_SHA_KEY` member) load with a
    structural check only."""
    try:
        with np.load(path, allow_pickle=False) as z:
            arrays = {name: z[name] for name in z.files}
    except FileNotFoundError:
        raise
    except (zipfile.BadZipFile, zlib.error, ValueError, OSError, EOFError,
            KeyError) as e:
        raise CheckpointCorrupt(path, f"unreadable npz ({e})") from e
    if _SHA_KEY in arrays:
        want = str(arrays.pop(_SHA_KEY))
        got = payload_digest(arrays)
        if got != want:
            raise CheckpointCorrupt(
                path, f"payload sha256 mismatch (stored {want[:16]}..., "
                      f"recomputed {got[:16]}...)")
    return arrays


def verify(path: str) -> bool:
    """True iff `path` reads back cleanly under `read_verified`."""
    try:
        read_verified(path)
        return True
    except CheckpointCorrupt:
        return False


_STEP_RE = re.compile(r"^(?P<prefix>.+?)(?P<step>\d+)\.npz$")


def checkpoint_steps(directory: str, prefix: str = "ckpt_"):
    """[(step, path)] for every `<prefix><step>.npz` in `directory`,
    ascending by step; [] when the directory is absent."""
    if not os.path.isdir(directory):
        return []
    out = []
    for f in os.listdir(directory):
        if f.startswith(prefix) and f.endswith(".npz"):
            try:
                step = int(f[len(prefix):-4])
            except ValueError:
                continue
            out.append((step, os.path.join(directory, f)))
    return sorted(out)


def latest_valid(directory: str, prefix: str = "ckpt_"
                 ) -> Optional[str]:
    """Newest checkpoint in `directory` that passes verification,
    falling back past corrupted epochs; None when no valid one exists.
    This is the supervisor's restart anchor: a corrupted newest epoch
    costs one epoch of replay, never the run."""
    for _, path in reversed(checkpoint_steps(directory, prefix)):
        if verify(path):
            return path
    return None
