"""Event-driven delivery backend (EngineConfig.delivery='event').

The paper's computational model is *event-driven for synaptic dynamics*:
per-step work scales with (spikes x fan-out), not with the total synapse
count E.  The dense backend (engine.py) is the TPU-idiomatic O(E) masked
formulation; this backend is the faithful event formulation under SPMD
static shapes:

  - the delay ring holds EVENT LISTS of synapse ids (not per-synapse
    flags): ev_ring [D, cap_ev] int32, ev_count [D];
  - spike emission gathers the spiking sources' padded forward rows and
    appends their synapse ids into the ring at slot (t + delay) mod D;
  - arrival processing touches only this step's event list: gather
    (w, tgt), scatter-add currents, LTD + last_arrival on that subset;
  - LTP gathers the spiking neurons' padded *incoming* rows.

Capacities are static (the AER trade again): cap_ev bounds events per
slot, spike compaction bounds spikes per step; overflow increments a
saturation counter (state.sat) instead of corrupting — exactly how the
fixed-capacity AER buffers degrade.  With default caps sized from the
paper's rate band (<=60 Hz) saturation never triggers in practice
(asserted in tests).

All compaction is sort-free: a cumsum over the selection mask assigns
each selected element its rank, and one scatter writes the compacted
list — O(N) work instead of the O(N log N) `jnp.sort` this backend used
to pay twice per step, and emission fills all D ring slots in a single
scatter (per-slot ranks from one cumsum over a [D, C*Kf] one-hot) where
it used to make D sequential `.at[].set` round-trips over the ring.

`phase_a`/`phase_b` are written against per-shard arrays, exactly like
`engine.phase_a/phase_b`: the same functions run under `vmap` (logical
shards, single device) and under `shard_map` with real collectives
(`core.distributed` dispatches on EngineConfig.delivery).  The exchange
wire is shared with the dense backend — its output `spiked_src` is
precisely phase_b's input — so halo and allgather schedules compose with
event delivery unchanged.

Equivalence: identical rasters + weights vs the dense backend
(tests/test_event_engine.py); fp32 summation order differs (scatter-add vs
canonical-order segment_sum), so weights match to ~1e-5 rather than
bit-exactly — documented backend trade.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import connectivity, engine, stimulus
from .aer import compact_indices as _compact
from .engine import NEG_TIME, ShardPlan, ShardState, SimSpec, StepTimings


class EventPlan(NamedTuple):
    fwd_rows: jnp.ndarray     # [S, Kf] int32 flat synapse ids (-1 pad)
    in_rows: jnp.ndarray      # [N, Ki] int32 flat synapse ids (-1 pad)


class EventState(NamedTuple):
    base: ShardState          # v, u, last_post, w, last_arr (arr_ring unused)
    ev_ring: jnp.ndarray      # [D, cap_ev] int32 (-1 pad)
    ev_count: jnp.ndarray     # [D] int32
    sat: jnp.ndarray          # [] int32 dropped events (overflow counter)


def default_caps(spec: SimSpec) -> Tuple[int, int]:
    """(c_post, c_src) spike-compaction capacities.

    Paper rates keep spikes/step far below N (<= ~6% at 60 Hz); N/2 and
    S/8 are comfortable headroom, the floors keep tiny test grids from
    degenerating.  Overflow is counted in `sat`, never corrupting."""
    n, s = spec.n_local, spec.s_cap
    return min(n, max(64, n // 2)), min(s, max(128, s // 8))


def _pad_rows(groups, n_rows: int, pad_to: int) -> np.ndarray:
    out = np.full((n_rows, pad_to), -1, dtype=np.int32)
    for r, ids in groups.items():
        out[r, :len(ids)] = ids
    return out


def build_event_plan(spec: SimSpec, cap_ev_factor: float = 0.25,
                     tables=None) -> Tuple[EventPlan, int]:
    """Build padded forward/incoming rows for every shard (stacked [H,...]).

    cap_ev: events per delay slot, sized as factor * E (paper rates keep
    arrivals per-ms far below E; 0.25 is ~5x headroom at 60 Hz).  `tables`
    optionally reuses connectivity tables already built for this (cfg,
    eng) — table construction is the most expensive host-side step."""
    if tables is None:
        tables = connectivity.build_all_shards(spec.cfg, spec.eng)
    fwd_all, in_all = [], []
    kf_max = ki_max = 1
    groups_fwd, groups_in = [], []
    for t in tables:
        e_valid = int(t.n_valid)
        fwd: dict = {}
        inr: dict = {}
        for e in range(e_valid):
            fwd.setdefault(int(t.src_idx[e]), []).append(e)
            inr.setdefault(int(t.tgt_local[e]), []).append(e)
        groups_fwd.append(fwd)
        groups_in.append(inr)
        if fwd:
            kf_max = max(kf_max, max(len(v) for v in fwd.values()))
        if inr:
            ki_max = max(ki_max, max(len(v) for v in inr.values()))

    S = tables[0].src_gid.shape[0]
    N = spec.n_local
    for fwd, inr in zip(groups_fwd, groups_in):
        fwd_all.append(_pad_rows(fwd, S, kf_max))
        in_all.append(_pad_rows(inr, N, ki_max))
    plan = EventPlan(fwd_rows=jnp.asarray(np.stack(fwd_all)),
                     in_rows=jnp.asarray(np.stack(in_all)))
    cap_ev = int(spec.e_cap * cap_ev_factor)
    cap_ev = max(256, -(-cap_ev // 128) * 128)
    return plan, cap_ev


def init_event_state(spec: SimSpec, base: ShardState, cap_ev: int
                     ) -> EventState:
    H = base.v.shape[0]
    D = spec.cfg.n_delay_slots
    return EventState(
        base=base,
        ev_ring=jnp.full((H, D, cap_ev), -1, jnp.int32),
        ev_count=jnp.zeros((H, D), jnp.int32),
        sat=jnp.zeros((H,), jnp.int32))


# ---------------------------------------------------------------------------
# per-shard phases (same A/exchange/B split as the dense engine)
# ---------------------------------------------------------------------------


def phase_a_dynamics(spec: SimSpec, plan: ShardPlan, eplan: EventPlan,
                     st: EventState, t: jnp.ndarray, stim_k
                     ) -> Tuple[EventState, jnp.ndarray, StepTimings]:
    """Event phase A minus LTP: arrival list -> currents/LTD -> stimulus ->
    neuron update.  Same split contract as `engine.phase_a_dynamics`: the
    returned spike mask is everything the exchange needs, so the
    pipelined schedule issues it here and hides it behind
    `phase_a_plasticity`."""
    cfg, stdp, izh = spec.cfg, spec.stdp, spec.izh
    D = cfg.n_delay_slots
    tf = t.astype(jnp.float32)
    r = jnp.mod(t, D)
    base = st.base

    # ---- arrivals: only this slot's event list ----
    ev = st.ev_ring[r]                                  # [cap_ev]
    valid = ev >= 0
    eve = jnp.maximum(ev, 0)
    w_ev = base.w[eve]
    tgt_ev = plan.syn_tgt[eve]
    i_syn = jnp.zeros((spec.n_local,), jnp.float32).at[tgt_ev].add(
        jnp.where(valid, w_ev, 0.0))
    # LTD + last_arrival on the event subset
    lp_ev = base.last_post[tgt_ev]
    plast_ev = plan.syn_plastic[eve]
    ltd = stdp.a_minus * jnp.exp((lp_ev - tf) / stdp.tau_minus)
    apply_ltd = valid & plast_ev & (lp_ev > NEG_TIME / 2)
    w_new = jnp.where(apply_ltd,
                      jnp.clip(w_ev - ltd, stdp.w_min, stdp.w_max), w_ev)
    oob = jnp.int32(base.w.shape[0])       # out-of-bounds drop sentinel
    w = base.w.at[jnp.where(valid, ev, oob)].set(w_new, mode="drop")
    last_arr = base.last_arr.at[jnp.where(valid, ev, oob)].set(
        tf, mode="drop")
    ev_ring = st.ev_ring.at[r].set(-1)
    ev_count = st.ev_count.at[r].set(0)

    # ---- stimulus + neuron dynamics (same as dense) ----
    g2l = engine.make_gid_to_local(spec, plan.shard_id)
    i_ext = stimulus.stim_current(cfg, stim_k, plan.columns, t, g2l,
                                  spec.n_local)
    from ..kernels import ops as kops
    a = jnp.where(plan.exc_mask, izh.a_exc, izh.a_inh).astype(jnp.float32)
    b = jnp.where(plan.exc_mask, izh.b_exc, izh.b_inh).astype(jnp.float32)
    c = jnp.where(plan.exc_mask, izh.c_exc, izh.c_inh).astype(jnp.float32)
    d = jnp.where(plan.exc_mask, izh.d_exc, izh.d_inh).astype(jnp.float32)
    v, u, spiked = kops.izhikevich_update(
        base.v, base.u, i_syn + i_ext, a, b, c, d, v_peak=izh.v_peak,
        dt=izh.dt, substeps=izh.v_substeps)
    spiked = spiked & plan.neuron_valid

    new = st._replace(
        base=base._replace(v=v, u=u, w=w, last_arr=last_arr),
        ev_ring=ev_ring, ev_count=ev_count)
    tm = StepTimings(spikes=spiked.sum(),
                     arrivals=valid.sum(dtype=jnp.int32))
    return new, spiked, tm


def phase_a_plasticity(spec: SimSpec, plan: ShardPlan, eplan: EventPlan,
                       st: EventState, spiked: jnp.ndarray, t: jnp.ndarray,
                       c_post: Optional[int] = None) -> EventState:
    """Event phase A's LTP pass: incoming rows of the COMPACTED
    spiking-neuron list.  Touches only {w, last_post, sat} — disjoint
    from phase B's {ev_ring, ev_count} writes — which is what makes
    overlapping the exchange with it legal."""
    stdp = spec.stdp
    tf = t.astype(jnp.float32)
    base = st.base
    if c_post is None:
        c_post = default_caps(spec)[0]

    n = spec.n_local
    oob = jnp.int32(base.w.shape[0])       # out-of-bounds drop sentinel
    spk_ids, post_sat = _compact(spiked, c_post, fill=n)
    rows = eplan.in_rows[jnp.minimum(spk_ids, n - 1)]    # [C_post, Ki]
    e_in = jnp.where((spk_ids < n)[:, None], rows, -1).reshape(-1)
    vin = e_in >= 0
    ein = jnp.maximum(e_in, 0)
    la_in = base.last_arr[ein]
    w_in = base.w[ein]
    ltp = stdp.a_plus * jnp.exp((la_in - tf) / stdp.tau_plus)
    apply_ltp = vin & plan.syn_plastic[ein] & (la_in > NEG_TIME / 2)
    w_upd = jnp.where(apply_ltp,
                      jnp.clip(w_in + ltp, stdp.w_min, stdp.w_max), w_in)
    w = base.w.at[jnp.where(vin, e_in, oob)].set(w_upd, mode="drop")
    last_post = jnp.where(spiked, tf, base.last_post)
    return st._replace(base=base._replace(w=w, last_post=last_post),
                       sat=st.sat + post_sat)


def phase_a(spec: SimSpec, plan: ShardPlan, eplan: EventPlan,
            st: EventState, t: jnp.ndarray, stim_k,
            c_post: Optional[int] = None
            ) -> Tuple[EventState, jnp.ndarray, StepTimings]:
    """Local dynamics on the event subset; returns (state', spiked, tm) —
    the same contract as `engine.phase_a`, so the distributed drivers can
    dispatch between backends without branching downstream.  Composition
    of `phase_a_dynamics` + `phase_a_plasticity`, bit-identical to the
    former fused version."""
    st, spiked, tm = phase_a_dynamics(spec, plan, eplan, st, t, stim_k)
    st = phase_a_plasticity(spec, plan, eplan, st, spiked, t, c_post=c_post)
    return st, spiked, tm


def phase_b(spec: SimSpec, plan: ShardPlan, eplan: EventPlan,
            st: EventState, spiked_src: jnp.ndarray, t: jnp.ndarray,
            c_src: Optional[int] = None) -> EventState:
    """Emission: append the spiking sources' synapse ids to the ring.

    The spiking source set is compacted first (event-sized gather of
    forward rows, O(spikes x fan) rather than O(S x Kf)).  All D ring
    slots are filled in ONE scatter: per-slot ranks come from a single
    cumsum over the [D, C*Kf] one-hot-by-slot matrix (D is 6), replacing
    the former Python loop of D sequential ranked `.at[].set` passes —
    each of which re-copied the ring on CPU."""
    D = spec.cfg.n_delay_slots
    cap = st.ev_ring.shape[-1]
    S = spiked_src.shape[0]
    if c_src is None:
        c_src = default_caps(spec)[1]
    src_ids, src_sat = _compact(spiked_src, c_src, fill=S)
    rows = eplan.fwd_rows[jnp.minimum(src_ids, S - 1)]   # [C_src, Kf]
    ids = jnp.where((src_ids < S)[:, None], rows, -1).reshape(-1)
    valid = ids >= 0
    idc = jnp.maximum(ids, 0)
    slot = jnp.mod(t + plan.syn_delay[idc], D)           # [L]

    # per-slot ranks in one pass: rank[i] = #earlier events in i's slot
    L = ids.shape[0]
    onehot = valid[None, :] & (slot[None, :]
                               == jnp.arange(D, dtype=slot.dtype)[:, None])
    rank = (jnp.cumsum(onehot, axis=1) - 1)[slot, jnp.arange(L)]
    per_slot = onehot.sum(axis=1, dtype=jnp.int32)       # [D]
    pos = st.ev_count[slot] + rank                       # [L] slot position
    ok = valid & (pos < cap)
    flat_pos = jnp.where(ok, slot * cap + pos, D * cap)  # oob -> drop
    ev_ring = st.ev_ring.reshape(-1).at[flat_pos].set(
        ids, mode="drop").reshape(D, cap)
    ev_count = jnp.minimum(st.ev_count + per_slot, cap)
    overflow = jnp.maximum(
        0, st.ev_count + per_slot - cap).sum(dtype=jnp.int32)
    return st._replace(ev_ring=ev_ring, ev_count=ev_count,
                       sat=st.sat + src_sat + overflow)


# ---------------------------------------------------------------------------
# single-device driver (mirrors engine.make_step_fn / run)
# ---------------------------------------------------------------------------


def build(cfg, eng, izh=None, stdp=None):
    """(spec, plan, eplan, state) for the event backend.

    Connectivity tables are built ONCE and shared between the dense plan
    and the event rows (they used to be rebuilt from scratch — the most
    expensive host-side construction step, doubled for nothing)."""
    from .params import DEFAULT_IZH, DEFAULT_STDP
    if connectivity.parse_mode(eng.connectivity)[0] != "materialized":
        raise ValueError(
            "delivery='event' requires connectivity='materialized': the "
            "event backend's per-source row tables are an O(E) permutation "
            "of synapse ids, which contradicts O(chunk) streamed residency")
    tables = connectivity.build_all_shards(cfg, eng)
    spec, plan, base = engine.build(cfg, eng, izh or DEFAULT_IZH,
                                    stdp or DEFAULT_STDP, tables=tables)
    eplan, cap_ev = build_event_plan(spec, tables=tables)
    state = init_event_state(spec, base, cap_ev)
    return spec, plan, eplan, state


def make_step_fn(spec: SimSpec, plan: ShardPlan, eplan: EventPlan,
                 c_post: Optional[int] = None, c_src: Optional[int] = None):
    stim_k = stimulus.stim_key(spec.cfg)

    def step(state: EventState, t: jnp.ndarray):
        state, spiked, tm = jax.vmap(
            lambda p, ep, s: phase_a(spec, p, ep, s, t, stim_k,
                                     c_post=c_post)
        )(plan, eplan, state)
        glob = engine._global_spike_mask(spec, plan, spiked)
        spiked_src = jax.vmap(
            lambda p: glob.at[p.src_gid].get(mode="fill", fill_value=False)
            & (p.src_gid >= 0))(plan)
        state = jax.vmap(
            lambda p, ep, s, ss: phase_b(spec, p, ep, s, ss, t, c_src=c_src)
        )(plan, eplan, state, spiked_src)
        return state, (spiked, tm)

    return step


def run(spec, plan, eplan, state, t0: int, n_steps: int,
        c_post: Optional[int] = None, c_src: Optional[int] = None):
    """Scan the simulation; returns (state, raster[T, H, N], timings) —
    the same contract as `engine.run`."""
    step = make_step_fn(spec, plan, eplan, c_post=c_post, c_src=c_src)
    ts = jnp.arange(t0, t0 + n_steps, dtype=jnp.int32)
    state, (raster, tm) = jax.lax.scan(step, state, ts)
    return state, raster, tm
