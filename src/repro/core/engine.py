"""DPSNN-STDP engine: per-shard plan/state, the two-phase simulation step,
and a single-device multi-shard driver (vmap-based logical distribution).

Step structure (paper §Methods, "dynamic phase" 2.1-2.4):

  phase A (local compute):
    1. pop this step's slot of the arrival ring        (spikes reach synapses)
    2. synaptic currents I = sum of arrived weights    (current injection)
    3. LTD for arrived synapses (nearest post spike)   (STDP, event-driven)
    4. thalamic stimulus
    5. Izhikevich neuron update -> spikes              (time-driven dynamics)
    6. LTP for incoming synapses of spiking neurons    (STDP, event-driven)
  exchange:
    7. deliver axonal spikes (AER) to target shards    (two-phase delivery)
  phase B (local compute):
    8. expand arrived axons into synapses: set arrival flags at
       slot (t + delay) mod D                          (deferred arborization)

The engine is written against per-shard arrays so the same phase functions
run under `vmap` (single device, logical shards — used by tests/benchmarks)
and under `shard_map` (real collectives — repro.core.distributed).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import connectivity, stimulus, topology
from .params import (DEFAULT_IZH, DEFAULT_STDP, EngineConfig, GridConfig,
                     IzhikevichParams, StdpParams)

NEG_TIME = jnp.float32(-1.0e9)   # "never" sentinel for last-spike times


class ShardPlan(NamedTuple):
    """Static per-shard data (device arrays).  Leading dim stacks shards."""

    src_gid: jnp.ndarray      # [S] int32 global ids of sources (-1 pad)
    syn_src: jnp.ndarray      # [E] int32 -> index into src table
    syn_tgt: jnp.ndarray      # [E] int32 local target neuron
    syn_delay: jnp.ndarray    # [E] int32 steps
    syn_plastic: jnp.ndarray  # [E] bool
    syn_valid: jnp.ndarray    # [E] bool
    exc_mask: jnp.ndarray     # [N] bool
    neuron_valid: jnp.ndarray  # [N] bool (capacity padding)
    gid: jnp.ndarray          # [N] int32 global id of each local neuron (-1)
    columns: jnp.ndarray      # [C] int32 columns owned (padded -1)
    shard_id: jnp.ndarray     # [] int32


class ShardState(NamedTuple):
    """Dynamic per-shard state."""

    v: jnp.ndarray            # [N] fp32
    u: jnp.ndarray            # [N] fp32
    last_post: jnp.ndarray    # [N] fp32 (time of most recent spike)
    w: jnp.ndarray            # [E] fp32 synaptic weights
    last_arr: jnp.ndarray     # [E] fp32 (time of most recent arrival)
    arr_ring: jnp.ndarray     # [D, E] bool arrival flags


class SimSpec(NamedTuple):
    """Static python-side description shared by all shards."""

    cfg: GridConfig
    eng: EngineConfig
    izh: IzhikevichParams
    stdp: StdpParams
    n_local: int              # N capacity per shard
    e_cap: int
    s_cap: int
    n_total: int
    # streamed-connectivity geometry (core.stream_engine.StreamSpec) or
    # None for materialized tables; when set, e_cap is the padded
    # synapse-STATE length and the ShardPlan syn_* leaves are dummies.
    stream: object = None


# ----------------------------------------------------------------------------
# plan construction
# ----------------------------------------------------------------------------


def _owned_columns_padded(cfg, eng, shard, c_cap):
    gids = topology.owned_gids(cfg, shard, eng.n_shards, eng.placement)
    cols = np.unique(topology.gid_column(cfg, gids))
    out = np.full((c_cap,), -1, dtype=np.int32)
    out[:cols.shape[0]] = cols
    return out


def build(cfg: GridConfig, eng: EngineConfig,
          izh: IzhikevichParams = DEFAULT_IZH,
          stdp: StdpParams = DEFAULT_STDP,
          tables=None) -> Tuple[SimSpec, ShardPlan, ShardState]:
    """Build plans + initial state for all shards, stacked on a leading [H]
    axis.  Construction is fully local per shard (zero communication).
    `tables` optionally reuses prebuilt `connectivity.build_all_shards`
    output so callers layering extra plans on top (the event backend) pay
    the host-side construction once."""
    if tables is None:
        tables = connectivity.build_all_shards(cfg, eng)
    H = eng.n_shards
    n_cap = topology.max_local_size(cfg, H, eng.placement)
    e_cap = tables[0].src_idx.shape[0]
    s_cap = tables[0].src_gid.shape[0]
    c_cap = max(
        np.unique(topology.gid_column(
            cfg, topology.owned_gids(cfg, h, H, eng.placement))).shape[0]
        for h in range(H))

    plans = []
    for h, t in enumerate(tables):
        gids = topology.owned_gids(cfg, h, H, eng.placement)
        n_loc = gids.shape[0]
        gid_p = np.full((n_cap,), -1, dtype=np.int32)
        gid_p[:n_loc] = gids
        exc = np.zeros((n_cap,), dtype=bool)
        exc[:n_loc] = topology.is_excitatory(cfg, gids)
        nv = np.zeros((n_cap,), dtype=bool)
        nv[:n_loc] = True
        plans.append(ShardPlan(
            src_gid=t.src_gid.astype(np.int32),
            syn_src=t.src_idx, syn_tgt=t.tgt_local,
            syn_delay=t.delay, syn_plastic=t.plastic, syn_valid=t.valid,
            exc_mask=exc, neuron_valid=nv, gid=gid_p,
            columns=_owned_columns_padded(cfg, eng, h, c_cap),
            shard_id=np.int32(h)))

    stacked = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *plans)
    spec = SimSpec(cfg=cfg, eng=eng, izh=izh, stdp=stdp, n_local=n_cap,
                   e_cap=e_cap, s_cap=s_cap, n_total=cfg.n_neurons)

    w0 = jnp.asarray(np.stack([t.weight0 for t in tables]))
    state = init_state(spec, stacked)._replace(w=w0)
    return spec, stacked, state


def init_state(spec: SimSpec, plan: ShardPlan) -> ShardState:
    """Fresh dynamic state (zero weights; `build` installs w0) [H, ...]."""
    if spec.stream is not None:
        from . import stream_engine
        return stream_engine.init_state(spec, plan)

    def one(p: ShardPlan) -> ShardState:
        v = jnp.full(p.exc_mask.shape, spec.izh.v_init, jnp.float32)
        b = jnp.where(p.exc_mask, spec.izh.b_exc, spec.izh.b_inh)
        return ShardState(
            v=v, u=b.astype(jnp.float32) * v,
            last_post=jnp.full(p.exc_mask.shape, NEG_TIME),
            w=jnp.zeros(p.syn_valid.shape, jnp.float32),
            last_arr=jnp.full(p.syn_valid.shape, NEG_TIME),
            arr_ring=jnp.zeros(
                (spec.cfg.n_delay_slots,) + p.syn_valid.shape, bool))

    return jax.vmap(one)(plan)


# ----------------------------------------------------------------------------
# ownership maps (gid -> local index), placement-specific
# ----------------------------------------------------------------------------


def make_gid_to_local(spec: SimSpec, shard_id: jnp.ndarray) -> Callable:
    """Returns gid_to_local(gids) -> (local_idx, owned_mask) for one shard."""
    eng, cfg = spec.eng, spec.cfg
    if eng.placement == "block":
        bounds = topology.shard_bounds_block(cfg.n_neurons, eng.n_shards)
        starts = jnp.asarray(bounds[:-1], jnp.int32)
        ends = jnp.asarray(bounds[1:], jnp.int32)

        def f(gids):
            s = starts[shard_id]
            e = ends[shard_id]
            owned = (gids >= s) & (gids < e)
            return (gids - s).astype(jnp.int32), owned
        return f
    elif eng.placement == "scatter":
        H = eng.n_shards

        def f(gids):
            owned = (gids % H) == shard_id
            owned &= (gids >= 0) & (gids < cfg.n_neurons)
            return (gids // H).astype(jnp.int32), owned
        return f
    raise ValueError(eng.placement)


# ----------------------------------------------------------------------------
# the step, phase A / phase B
# ----------------------------------------------------------------------------


class StepTimings(NamedTuple):
    """Per-phase work markers (paper Table 2 instrumentation hooks)."""
    spikes: jnp.ndarray       # local spike count this step
    arrivals: jnp.ndarray     # synaptic arrival count this step


def phase_a_dynamics(spec: SimSpec, plan: ShardPlan, state: ShardState,
                     t: jnp.ndarray, stim_k: jax.Array
                     ) -> Tuple[ShardState, jnp.ndarray, StepTimings]:
    """Phase A steps 1-5: arrivals -> currents -> LTD -> stimulus -> neuron.

    Produces the spike mask — everything the exchange needs — WITHOUT the
    LTP pass, so a pipelined schedule can issue the spike exchange here
    and overlap it with `phase_a_plasticity`.  Returns (state', spiked,
    timings); `state'.last_post` is untouched (plasticity owns it).
    """
    from ..kernels import ops as kops

    cfg, stdp = spec.cfg, spec.stdp
    up = spec.eng.use_pallas or None   # None -> auto (Pallas iff on TPU)
    D = cfg.n_delay_slots
    tf = t.astype(jnp.float32)
    r = jnp.mod(t, D)

    arrivals = state.arr_ring[r] & plan.syn_valid            # [E]
    # 2+3. fused arrival pass: current contributions (pre-LTD weights, in
    # canonical (tgt, src, j) order => reproducible sum), LTD against the
    # nearest post spike, last_arrival refresh.
    lp = state.last_post[plan.syn_tgt]
    w, last_arr, contrib = kops.stdp_arrival(
        arrivals, state.w, lp, state.last_arr, plan.syn_plastic, tf,
        a_minus=stdp.a_minus, tau_minus=stdp.tau_minus, w_min=stdp.w_min,
        w_max=stdp.w_max, neg_time=float(NEG_TIME), use_pallas=up)
    i_syn = jax.ops.segment_sum(contrib, plan.syn_tgt,
                                num_segments=spec.n_local,
                                indices_are_sorted=True)
    arr_ring = state.arr_ring.at[r].set(False)

    # 4+5. stimulus + Izhikevich (shared with the streamed driver)
    v, u, spiked = neuron_update(spec, plan, state, i_syn, t, stim_k)

    new = ShardState(v=v, u=u, last_post=state.last_post, w=w,
                     last_arr=last_arr, arr_ring=arr_ring)
    tm = StepTimings(spikes=spiked.sum(), arrivals=arrivals.sum())
    return new, spiked, tm


def neuron_update(spec: SimSpec, plan: ShardPlan, state: ShardState,
                  i_syn: jnp.ndarray, t: jnp.ndarray, stim_k: jax.Array
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Phase A steps 4-5: thalamic stimulus + Izhikevich update.

    Factored out so `core.stream_engine` runs the identical op sequence on
    a chunk-accumulated i_syn — the neuron-level halves of the two drivers
    cannot drift apart.  Returns (v, u, spiked).
    """
    from ..kernels import ops as kops

    cfg, izh = spec.cfg, spec.izh
    up = spec.eng.use_pallas or None

    # 4. thalamic stimulus
    g2l = make_gid_to_local(spec, plan.shard_id)
    i_ext = stimulus.stim_current(cfg, stim_k, plan.columns, t, g2l,
                                  spec.n_local)

    # 5. Izhikevich update (fused kernel on TPU)
    i_tot = i_syn + i_ext
    a = jnp.where(plan.exc_mask, izh.a_exc, izh.a_inh).astype(jnp.float32)
    b = jnp.where(plan.exc_mask, izh.b_exc, izh.b_inh).astype(jnp.float32)
    c = jnp.where(plan.exc_mask, izh.c_exc, izh.c_inh).astype(jnp.float32)
    d = jnp.where(plan.exc_mask, izh.d_exc, izh.d_inh).astype(jnp.float32)
    v, u, spiked = kops.izhikevich_update(
        state.v, state.u, i_tot, a, b, c, d, v_peak=izh.v_peak, dt=izh.dt,
        substeps=izh.v_substeps, use_pallas=up)
    spiked = spiked & plan.neuron_valid
    return v, u, spiked


def phase_a_plasticity(spec: SimSpec, plan: ShardPlan, state: ShardState,
                       spiked: jnp.ndarray, t: jnp.ndarray) -> ShardState:
    """Phase A step 6: LTP for incoming synapses of spiking neurons.

    dW = +a_plus * exp((last_arrival - t) / tau_plus), dt >= 0.
    Touches only {w, last_post} — disjoint from phase B's {arr_ring} — so
    it commutes with spike delivery and is the compute the pipelined
    schedule hides the exchange behind.
    """
    from ..kernels import ops as kops

    stdp = spec.stdp
    up = spec.eng.use_pallas or None
    tf = t.astype(jnp.float32)
    post = spiked[plan.syn_tgt]
    w = kops.stdp_ltp(post, state.w, state.last_arr, plan.syn_plastic,
                      plan.syn_valid, tf, a_plus=stdp.a_plus,
                      tau_plus=stdp.tau_plus, w_min=stdp.w_min,
                      w_max=stdp.w_max, neg_time=float(NEG_TIME),
                      use_pallas=up)
    last_post = jnp.where(spiked, tf, state.last_post)
    return state._replace(w=w, last_post=last_post)


def phase_a(spec: SimSpec, plan: ShardPlan, state: ShardState,
            t: jnp.ndarray, stim_k: jax.Array
            ) -> Tuple[ShardState, jnp.ndarray, StepTimings]:
    """Local dynamics: arrivals -> currents -> LTD -> neuron -> LTP.

    Composition of `phase_a_dynamics` + `phase_a_plasticity` (the split
    exists for the pipelined exchange schedule; composing them is
    bit-identical to the original fused phase A).  Returns
    (state', spiked[N] bool, timings).
    """
    state, spiked, tm = phase_a_dynamics(spec, plan, state, t, stim_k)
    state = phase_a_plasticity(spec, plan, state, spiked, t)
    return state, spiked, tm


def phase_b(spec: SimSpec, plan: ShardPlan, state: ShardState,
            spiked_src: jnp.ndarray, t: jnp.ndarray) -> ShardState:
    """Deferred axonal arborization: set arrival flags at t + delay.

    The update is a broadcast-compare against the D (=6) static slots
    instead of a scatter: a scatter into [D, E] lowers to iota+concat+
    scatter-max (~12 MB/step of index traffic at E=216k); the compare
    formulation is D fused selects (EXPERIMENTS.md §Perf, SNN iteration).
    """
    D = spec.cfg.n_delay_slots
    active = spiked_src[plan.syn_src] & plan.syn_valid       # [E]
    slot = jnp.mod(t + plan.syn_delay, D)                    # [E]
    hit = active[None, :] & (slot[None, :]
                             == jnp.arange(D, dtype=slot.dtype)[:, None])
    return state._replace(arr_ring=state.arr_ring | hit)


# ----------------------------------------------------------------------------
# single-device driver: logical shards via vmap, exchange via global mask
# ----------------------------------------------------------------------------


def _global_spike_mask(spec: SimSpec, plan: ShardPlan, spiked: jnp.ndarray
                       ) -> jnp.ndarray:
    """[N_total] bool from stacked per-shard spike masks."""
    gids = plan.gid.reshape(-1)
    spk = spiked.reshape(-1)
    return jnp.zeros((spec.n_total,), bool).at[gids].max(spk, mode="drop")


def make_step_fn(spec: SimSpec, plan: ShardPlan):
    """jit-able step over stacked shard states (single device, vmap comm)."""
    stim_k = stimulus.stim_key(spec.cfg)

    def step(state: ShardState, t: jnp.ndarray):
        state, spiked, tm = jax.vmap(
            lambda p, s: phase_a(spec, p, s, t, stim_k))(plan, state)
        glob = _global_spike_mask(spec, plan, spiked)        # the exchange
        spiked_src = jax.vmap(
            lambda p: glob.at[p.src_gid].get(mode="fill", fill_value=False)
            & (p.src_gid >= 0))(plan)
        state = jax.vmap(
            lambda p, s, ss: phase_b(spec, p, s, ss, t))(plan, state,
                                                         spiked_src)
        return state, (spiked, tm)

    return step


def run(spec: SimSpec, plan: ShardPlan, state: ShardState, t0: int,
        n_steps: int):
    """Scan the simulation; returns (state, raster[T, H, N], timings)."""
    step = make_step_fn(spec, plan)

    def body(s, t):
        s, out = step(s, t)
        return s, out

    ts = jnp.arange(t0, t0 + n_steps, dtype=jnp.int32)
    state, (raster, tm) = jax.lax.scan(body, state, ts)
    return state, raster, tm
