"""Streamed connectivity execution: regenerate synapse chunks inside the step.

`EngineConfig.connectivity='streamed:chunk=<K>'` trades the materialized
O(E) per-shard synapse tables for in-step regeneration: every phase scans
over fixed chunks of K target columns and rebuilds that chunk's incoming
synapses from the SAME counter-based splitmix64 draw lanes the host builder
uses (`core.connectivity.forward_synapses`), so only one chunk's tables —
O(K * neighbourhood * M) slots — are ever live.  Weight/arrival STATE stays
O(E) (it is genuine state), laid out in the identical canonical
(tgt_gid, src_gid, j) order as materialized mode, which is why rasters AND
weights are bit-identical and checkpoints round-trip across modes' shard
counts and chunk sizes (DESIGN.md §Streamed connectivity).

Bit-identity hinges on two facts:

  1. The draw is counter-based: synapse (g, j) is a pure function of
     (seed, g, j, grid), independent of which shard/chunk asks.  The jitted
     generator below reimplements splitmix64 on uint32 limb pairs (jax here
     runs with 32-bit ints) and derives ring/member/target/delay with exact
     integer arithmetic — no float draw is ever compared differently from
     the numpy path (tests wall this per profile).
  2. Chunks partition targets by whole local index ranges, so each target's
     incoming synapses live wholly inside one chunk and the concatenation
     of per-chunk canonical slices IS the shard's canonical synapse list.
     Per-target accumulation order — the paper's Table 1 bit-identity
     argument — is therefore unchanged.

The scan windows [e_start[c], e_start[c] + k_cap) of the state arrays
overlap the next chunk's live region (k_cap is a static capacity, chunk
fill varies).  That is safe because the STDP oracles are no-ops at
non-arrival/invalid slots and the scan is sequential (read-modify-write),
and the arrival-ring clear masks to the chunk's own valid slots.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import connectivity, engine, profiles, stimulus, topology
from .engine import (NEG_TIME, ShardPlan, ShardState, SimSpec, StepTimings,
                     make_gid_to_local)
from .params import (DEFAULT_IZH, DEFAULT_STDP, EngineConfig, GridConfig,
                     IzhikevichParams, StdpParams)

_MASK32 = 0xFFFFFFFF


class StreamSpec(NamedTuple):
    """Static streamed-mode geometry (rides on SimSpec.stream)."""

    chunk_cols: int           # K: target columns per chunk
    q: int                    # owned-neuron slots per chunk (K * npc)
    n_chunks: int
    c_cap: int                # candidate-source cap per chunk
    k_cap: int                # generation slots per chunk (c_cap * M)
    e_pad: int                # padded synapse-state length (>= E + k_cap)


class StreamedPlan(NamedTuple):
    """Per-shard streamed metadata (leading dim stacks shards).

    O(n_chunks * c_cap) ints — the only per-synapse-table data kept live
    across the whole run; actual tables are regenerated per chunk.
    """

    cand: jnp.ndarray         # [n_chunks, c_cap] int32 src-table rows (-1 pad)
    e_start: jnp.ndarray      # [n_chunks + 1] int32 canonical chunk offsets


class ChunkTables(NamedTuple):
    """One regenerated chunk, canonical order, valid-first.  All [k_cap]."""

    src: jnp.ndarray          # int32 index into plan.src_gid (0 when invalid)
    tgt_rel: jnp.ndarray      # int32 in [0, q]; q = segment-sum dump slot
    delay: jnp.ndarray        # int32
    plastic: jnp.ndarray      # bool
    valid: jnp.ndarray        # bool
    j: Optional[jnp.ndarray] = None   # int32 forward slot (test/debug only)


# ----------------------------------------------------------------------------
# uint32-limb splitmix64 (bit-identical to connectivity.splitmix64)
# ----------------------------------------------------------------------------


def _add64(ah, al, bh, bl):
    lo = al + bl
    carry = (lo < al).astype(jnp.uint32)
    return ah + bh + carry, lo


def _shr64(ah, al, k: int):
    # all splitmix64 shifts (30/27/31) satisfy 0 < k < 32
    return ah >> k, (al >> k) | (ah << (32 - k))


def _mul32(a, b):
    """Full 32x32 -> 64 product as (hi, lo) uint32 limbs."""
    a0, a1 = a & 0xFFFF, a >> 16
    b0, b1 = b & 0xFFFF, b >> 16
    t00 = a0 * b0
    t01 = a0 * b1
    t10 = a1 * b0
    mid = (t00 >> 16) + (t01 & 0xFFFF) + (t10 & 0xFFFF)
    lo = (t00 & 0xFFFF) | ((mid & 0xFFFF) << 16)
    hi = a1 * b1 + (mid >> 16) + (t01 >> 16) + (t10 >> 16)
    return hi, lo


def _mul64(ah, al, bh, bl):
    """Low 64 bits of the product (wrapping, like uint64 multiply)."""
    hi, lo = _mul32(al, bl)
    return hi + al * bh + ah * bl, lo


def _c64(x: int):
    """Split a python uint64 constant into jnp.uint32 limbs (hi, lo).

    Explicit wrapping: a bare python int above 2^31 fails jax's weak-type
    promotion with an int32 OverflowError.
    """
    return jnp.uint32((x >> 32) & _MASK32), jnp.uint32(x & _MASK32)


def _splitmix64(h, l):
    h, l = _add64(h, l, *_c64(0x9E3779B97F4A7C15))   # += GOLDEN
    xh, xl = _shr64(h, l, 30)
    h, l = h ^ xh, l ^ xl
    h, l = _mul64(h, l, *_c64(0xBF58476D1CE4E5B9))   # *= MIX1
    xh, xl = _shr64(h, l, 27)
    h, l = h ^ xh, l ^ xl
    h, l = _mul64(h, l, *_c64(0x94D049BB133111EB))   # *= MIX2
    xh, xl = _shr64(h, l, 31)
    return h ^ xh, l ^ xl


def _mod64(h, l, m):
    """(h * 2^32 + l) mod m for small m (m <= 2^16, so no limb overflows)."""
    m = jnp.asarray(m, jnp.uint32)
    r16 = jnp.uint32(1 << 16) % m
    r32 = (r16 * r16) % m
    return ((h % m) * r32 + (l % m)) % m


# ----------------------------------------------------------------------------
# in-jit chunk regeneration
# ----------------------------------------------------------------------------


def _gen_consts(cfg: GridConfig):
    """Host-side generation constants (profile tables, draw-lane seeds)."""
    prof = profiles.from_config(cfg)
    reach = prof.reach()
    off_tab, start = profiles.offset_tables(reach)
    # U[k] = ceil(fr[k] * 2^53): `fr[k] <= bits53 * 2^-53` iff `bits53 >=
    # U[k]` (power-of-two scaling is exact), so the integer comparison
    # reproduces np.searchsorted(fr, r, side='right') bit-for-bit,
    # including every equality edge case.
    U = [math.ceil(float(f) * 2.0 ** 53) for f in prof.cum_fractions()]
    with np.errstate(over="ignore"):
        lanes = [int(connectivity.splitmix64(
            np.uint64(cfg.seed)
            + connectivity._GOLDEN * np.uint64(k + 1)))
            for k in range(4)]
    return reach, off_tab, start, U, lanes


def make_chunk_tables(spec: SimSpec, plan: ShardPlan):
    """Returns f(c, cand_row, with_j=False) -> ChunkTables for ONE shard.

    Bit-identical (over valid slots) to `connectivity._chunk_synapses`
    restricted to chunk c; invalid slots sort to the tail, so valid entries
    occupy the contiguous prefix [0, e_start[c+1] - e_start[c]).
    """
    cfg = spec.cfg
    ss = spec.stream
    assert ss is not None
    M = cfg.synapses_per_neuron
    npc = cfg.neurons_per_column
    nexc = cfg.n_exc_per_column
    # _mod64's limb arithmetic needs every modulus < 2^16
    assert npc < (1 << 16) and M < (1 << 16), \
        "streamed generation assumes npc, M < 65536"
    reach, off_tab, start, U, lanes = _gen_consts(cfg)
    start_j = jnp.asarray(start, jnp.int32)
    off_j = jnp.asarray(off_tab, jnp.int32)
    dspan = cfg.delay_max - cfg.delay_min + 1
    gx, gy = cfg.grid_x, cfg.grid_y
    g2l = make_gid_to_local(spec, plan.shard_id)
    int_max = jnp.iinfo(jnp.int32).max

    def draw(lane, ch, cl):
        sh, sl = _c64(lanes[lane])
        return _splitmix64(ch ^ sh, cl ^ sl)

    def tables(c, cand_row, with_j: bool = False) -> ChunkTables:
        cvalid = cand_row >= 0                               # [c_cap]
        sidx = jnp.where(cvalid, cand_row, 0)
        g = jnp.where(cvalid, plan.src_gid[sidx], 0)         # [c_cap] int32
        g_u = g.astype(jnp.uint32)

        # counter = g * M + j (64-bit, exact)
        jj = jnp.arange(M, dtype=jnp.uint32)[None, :]        # [1, M]
        ch_, cl_ = _mul32(g_u[:, None], jnp.uint32(M))       # [c_cap, 1]
        ch_, cl_ = _add64(ch_, cl_, jnp.uint32(0), jj)       # [c_cap, M]

        # lane 0: ring selection via 53-bit threshold comparison
        b0h, b0l = draw(0, ch_, cl_)
        rh = b0h >> 11                                       # top 21 bits
        rl = (b0l >> 11) | (b0h << 21)
        ring = jnp.zeros(rh.shape, jnp.int32)
        for Uk in U:
            uh, ul = _c64(Uk)
            ring = ring + ((rh > uh)
                           | ((rh == uh) & (rl >= ul))).astype(jnp.int32)
        ring = jnp.clip(ring, 0, reach)

        # lane 1: member within ring
        b1h, b1l = draw(1, ch_, cl_)
        rsize = (start_j[ring + 1] - start_j[ring]).astype(jnp.uint32)
        member = _mod64(b1h, b1l, rsize).astype(jnp.int32)
        off = off_j[start_j[ring] + member]                  # [c_cap, M, 2]

        # lane 2: target neuron within column
        b2h, b2l = draw(2, ch_, cl_)
        col = g // npc
        cx, cy = col % gx, col // gx
        tcol = (((cy[:, None] + off[..., 1]) % gy) * gx
                + ((cx[:, None] + off[..., 0]) % gx))
        n_exc_tgt = _mod64(b2h, b2l, jnp.uint32(npc)).astype(jnp.int32)
        tgt_exc = tcol * npc + n_exc_tgt
        n_inh_tgt = _mod64(b2h, b2l, jnp.uint32(nexc)).astype(jnp.int32)
        tgt_inh = col[:, None] * npc + n_inh_tgt

        # lane 3: delay
        b3h, b3l = draw(3, ch_, cl_)
        delay_exc = (1 + _mod64(b3h, b3l, jnp.uint32(dspan)).astype(jnp.int32)
                     + (cfg.delay_min - 1))

        exc = (g % npc) < nexc                               # [c_cap] bool
        excb = exc[:, None]
        tgt = jnp.where(excb, tgt_exc, tgt_inh)
        delay = jnp.where(excb, delay_exc, jnp.int32(cfg.delay_min))

        # ownership + chunk-range filter, then canonical stable sort:
        # generation order is (src gid asc, j asc), so a stable sort on
        # target-local index reproduces lexsort((j, src, tgt)).
        tloc, owned = g2l(tgt)
        lo = c * ss.q
        keep = cvalid[:, None] & owned & (tloc >= lo) & (tloc < lo + ss.q)
        keepf = keep.reshape(-1)
        tlocf = tloc.reshape(-1)
        key = jnp.where(keepf, tlocf, int_max)
        order = jnp.argsort(key, stable=True)
        valid = keepf[order]
        srcf = jnp.where(valid,
                         jnp.broadcast_to(sidx[:, None],
                                          keep.shape).reshape(-1)[order], 0)
        tgt_rel = jnp.where(valid, tlocf[order] - lo, ss.q)
        delayf = delay.reshape(-1)[order]
        plasticf = jnp.broadcast_to(excb, keep.shape).reshape(-1)[order] & valid
        jf = None
        if with_j:
            jf = jnp.where(valid, jnp.broadcast_to(
                jnp.arange(M, dtype=jnp.int32)[None, :],
                keep.shape).reshape(-1)[order], 0)
        return ChunkTables(src=srcf, tgt_rel=tgt_rel.astype(jnp.int32),
                           delay=delayf, plastic=plasticf, valid=valid, j=jf)

    return tables


# ----------------------------------------------------------------------------
# streamed phases: lax.scan over chunks with windowed state
# ----------------------------------------------------------------------------


def _chunk_xs(spec: SimSpec, splan: StreamedPlan):
    cs = jnp.arange(spec.stream.n_chunks, dtype=jnp.int32)
    return cs, splan.cand, splan.e_start[:-1]


def phase_a_dynamics(spec: SimSpec, plan: ShardPlan, splan: StreamedPlan,
                     state: ShardState, t: jnp.ndarray, stim_k: jax.Array
                     ) -> Tuple[ShardState, jnp.ndarray, StepTimings]:
    """Streamed phase A steps 1-5 (see engine.phase_a_dynamics)."""
    from ..kernels import ops as kops

    cfg, stdp = spec.cfg, spec.stdp
    ss = spec.stream
    up = spec.eng.use_pallas or None
    D = cfg.n_delay_slots
    tf = t.astype(jnp.float32)
    r = jnp.mod(t, D)
    tables = make_chunk_tables(spec, plan)

    def body(carry, xs):
        w, la, ring, i_buf, n_arr = carry
        c, cand_row, e0 = xs
        tb = tables(c, cand_row)
        w_win = jax.lax.dynamic_slice_in_dim(w, e0, ss.k_cap)
        la_win = jax.lax.dynamic_slice_in_dim(la, e0, ss.k_cap)
        ring_win = jax.lax.dynamic_slice(ring, (jnp.int32(0), e0),
                                         (D, ss.k_cap))
        arrivals = ring_win[r] & tb.valid
        lp = state.last_post[tb.tgt_rel + c * ss.q]
        w2, la2, contrib = kops.stdp_arrival(
            arrivals, w_win, lp, la_win, tb.plastic, tf,
            a_minus=stdp.a_minus, tau_minus=stdp.tau_minus,
            w_min=stdp.w_min, w_max=stdp.w_max, neg_time=float(NEG_TIME),
            use_pallas=up)
        # per-chunk segment sum: every target's synapses live wholly in
        # this chunk and arrive in canonical order, so the per-target add
        # order is identical to the materialized full-table segment_sum;
        # invalid slots dump into segment q (contributions are exactly 0.0,
        # and no valid contribution is -0.0 — exc weights clip to
        # [0, w_max], inh weights are a fixed negative — so the dump adds
        # are bit-inert anyway).
        seg = jax.ops.segment_sum(contrib, tb.tgt_rel,
                                  num_segments=ss.q + 1,
                                  indices_are_sorted=True)
        i_buf = jax.lax.dynamic_update_slice_in_dim(i_buf, seg[:ss.q],
                                                    c * ss.q, 0)
        # clear this step's slot ONLY at this chunk's valid slots: the
        # window tail overlaps the next chunk's live region.
        row = ring_win[r] & ~tb.valid
        ring_win = jax.lax.dynamic_update_slice(ring_win, row[None, :],
                                                (r, jnp.int32(0)))
        ring = jax.lax.dynamic_update_slice(ring, ring_win,
                                            (jnp.int32(0), e0))
        w = jax.lax.dynamic_update_slice_in_dim(w, w2, e0, 0)
        la = jax.lax.dynamic_update_slice_in_dim(la, la2, e0, 0)
        return (w, la, ring, i_buf, n_arr + arrivals.sum()), None

    i_buf0 = jnp.zeros((ss.n_chunks * ss.q,), jnp.float32)
    carry0 = (state.w, state.last_arr, state.arr_ring, i_buf0, jnp.int32(0))
    (w, la, ring, i_buf, n_arr), _ = jax.lax.scan(
        body, carry0, _chunk_xs(spec, splan))
    i_syn = i_buf[:spec.n_local]

    v, u, spiked = engine.neuron_update(spec, plan, state, i_syn, t, stim_k)
    new = ShardState(v=v, u=u, last_post=state.last_post, w=w,
                     last_arr=la, arr_ring=ring)
    tm = StepTimings(spikes=spiked.sum(), arrivals=n_arr)
    return new, spiked, tm


def phase_a_plasticity(spec: SimSpec, plan: ShardPlan, splan: StreamedPlan,
                       state: ShardState, spiked: jnp.ndarray,
                       t: jnp.ndarray) -> ShardState:
    """Streamed phase A step 6 (see engine.phase_a_plasticity)."""
    from ..kernels import ops as kops

    stdp = spec.stdp
    ss = spec.stream
    up = spec.eng.use_pallas or None
    tf = t.astype(jnp.float32)
    tables = make_chunk_tables(spec, plan)

    def body(w, xs):
        c, cand_row, e0 = xs
        tb = tables(c, cand_row)
        w_win = jax.lax.dynamic_slice_in_dim(w, e0, ss.k_cap)
        la_win = jax.lax.dynamic_slice_in_dim(state.last_arr, e0, ss.k_cap)
        post = spiked[tb.tgt_rel + c * ss.q]
        w2 = kops.stdp_ltp(post, w_win, la_win, tb.plastic, tb.valid, tf,
                           a_plus=stdp.a_plus, tau_plus=stdp.tau_plus,
                           w_min=stdp.w_min, w_max=stdp.w_max,
                           neg_time=float(NEG_TIME), use_pallas=up)
        return jax.lax.dynamic_update_slice_in_dim(w, w2, e0, 0), None

    w, _ = jax.lax.scan(body, state.w, _chunk_xs(spec, splan))
    last_post = jnp.where(spiked, tf, state.last_post)
    return state._replace(w=w, last_post=last_post)


def phase_a(spec: SimSpec, plan: ShardPlan, splan: StreamedPlan,
            state: ShardState, t: jnp.ndarray, stim_k: jax.Array
            ) -> Tuple[ShardState, jnp.ndarray, StepTimings]:
    state, spiked, tm = phase_a_dynamics(spec, plan, splan, state, t, stim_k)
    state = phase_a_plasticity(spec, plan, splan, state, spiked, t)
    return state, spiked, tm


def phase_b(spec: SimSpec, plan: ShardPlan, splan: StreamedPlan,
            state: ShardState, spiked_src: jnp.ndarray, t: jnp.ndarray
            ) -> ShardState:
    """Streamed deferred arborization (see engine.phase_b)."""
    ss = spec.stream
    D = spec.cfg.n_delay_slots
    tables = make_chunk_tables(spec, plan)

    def body(ring, xs):
        c, cand_row, e0 = xs
        tb = tables(c, cand_row)
        active = spiked_src[tb.src] & tb.valid
        slot = jnp.mod(t + tb.delay, D)
        hit = active[None, :] & (slot[None, :]
                                 == jnp.arange(D, dtype=slot.dtype)[:, None])
        ring_win = jax.lax.dynamic_slice(ring, (jnp.int32(0), e0),
                                         (D, ss.k_cap))
        ring = jax.lax.dynamic_update_slice(ring, ring_win | hit,
                                            (jnp.int32(0), e0))
        return ring, None

    ring, _ = jax.lax.scan(body, state.arr_ring, _chunk_xs(spec, splan))
    return state._replace(arr_ring=ring)


# ----------------------------------------------------------------------------
# build + single-device driver
# ----------------------------------------------------------------------------


def build(cfg: GridConfig, eng: EngineConfig,
          izh: IzhikevichParams = DEFAULT_IZH,
          stdp: StdpParams = DEFAULT_STDP
          ) -> Tuple[SimSpec, ShardPlan, StreamedPlan, ShardState]:
    """Build streamed plans + initial state, stacked on a leading [H] axis.

    The returned ShardPlan carries the full candidate-source table (the
    exchange wires and halo provisioning read only `src_gid`/`gid`) but
    1-element dummies for the per-synapse arrays — those are regenerated
    per chunk by `make_chunk_tables`.
    """
    mode, chunk_cols = connectivity.parse_mode(eng.connectivity)
    if mode != "streamed":
        raise ValueError(f"stream_engine.build called with connectivity="
                         f"{eng.connectivity!r}")
    if eng.delivery != "dense":
        raise ValueError(
            "connectivity='streamed' requires delivery='dense': the event "
            "backend's fwd/in row tables are an O(E) synapse-id "
            "permutation, which contradicts O(chunk) table residency")
    shards = connectivity.build_all_streamed(cfg, eng, chunk_cols)
    H = eng.n_shards
    n_cap, q, n_chunks = connectivity.stream_geometry(cfg, eng, chunk_cols)
    c_cap = shards[0].cand.shape[1]
    s_cap = shards[0].src_gid.shape[0]
    k_cap = c_cap * cfg.synapses_per_neuron
    e_max = max(s.n_valid for s in shards)
    # + k_cap: the last chunk's [e0, e0 + k_cap) window must fit without
    # dynamic_slice clamping (a clamped window would shift the read).
    e_pad = connectivity._round_up(max(e_max, 1), 8) + k_cap
    col_cap = max(
        np.unique(topology.gid_column(
            cfg, topology.owned_gids(cfg, h, H, eng.placement))).shape[0]
        for h in range(H))

    plans, splans = [], []
    for h, sh in enumerate(shards):
        gids = topology.owned_gids(cfg, h, H, eng.placement)
        n_loc = gids.shape[0]
        gid_p = np.full((n_cap,), -1, dtype=np.int32)
        gid_p[:n_loc] = gids
        exc = np.zeros((n_cap,), dtype=bool)
        exc[:n_loc] = topology.is_excitatory(cfg, gids)
        nv = np.zeros((n_cap,), dtype=bool)
        nv[:n_loc] = True
        plans.append(ShardPlan(
            src_gid=sh.src_gid.astype(np.int32),
            syn_src=np.zeros((1,), np.int32),
            syn_tgt=np.zeros((1,), np.int32),
            syn_delay=np.ones((1,), np.int32),
            syn_plastic=np.zeros((1,), bool),
            syn_valid=np.zeros((1,), bool),
            exc_mask=exc, neuron_valid=nv, gid=gid_p,
            columns=engine._owned_columns_padded(cfg, eng, h, col_cap),
            shard_id=np.int32(h)))
        splans.append(StreamedPlan(cand=sh.cand,
                                   e_start=sh.e_start.astype(np.int32)))

    plan = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *plans)
    splan = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *splans)
    spec = SimSpec(cfg=cfg, eng=eng, izh=izh, stdp=stdp, n_local=n_cap,
                   e_cap=e_pad, s_cap=s_cap, n_total=cfg.n_neurons,
                   stream=StreamSpec(chunk_cols=chunk_cols, q=q,
                                     n_chunks=n_chunks, c_cap=c_cap,
                                     k_cap=k_cap, e_pad=e_pad))
    w0 = np.zeros((H, e_pad), np.float32)
    for h, sh in enumerate(shards):
        w0[h, :sh.n_valid] = sh.weight0
    state = init_state(spec, plan)._replace(w=jnp.asarray(w0))
    return spec, plan, splan, state


def init_state(spec: SimSpec, plan: ShardPlan) -> ShardState:
    """Fresh streamed state: synapse-state arrays sized [e_pad]."""
    ss = spec.stream
    assert ss is not None

    def one(p: ShardPlan) -> ShardState:
        v = jnp.full(p.exc_mask.shape, spec.izh.v_init, jnp.float32)
        b = jnp.where(p.exc_mask, spec.izh.b_exc, spec.izh.b_inh)
        return ShardState(
            v=v, u=b.astype(jnp.float32) * v,
            last_post=jnp.full(p.exc_mask.shape, NEG_TIME),
            w=jnp.zeros((ss.e_pad,), jnp.float32),
            last_arr=jnp.full((ss.e_pad,), NEG_TIME),
            arr_ring=jnp.zeros((spec.cfg.n_delay_slots, ss.e_pad), bool))

    return jax.vmap(one)(plan)


def make_step_fn(spec: SimSpec, plan: ShardPlan, splan: StreamedPlan):
    """jit-able step over stacked shard states (single device, vmap comm)."""
    stim_k = stimulus.stim_key(spec.cfg)

    def step(state: ShardState, t: jnp.ndarray):
        state, spiked, tm = jax.vmap(
            lambda p, sp, s: phase_a(spec, p, sp, s, t, stim_k)
        )(plan, splan, state)
        glob = engine._global_spike_mask(spec, plan, spiked)
        spiked_src = jax.vmap(
            lambda p: glob.at[p.src_gid].get(mode="fill", fill_value=False)
            & (p.src_gid >= 0))(plan)
        state = jax.vmap(
            lambda p, sp, s, ssrc: phase_b(spec, p, sp, s, ssrc, t)
        )(plan, splan, state, spiked_src)
        return state, (spiked, tm)

    return step


def run(spec: SimSpec, plan: ShardPlan, splan: StreamedPlan,
        state: ShardState, t0: int, n_steps: int):
    """Scan the simulation; returns (state, raster[T, H, N], timings)."""
    step = make_step_fn(spec, plan, splan)

    def body(s, t):
        s, out = step(s, t)
        return s, out

    ts = jnp.arange(t0, t0 + n_steps, dtype=jnp.int32)
    state, (raster, tm) = jax.lax.scan(body, state, ts)
    return state, raster, tm


# ----------------------------------------------------------------------------
# table-residency accounting (memory tests + weak_scaling suite)
# ----------------------------------------------------------------------------

# bytes per synapse-table slot: src/tgt/delay int32 + plastic/valid bool.
# Matches the materialized ShardPlan per-synapse leaves (syn_src, syn_tgt,
# syn_delay, syn_plastic, syn_valid) so the two modes compare honestly.
TABLE_BYTES_PER_SLOT = 4 + 4 + 4 + 1 + 1


def chunk_table_bytes(spec: SimSpec) -> int:
    """Peak LIVE regenerated-table bytes per shard (one chunk resident)."""
    return spec.stream.k_cap * TABLE_BYTES_PER_SLOT


def metadata_bytes(spec: SimSpec) -> int:
    """Persistent streamed metadata bytes per shard (cand + e_start)."""
    ss = spec.stream
    return ss.n_chunks * ss.c_cap * 4 + (ss.n_chunks + 1) * 4


def streamed_table_bytes(spec: SimSpec) -> int:
    """Peak live synapse-table bytes per shard in streamed mode."""
    return chunk_table_bytes(spec) + metadata_bytes(spec)


def materialized_table_bytes(e_cap: int) -> int:
    """Synapse-table bytes per shard when fully materialized."""
    return e_cap * TABLE_BYTES_PER_SLOT
