from . import attention, common, lm, mlp, moe, recurrent, transformer

__all__ = ["attention", "common", "lm", "mlp", "moe", "recurrent",
           "transformer"]
