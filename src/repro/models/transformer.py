"""The transformer stack: pattern-expanded layers, scan-over-units HLO
compaction, KV/recurrent-state caches, MoE aux-loss plumbing.

Layer structure (pre-norm residual):
    x = x + rs * Mixer(RMSNorm(x))        rs = cfg.residual_scale
    x = x + rs * MLP(RMSNorm(x))

The repeating `cfg.pattern` unit is scanned with stacked params (compact
HLO at any depth — essential for compiling 48-62 layer configs with 512
partitions); the `n_layers % len(pattern)` remainder is unrolled.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import shard
from . import attention, common, mlp as mlp_mod, moe as moe_mod, recurrent


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------


def _init_layer(key, path: str, cfg: ModelConfig, kinds: Tuple[str, str],
                dtype):
    mixer_kind, mlp_kind = kinds
    p: Dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
                         "norm2": jnp.ones((cfg.d_model,), jnp.float32)}
    if mixer_kind in ("ga", "la", "bi"):
        p["mixer"] = attention.init_attn(key, path + "/attn", cfg, dtype)
    elif mixer_kind == "xa":
        p["mixer"] = attention.init_attn(key, path + "/attn", cfg, dtype)
        p["cross"] = attention.init_attn(key, path + "/cross", cfg, dtype)
        p["norm3"] = jnp.ones((cfg.d_model,), jnp.float32)
    elif mixer_kind == "rg":
        p["mixer"] = recurrent.init_rglru(key, path + "/rg", cfg, dtype)
    elif mixer_kind == "rwkv":
        p["mixer"] = recurrent.init_rwkv(key, path + "/rwkv", cfg, dtype)
    else:
        raise ValueError(mixer_kind)

    if mlp_kind == "dense":
        p["mlp"] = mlp_mod.init_mlp(key, path + "/mlp", cfg.d_model,
                                    cfg.d_ff, cfg.act, dtype)
    elif mlp_kind == "moe":
        p["mlp"] = moe_mod.init_moe(key, path + "/moe", cfg.d_model,
                                    cfg.moe, cfg.act, dtype)
    elif mlp_kind == "cmix":
        p["mlp"] = recurrent.init_rwkv_cmix(key, path + "/cmix", cfg, dtype)
    else:
        raise ValueError(mlp_kind)
    return p


def _apply_layer(cfg: ModelConfig, kinds: Tuple[str, str], p, x, positions,
                 cache: Optional[dict], cache_pos, enc_kv) -> Tuple:
    """Returns (x, new_cache, aux)."""
    mixer_kind, mlp_kind = kinds
    rs = cfg.residual_scale
    aux = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}

    h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer_kind in ("ga", "la", "bi"):
        window = cfg.window if mixer_kind == "la" else None
        kv_cache = cache.get("kv") if cache else None
        y, kv_new = attention.attention(
            cfg, p["mixer"], h, positions, causal=(mixer_kind != "bi"),
            window=window, cache=kv_cache, cache_pos=cache_pos)
        if kv_new is not None:
            new_cache["kv"] = kv_new
    elif mixer_kind == "xa":
        kv_cache = cache.get("kv") if cache else None
        y, kv_new = attention.attention(
            cfg, p["mixer"], h, positions, causal=True, cache=kv_cache,
            cache_pos=cache_pos)
        if kv_new is not None:
            new_cache["kv"] = kv_new
        x = x + rs * y
        h = common.rms_norm(x, p["norm3"], cfg.norm_eps)
        y, _ = attention.attention(cfg, p["cross"], h, positions,
                                   causal=False, kv_override=enc_kv)
    elif mixer_kind == "rg":
        st = cache.get("rg") if cache else None
        y, st_new = recurrent.rglru(cfg, p["mixer"], h, st)
        if cache is not None:
            new_cache["rg"] = st_new
    elif mixer_kind == "rwkv":
        st = cache.get("rwkv") if cache else None
        y, st_new = recurrent.rwkv_time_mix(cfg, p["mixer"], h, st)
        if cache is not None:
            new_cache["rwkv"] = st_new
    else:
        raise ValueError(mixer_kind)
    x = x + rs * y
    x = shard(x, "batch", None, None)

    h = common.rms_norm(x, p["norm2"], cfg.norm_eps)
    if mlp_kind == "dense":
        y = mlp_mod.mlp(p["mlp"], h, cfg.act)
    elif mlp_kind == "moe":
        y, aux = moe_mod.moe(p["mlp"], h, cfg.moe, cfg.act)
    elif mlp_kind == "cmix":
        st = cache.get("rwkv") if cache else None
        y, xf_new = recurrent.rwkv_channel_mix(cfg, p["mlp"], h, st)
        if cache is not None and "rwkv" in new_cache:
            new_cache["rwkv"]["xf"] = xf_new
    x = x + rs * y
    x = shard(x, "batch", None, None)
    return x, (new_cache if cache is not None else None), aux


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------


def init_stack(key, path: str, cfg: ModelConfig, dtype):
    """Params: {'units': stacked-over-units pytree, 'rem': [layer dicts]}."""
    pat = cfg.pattern
    n_units = cfg.n_units

    def unit_at(u):
        return {f"layer{i}": _init_layer(
            jax.random.fold_in(key, u), f"{path}/u/l{i}", cfg, pat[i], dtype)
            for i in range(len(pat))}

    units = None
    if n_units > 0:
        units = jax.vmap(unit_at)(jnp.arange(n_units))
    rem = [ _init_layer(jax.random.fold_in(key, 10_000 + r),
                        f"{path}/rem{r}", cfg, cfg.layers[n_units * len(pat) + r],
                        dtype)
            for r in range(cfg.n_remainder)]
    return {"units": units, "rem": rem}


def apply_stack(cfg: ModelConfig, params, x, positions, *,
                caches: Optional[dict] = None, cache_pos=None, enc_kv=None):
    """Returns (x, new_caches, aux_sum)."""
    pat = cfg.pattern
    n_units = cfg.n_units
    decode = caches is not None

    aux_total = jnp.float32(0.0)
    new_caches: Dict[str, Any] = {}

    if n_units > 0:
        # remat at LAYER granularity: backward recomputes one layer at a
        # time from its input — per-unit remat left the whole unit's
        # intermediates live at once (6 layers for gemma3's pattern), which
        # measured 6x worse (EXPERIMENTS.md §Perf)
        def layer_fn(kinds, lp, x, c):
            return _apply_layer(cfg, kinds, lp, x, positions, c, cache_pos,
                                enc_kv)

        if not decode:
            layer_fn = jax.checkpoint(
                layer_fn, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=(0,))

        def unit_fn(carry, xs):
            x, aux = carry
            up, ucache = xs
            new_ucache = {}
            for i, kinds in enumerate(pat):
                c = ucache[f"layer{i}"] if decode else None
                x, nc, a = layer_fn(kinds, up[f"layer{i}"], x, c)
                aux = aux + a
                new_ucache[f"layer{i}"] = nc if decode else 0
            return (x, aux), new_ucache

        if not decode and len(pat) > 1:
            # nested remat for multi-layer units: the scan saves ONE
            # residual per unit; the unit's backward recompute then saves
            # one residual per layer transiently.  Layer-only remat made
            # the scan save len(pat) residuals per unit (gemma3: 96 ->
            # 150 GB, refuted); unit-only remat kept a whole 6-layer
            # backward live set (96 GB).  Nesting gets both bounds.
            unit_fn = jax.checkpoint(
                unit_fn, policy=jax.checkpoint_policies.nothing_saveable)

        ucaches = caches["units"] if decode else jax.tree.map(
            lambda _: jnp.zeros((n_units,)), {f"layer{i}": 0
                                              for i in range(len(pat))})
        (x, aux_total), out_ucaches = jax.lax.scan(
            unit_fn, (x, aux_total), (params["units"], ucaches))
        new_caches["units"] = out_ucaches if decode else None

    new_caches["rem"] = []
    for r in range(cfg.n_remainder):
        kinds = cfg.layers[n_units * len(pat) + r]
        c = caches["rem"][r] if decode else None
        x, nc, a = _apply_layer(cfg, kinds, params["rem"][r], x, positions,
                                c, cache_pos, enc_kv)
        aux_total = aux_total + a
        new_caches["rem"].append(nc)

    return x, (new_caches if decode else None), aux_total


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------


def _layer_cache(cfg: ModelConfig, kinds, batch: int, s_max: int, dtype):
    mixer_kind, mlp_kind = kinds
    c: Dict[str, Any] = {}
    if mixer_kind in ("ga", "la", "xa"):
        s_r = s_max
        if mixer_kind == "la" and cfg.window:
            s_r = min(s_max, cfg.window)   # ring buffer: O(window) memory
        shape = (batch, s_r, cfg.n_kv_heads, cfg.head_dim)
        c["kv"] = (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if mixer_kind == "rg":
        c["rg"] = recurrent.init_rglru_state(cfg, batch, dtype)
    if mixer_kind == "rwkv" or mlp_kind == "cmix":
        c["rwkv"] = recurrent.init_rwkv_state(cfg, batch, dtype)
    return c


def init_caches(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    pat = cfg.pattern
    n_units = cfg.n_units

    def unit_cache(_):
        return {f"layer{i}": _layer_cache(cfg, pat[i], batch, s_max, dtype)
                for i in range(len(pat))}

    units = jax.vmap(unit_cache)(jnp.arange(n_units)) if n_units else None
    rem = [_layer_cache(cfg, cfg.layers[n_units * len(pat) + r], batch,
                        s_max, dtype) for r in range(cfg.n_remainder)]
    return {"units": units, "rem": rem}
