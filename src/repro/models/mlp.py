"""Dense MLP blocks (SwiGLU / GELU / squared-ReLU)."""
from __future__ import annotations


from . import common


def init_mlp(key, path: str, d_model: int, d_ff: int, act: str, dtype):
    p = {
        "w_in": common.dense_init(key, path + "/w_in", (d_model, d_ff),
                                  dtype),
        "w_out": common.dense_init(key, path + "/w_out", (d_ff, d_model),
                                   dtype),
    }
    if act == "swiglu":
        p["w_gate"] = common.dense_init(key, path + "/w_gate",
                                        (d_model, d_ff), dtype)
    return p


def mlp(p, x, act: str):
    h = x @ p["w_in"]
    gate = (x @ p["w_gate"]) if "w_gate" in p else None
    if act == "swiglu":
        h = common.activate(h, gate, "swiglu")
    else:
        h = common.activate(h, gate, act)
    return h @ p["w_out"]
