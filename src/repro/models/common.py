"""Shared model building blocks: reproducible init, norms, rotary, acts.

Init mirrors the paper's reproducible-construction idea: every parameter is
generated from fold_in(key, path-hash) — a pure function of the parameter
name, independent of mesh layout or device count, so any shard can
materialize exactly its slice (and re-materialize it after elastic events).
"""
from __future__ import annotations

import hashlib
from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def path_key(key: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def dense_init(key: jax.Array, path: str, shape: Sequence[int],
               dtype=jnp.bfloat16, scale: Optional[float] = None,
               fan_in_axis: int = -2) -> jnp.ndarray:
    """Truncated-normal fan-in init (1/sqrt(fan_in))."""
    fan_in = shape[fan_in_axis] if len(shape) > 1 else shape[0]
    std = (scale if scale is not None else 1.0) / (fan_in ** 0.5)
    w = jax.random.truncated_normal(path_key(key, path), -3.0, 3.0, shape,
                                    jnp.float32) * std
    return w.astype(dtype)


def embed_init(key: jax.Array, path: str, shape, dtype=jnp.bfloat16):
    """N(0, 1/d): with tied unembedding and an RMS-normed final stream the
    init logits are O(1), so the init loss is ~ln(V) as it should be."""
    std = shape[-1] ** -0.5
    w = jax.random.normal(path_key(key, path), shape, jnp.float32) * std
    return w.astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm in fp32, cast back to x.dtype (gemma uses (1+gamma))."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    g = gamma.astype(jnp.float32)
    if plus_one:
        g = g + 1.0
    return (xn * g).astype(x.dtype)


def layer_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    xn = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xn * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding.  x: [..., T, H, D]; positions: [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # [...,T,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def activate(x: jnp.ndarray, gate: Optional[jnp.ndarray], kind: str
             ) -> jnp.ndarray:
    if kind == "swiglu":
        assert gate is not None
        return jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * x
    if kind == "gelu":
        y = jax.nn.gelu(x.astype(jnp.float32), approximate=True)
        return (y.astype(x.dtype) * gate) if gate is not None \
            else y.astype(x.dtype)
    if kind == "relu2":
        y = jnp.square(jax.nn.relu(x.astype(jnp.float32)))
        return y.astype(x.dtype)
    raise ValueError(kind)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 ignore_id: int = -100):
    """Token-mean cross entropy in fp32; returns (loss, n_tokens)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = labels != ignore_id
    nll = (lse - ll) * mask
    n = jnp.maximum(mask.sum(), 1)
    return nll.sum() / n, n
