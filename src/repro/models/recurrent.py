"""Recurrent mixers: RG-LRU (Griffin / RecurrentGemma) and RWKV-6 (Finch).

Both are the LM-side cousins of the SNN engine's time-driven update: a
per-step state recurrence with data-dependent decay, trained via scan.

  RG-LRU:  h_t = a_t (.) h_{t-1} + sqrt(1 - a_t^2) (.) (i_t (.) x_t),
           a_t = exp(-c softplus(L) (.) r_t); gated conv1d branch as in
           Griffin (arXiv:2402.19427).  Train path uses an associative scan
           (log-depth on TPU); decode carries h.

  RWKV-6:  per-head state S in R^{dk x dv};
           o_t = r_t (S + u (.) k_t^T v_t);  S <- diag(w_t) S + k_t^T v_t,
           with data-dependent per-channel decay w_t via a low-rank MLP
           (arXiv:2404.05892).  Train path scans T; decode carries S.

Decode state (the recurrent 'KV cache'):
  RG-LRU: {h: [B, d_rnn], conv: [B, w-1, d_rnn], xprev? -}
  RWKV-6: {S: [B, H, dk, dv], xa: [B, d], xf: [B, d]}
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import common

_RG_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block)
# ---------------------------------------------------------------------------


def init_rglru(key, path: str, cfg: ModelConfig, dtype):
    d = cfg.d_model
    dr = cfg.rg_lru_width or d
    w = cfg.conv1d_width
    return {
        "w_x": common.dense_init(key, path + "/w_x", (d, dr), dtype),
        "w_gate": common.dense_init(key, path + "/w_gate", (d, dr), dtype),
        "conv_w": common.dense_init(key, path + "/conv_w", (w, dr), dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "wa": common.dense_init(key, path + "/wa", (dr, dr), dtype),
        "ba": jnp.zeros((dr,), jnp.float32),
        "wi": common.dense_init(key, path + "/wi", (dr, dr), dtype),
        "bi": jnp.zeros((dr,), jnp.float32),
        # Lambda init so that a in ~(0.9, 0.999) at r=1 (Griffin B.2)
        "log_lambda": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, dr)) / _RG_C
        )).astype(jnp.float32),
        "w_out": common.dense_init(key, path + "/w_out", (dr, d), dtype),
    }


def _causal_conv1d(x, w, b, state=None):
    """x: [B,T,D]; w: [W,D] depthwise.  state: [B,W-1,D] tail of previous
    tokens (decode).  Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)        # [B, T+W-1, D]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return y.astype(x.dtype), new_state


def _rg_lru_scan(xb, a, h0=None):
    """h_t = a_t*h_{t-1} + b_t.  xb, a: [B,T,D] fp32.

    Dispatches to the sequential VMEM-resident Pallas kernel on TPU
    (kernels/rg_lru.py) and to an associative scan elsewhere."""
    from ..kernels import ops as kops
    return kops.rg_lru_scan(a, xb, h0)


def rglru(cfg: ModelConfig, p, x, state: Optional[dict] = None
          ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B,T,d] -> (y, new_state)."""
    gate = common.activate(x @ p["w_gate"], None, "gelu")
    xi = x @ p["w_x"]
    conv_state = None if state is None else state["conv"]
    xi, new_conv = _causal_conv1d(xi, p["conv_w"], p["conv_b"], conv_state)

    xf = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -_RG_C * jax.nn.softplus(p["log_lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    if state is None:
        h = _rg_lru_scan(b, a)
        new_state = None if x.shape[1] == 0 else {
            "h": h[:, -1], "conv": new_conv}
    else:
        h = _rg_lru_scan(b, a, h0=state["h"])
        new_state = {"h": h[:, -1], "conv": new_conv}

    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    dr = cfg.rg_lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, dr), dtype)}


# ---------------------------------------------------------------------------
# RWKV-6 block (time mix; the channel mix lives in transformer.py as an MLP
# variant with token shift)
# ---------------------------------------------------------------------------


def init_rwkv(key, path: str, cfg: ModelConfig, dtype):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    lora = max(32, d // 32)
    return {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "w_r": common.dense_init(key, path + "/w_r", (d, d), dtype),
        "w_k": common.dense_init(key, path + "/w_k", (d, d), dtype),
        "w_v": common.dense_init(key, path + "/w_v", (d, d), dtype),
        "w_g": common.dense_init(key, path + "/w_g", (d, d), dtype),
        "w_o": common.dense_init(key, path + "/w_o", (d, d), dtype),
        # data-dependent decay LoRA (Finch):  w = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_a": common.dense_init(key, path + "/decay_a", (d, lora),
                                     dtype),
        "decay_b": common.dense_init(key, path + "/decay_b", (lora, d),
                                     dtype),
        "bonus_u": jnp.zeros((H, dh), jnp.float32),
        "ln_gamma": jnp.ones((d,), jnp.float32),
    }


def _token_shift(x, mu, xprev=None):
    """RWKV token shift: lerp(x_{t-1}, x_t, mu).  xprev: [B,d] carry."""
    if xprev is None:
        prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        prev = jnp.concatenate([xprev[:, None].astype(x.dtype),
                                x[:, :-1]], axis=1)
    mu = mu.astype(jnp.float32)
    return (x.astype(jnp.float32) * mu
            + prev.astype(jnp.float32) * (1.0 - mu)).astype(x.dtype)


def rwkv_time_mix(cfg: ModelConfig, p, x, state: Optional[dict] = None
                  ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B,T,d] -> (y, new_state)."""
    B, T, d = x.shape
    dh = cfg.rwkv_head_dim
    H = d // dh
    xprev = None if state is None else state["xa"]

    r = _token_shift(x, p["mu_r"], xprev) @ p["w_r"]
    k = _token_shift(x, p["mu_k"], xprev) @ p["w_k"]
    v = _token_shift(x, p["mu_v"], xprev) @ p["w_v"]
    g = _token_shift(x, p["mu_g"], xprev) @ p["w_g"]
    xw = _token_shift(x, p["mu_w"], xprev)
    dec = p["decay_w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ p["decay_a"].astype(jnp.float32)
    ) @ p["decay_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec))                       # [B,T,d] in (0,1)

    r = r.reshape(B, T, H, dh).astype(jnp.float32)
    k = k.reshape(B, T, H, dh).astype(jnp.float32)
    v = v.reshape(B, T, H, dh).astype(jnp.float32)
    w = w.reshape(B, T, H, dh)
    u = p["bonus_u"]

    s0 = jnp.zeros((B, H, dh, dh), jnp.float32) if state is None \
        else state["S"]

    def step(S, inp):
        rt, kt, vt, wt = inp                          # [B,H,dh]
        kv = kt[..., :, None] * vt[..., None, :]      # [B,H,dk,dv]
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[..., None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    xs = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0))
    S, outs = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(outs, 0, 1).reshape(B, T, d)     # [B,T,d] fp32

    # per-head group norm, silu(g) gate, output projection
    y = y.reshape(B, T, H, dh)
    mu_ = y.mean(-1, keepdims=True)
    var = ((y - mu_) ** 2).mean(-1, keepdims=True)
    y = (y - mu_) * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(B, T, d) * p["ln_gamma"]
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype) @ p["w_o"]

    new_state = None
    if state is not None or True:
        new_state = {"S": S, "xa": x[:, -1]}
    return y, new_state


def init_rwkv_cmix(key, path: str, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "w_k": common.dense_init(key, path + "/w_k", (d, f), dtype),
        "w_v": common.dense_init(key, path + "/w_v", (f, d), dtype),
        "w_r": common.dense_init(key, path + "/w_r", (d, d), dtype),
    }


def rwkv_channel_mix(cfg: ModelConfig, p, x, state: Optional[dict] = None):
    """RWKV-6 channel mix (squared-relu MLP with token shift + r gate)."""
    xprev = None if state is None else state["xf"]
    xk = _token_shift(x, p["mu_k"], xprev)
    xr = _token_shift(x, p["mu_r"], xprev)
    k = jnp.square(jax.nn.relu((xk @ p["w_k"]).astype(jnp.float32)))
    rgate = jax.nn.sigmoid((xr @ p["w_r"]).astype(jnp.float32))
    y = (rgate * (k.astype(x.dtype) @ p["w_v"]).astype(jnp.float32))
    return y.astype(x.dtype), x[:, -1]


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    return {"S": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "xa": jnp.zeros((batch, d), dtype),
            "xf": jnp.zeros((batch, d), dtype)}
