"""Top-level language model: embeddings -> stack -> logits; train loss,
prefill and decode entry points; enc-dec (seamless) and embedding-input
(VLM/audio frontend stub) variants.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import shard
from . import common, transformer


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    p: Dict[str, Any] = {
        "embed": common.embed_init(key, "embed",
                                   (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "stack": transformer.init_stack(key, "stack", cfg, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = common.dense_init(key, "unembed",
                                         (cfg.d_model, cfg.vocab_size),
                                         dtype)
    if cfg.family == "encdec":
        enc_cfg = encoder_view(cfg)
        p["enc_stack"] = transformer.init_stack(key, "enc", enc_cfg, dtype)
        p["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return p


def encoder_view(cfg: ModelConfig) -> ModelConfig:
    """The encoder half of an enc-dec config (bidirectional layers)."""
    return cfg.scaled(n_layers=cfg.n_encoder_layers,
                      pattern=(("bi", "dense"),), family="decoder")


def embed_tokens(cfg: ModelConfig, params, tokens) -> jnp.ndarray:
    x = params["embed"][tokens] * jnp.asarray(cfg.emb_scale,
                                              _dtype(cfg))
    return shard(x, "batch", None, None)


def unembed(cfg: ModelConfig, params, x) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["unembed"]
    return logits * cfg.logit_scale


def encode(cfg: ModelConfig, params, enc_embeds) -> jnp.ndarray:
    """Run the (bidirectional) encoder over frontend embeddings."""
    enc_cfg = encoder_view(cfg)
    pos = jnp.arange(enc_embeds.shape[1])
    h, _, _ = transformer.apply_stack(enc_cfg, params["enc_stack"],
                                      enc_embeds.astype(_dtype(cfg)), pos)
    return common.rms_norm(h, params["enc_norm"], cfg.norm_eps)


def hidden_states(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Embed -> stack -> final norm.  Returns (x [B,T,d], aux_loss)."""
    if "embeds" in batch and cfg.family != "encdec":
        x = shard(batch["embeds"].astype(_dtype(cfg)), "batch", None, None)
    else:
        x = embed_tokens(cfg, params, batch["tokens"])
    T = x.shape[1]
    pos = jnp.arange(T)

    enc_kv = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params, batch["enc_embeds"])
        enc_kv = enc_out  # per-layer kv computed lazily below

    x, _, aux = transformer.apply_stack(
        cfg, params["stack"], x, pos,
        enc_kv=_EncOut(enc_kv) if enc_kv is not None else None)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def forward(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/prefill forward.  Returns (logits, aux_loss).

    batch keys: 'tokens' [B,T] (text) or 'embeds' [B,T,d] (vlm/audio stub);
    encdec additionally 'enc_embeds' [B,S,d].
    """
    x, aux = hidden_states(cfg, params, batch)
    return unembed(cfg, params, x), aux


class _EncOut:
    """Lazy cross-attention source understood by attention.attention:
    K/V are computed from .enc_out with each layer's own projections
    (avoids materializing every layer's cross K/V at once under scan)."""

    def __init__(self, enc_out):
        self.enc_out = enc_out


def loss_fn(cfg: ModelConfig, params, batch, aux_weight: float = 0.01,
            xent_chunk: int = 512):
    """Sequence-chunked cross entropy: the [B, T, V] fp32 logits tensor is
    never materialized (at gemma3's 262k vocab it is ~4.3 GB/device at 4k x
    bs16 even sharded); each T-chunk's logits are computed, reduced, and
    rematerialized in the backward pass (EXPERIMENTS.md §Perf)."""
    x, aux = hidden_states(cfg, params, batch)
    labels = batch["labels"]
    B, T, d = x.shape
    c = min(xent_chunk, T)
    while T % c:
        c -= 1

    def chunk_nll(h_c, y_c):
        logits = unembed(cfg, params, h_c).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        mask = y_c != -100
        return ((lse - ll) * mask).sum(), mask.sum()

    chunk_nll = jax.checkpoint(chunk_nll)

    if c == T:
        nll, n = chunk_nll(x, labels)
    else:
        xs = (jnp.moveaxis(x.reshape(B, T // c, c, d), 1, 0),
              jnp.moveaxis(labels.reshape(B, T // c, c), 1, 0))

        def body(carry, xc):
            s, n = carry
            ds, dn = chunk_nll(*xc)
            return (s + ds, n + dn), None

        (nll, n), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.int32(0)), xs)

    loss = nll / jnp.maximum(n, 1)
    return loss + aux_weight * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               enc_out: Optional[jnp.ndarray] = None,
               per_slot: bool = False):
    """per_slot=True gives cache['pos'] shape [batch]: each slot carries
    its own position (continuous batching with per-slot refill)."""
    caches = transformer.init_caches(cfg, batch, s_max, _dtype(cfg))
    pos = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return {"layers": caches, "enc_out": enc_out, "pos": pos}


def prefill(cfg: ModelConfig, params, cache, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Any]:
    """Process a whole prompt from cache['pos']==0: fill the caches and
    return ONLY the last position's logits [B, V] (the full [B, T, V]
    logits tensor is never materialized — at 32k x 262k vocab it wouldn't
    fit anything)."""
    if "embeds" in batch and cfg.family != "encdec":
        x = shard(batch["embeds"].astype(_dtype(cfg)), "batch", None, None)
    else:
        x = embed_tokens(cfg, params, batch["tokens"])
    T = x.shape[1]
    enc_out = cache.get("enc_out")
    if cfg.family == "encdec" and "enc_embeds" in batch:
        enc_out = encode(cfg, params, batch["enc_embeds"])
    enc_kv = _EncOut(enc_out) if enc_out is not None else None
    pos = cache["pos"] + jnp.arange(T)
    x, new_layers, _ = transformer.apply_stack(
        cfg, params["stack"], x, pos, caches=cache["layers"],
        cache_pos=cache["pos"], enc_kv=enc_kv)
    xl = common.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, xl)[:, 0]
    new_cache = {"layers": new_layers, "enc_out": enc_out,
                 "pos": cache["pos"] + T}
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Any]:
    """One decode step: tokens [B, Tq] (Tq=1 usually).

    Positions/cache offset come from cache['pos'] — scalar (lockstep
    slots) or [B] (per-slot serving positions).
    """
    x = embed_tokens(cfg, params, tokens)
    # scalar pos -> [Tq] (as before); per-slot [B] pos -> [B, Tq]
    pos = cache["pos"][..., None] + jnp.arange(tokens.shape[1])
    enc_kv = _EncOut(cache["enc_out"]) if cache.get("enc_out") is not None \
        else None
    x, new_layer_caches, _ = transformer.apply_stack(
        cfg, params["stack"], x, pos, caches=cache["layers"],
        cache_pos=cache["pos"], enc_kv=enc_kv)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)
    new_cache = {"layers": new_layer_caches, "enc_out": cache.get("enc_out"),
                 "pos": cache["pos"] + tokens.shape[1]}
    return logits, new_cache


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
