"""GQA attention: grouped heads (no kv repetition), rotary, optional
qk-norm / sliding window / logit softcap; flash-style chunked computation in
pure jnp (memory-safe lowering at 32k+), Pallas kernel dispatch on TPU.

Layouts:
  x          [B, T, d]
  q          [B, T, H, dh]     ->  grouped [B, Hkv, G, T, dh]
  k, v       [B, S, Hkv, dh]
  kv cache   [B, S_max, Hkv, dh] (sequence-shardable for long decode)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import common

NEG_INF = -1.0e30


def init_attn(key, path: str, cfg: ModelConfig, dtype):
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": common.dense_init(key, path + "/wq", (d, H * dh), dtype),
        "wk": common.dense_init(key, path + "/wk", (d, Hkv * dh), dtype),
        "wv": common.dense_init(key, path + "/wv", (d, Hkv * dh), dtype),
        "wo": common.dense_init(key, path + "/wo", (H * dh, d), dtype),
    }
    if cfg.qk_norm:
        p["q_gamma"] = jnp.ones((dh,), dtype)
        p["k_gamma"] = jnp.ones((dh,), dtype)
    return p


def _pick_chunk(n: int, target: int) -> int:
    c = min(target, n)
    while n % c:
        c -= 1
    return c


# Block pruning (beyond-paper perf pass, EXPERIMENTS.md §Perf): when True,
# the chunked path enumerates only the (q_chunk, kv_chunk) pairs that the
# causal/window mask can reach — ~2x fewer FLOPs for causal, O(T*W) instead
# of O(T*S) for sliding-window layers.  Baselines in §Perf were taken with
# this False.
BLOCK_PRUNE = True


def _visible(i, j, cq, ck, q_offset, causal, window):
    """Can kv chunk j contribute to q chunk i at all?"""
    q_lo = i * cq + q_offset
    q_hi = q_lo + cq - 1
    k_lo = j * ck
    k_hi = k_lo + ck - 1
    if causal and k_lo > q_hi:
        return False
    # the weakest window constraint in the chunk comes from the earliest
    # query row: kpos > q_lo - window for some kpos in the kv chunk
    if window is not None and k_hi <= q_lo - window:
        return False
    return True


def _chunked_gqa_pruned(q, k, v, *, causal: bool, window: Optional[int],
                        softcap: Optional[float], scale: float,
                        q_offset: int, chunk_q: int = 512,
                        chunk_k: int = 1024):
    """Flash-style attention over the statically-pruned visible chunk-pair
    list.  One scan over pairs ordered (i asc, j asc); the running softmax
    state resets at each new i and the finished q chunk is written into the
    output carry at its last pair."""
    b, hkv, g, t, dh = q.shape
    s = k.shape[1]
    cq = _pick_chunk(t, chunk_q)
    ck = _pick_chunk(s, chunk_k)
    nq, nk = t // cq, s // ck
    k_ = jnp.transpose(k, (0, 2, 1, 3))           # [B, Hkv, S, dh]
    v_ = jnp.transpose(v, (0, 2, 1, 3))

    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if _visible(i, j, cq, ck, q_offset, causal, window)]
    if not pairs:                                 # degenerate: all masked
        return jnp.zeros_like(q)
    ii = jnp.array([p[0] for p in pairs], jnp.int32)
    jj = jnp.array([p[1] for p in pairs], jnp.int32)
    first = jnp.array([l == 0 or pairs[l][0] != pairs[l - 1][0]
                       for l in range(len(pairs))])
    last = jnp.array([l == len(pairs) - 1
                      or pairs[l][0] != pairs[l + 1][0]
                      for l in range(len(pairs))])

    def body(carry, xs):
        m, l, acc, out = carry
        i, j, fst, lst = xs
        m = jnp.where(fst, jnp.full_like(m, NEG_INF), m)
        l = jnp.where(fst, jnp.zeros_like(l), l)
        acc = jnp.where(fst, jnp.zeros_like(acc), acc)

        qi = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=3)
        kj = jax.lax.dynamic_slice_in_dim(k_, j * ck, ck, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v_, j * ck, ck, axis=2)

        sc = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                        kj.astype(jnp.float32)) * scale
        if softcap is not None:
            sc = softcap * jnp.tanh(sc / softcap)
        qpos = i * cq + jnp.arange(cq)[:, None] + q_offset
        kpos = j * ck + jnp.arange(ck)[None, :]
        mask = jnp.ones((cq, ck), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)

        m_new = jnp.maximum(m, sc.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32))

        safe = jnp.where(l_new == 0.0, 1.0, l_new)
        done = (acc_new / safe[..., None]).astype(q.dtype)
        out = jax.lax.cond(
            lst,
            lambda o: jax.lax.dynamic_update_slice_in_dim(
                o, done, i * cq, axis=3),
            lambda o: o, out)
        return (m_new, l_new, acc_new, out), None

    m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, cq, dh), jnp.float32)
    out0 = jnp.zeros_like(q)
    (_, _, _, out), _ = jax.lax.scan(body, (m0, l0, a0, out0),
                                     (ii, jj, first, last))
    return out


def _chunked_gqa(q, k, v, *, causal: bool, window: Optional[int],
                 softcap: Optional[float], scale: float, q_offset: int,
                 chunk_q: int = 512, chunk_k: int = 1024):
    if BLOCK_PRUNE and (causal or window is not None):
        return _chunked_gqa_pruned(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, chunk_q=chunk_q,
            chunk_k=chunk_k)
    return _chunked_gqa_dense(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale,
                              q_offset=q_offset, chunk_q=chunk_q,
                              chunk_k=chunk_k)


def _chunked_gqa_dense(q, k, v, *, causal: bool, window: Optional[int],
                       softcap: Optional[float], scale: float,
                       q_offset: int, chunk_q: int = 512,
                       chunk_k: int = 1024):
    """Flash-style two-level scan, O(cq*ck) peak score memory.

    q: [B, Hkv, G, T, dh];  k, v: [B, S, Hkv, dh].  Returns like q.
    """
    b, hkv, g, t, dh = q.shape
    s = k.shape[1]
    cq = _pick_chunk(t, chunk_q)
    ck = _pick_chunk(s, chunk_k)
    k_ = jnp.transpose(k, (0, 2, 1, 3))           # [B, Hkv, S, dh]
    v_ = jnp.transpose(v, (0, 2, 1, 3))           # [B, Hkv, S, dh]

    q_chunks = q.reshape(b, hkv, g, t // cq, cq, dh)
    q_chunks = jnp.moveaxis(q_chunks, 3, 0)       # [nq, B, Hkv, G, cq, dh]
    k_chunks = jnp.moveaxis(k_.reshape(b, hkv, s // ck, ck, dh), 2, 0)
    v_chunks = jnp.moveaxis(v_.reshape(b, hkv, s // ck, ck, dh), 2, 0)

    def q_body(_, qi_i):
        qi, i = qi_i
        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, dh), jnp.float32)

        def kv_body(carry, kvj_j):
            m, l, acc = carry
            (kj, vj), j = kvj_j
            sc = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                            kj.astype(jnp.float32)) * scale
            if softcap is not None:
                sc = softcap * jnp.tanh(sc / softcap)
            qpos = i * cq + jnp.arange(cq)[:, None] + q_offset
            kpos = j * ck + jnp.arange(ck)[None, :]
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        js = jnp.arange(s // ck)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      ((k_chunks, v_chunks), js))
        safe = jnp.where(l == 0.0, 1.0, l)
        return None, (acc / safe[..., None]).astype(q.dtype)

    is_ = jnp.arange(t // cq)
    _, out = jax.lax.scan(q_body, None, (q_chunks, is_))
    out = jnp.moveaxis(out, 0, 3)                 # [B,Hkv,G,nq,cq,dh]
    return out.reshape(b, hkv, g, t, dh)


def _direct_gqa(q, k, v, *, causal, window, softcap, scale, q_offset):
    """Small-shape einsum path (decode steps, smoke tests)."""
    b, hkv, g, t, dh = q.shape
    s = k.shape[1]
    sc = jnp.einsum("bhgqd,bshd->bhgqs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = jnp.arange(t)[:, None] + q_offset
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bhgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention(cfg: ModelConfig, p, x, positions, *, causal: bool = True,
              window: Optional[int] = None, cache: Optional[Tuple] = None,
              cache_pos=None, kv_override=None, chunk_q: int = 512,
              chunk_k: int = 1024):
    """Full attention block.  Returns (y [B,T,d], new_cache or None).

    cache: (k_cache, v_cache) each [B, S_max, Hkv, dh]; cache_pos: write
    offset (tokens already in cache) — a scalar when all rows advance in
    lockstep, or [B] for per-slot serving (continuous batching: each slot
    carries its own position).  kv_override: precomputed (k, v) for
    cross-attention.
    """
    B, T, d = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv

    q = (x @ p["wq"]).reshape(B, T, H, dh)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(B, T, Hkv, dh)
        v = (x @ p["wv"]).reshape(B, T, Hkv, dh)
    elif isinstance(kv_override, tuple):
        k, v = kv_override
    else:
        # lazy cross-attention source: an object with .enc_out [B,S,d];
        # K/V are computed with this layer's own projections.
        enc = kv_override.enc_out
        S = enc.shape[1]
        k = (enc @ p["wk"]).reshape(B, S, Hkv, dh)
        v = (enc @ p["wv"]).reshape(B, S, Hkv, dh)

    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_gamma"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_gamma"], cfg.norm_eps)

    if kv_override is None:
        q = common.rope(q, positions, cfg.rope_theta)
        k = common.rope(k, positions, cfg.rope_theta)

    # ----- KV cache: unified ring buffer -----------------------------------
    # The cache holds S_r slots; absolute position p lives in slot p % S_r.
    # For global layers S_r == s_max so slot == p (plain linear cache);
    # for sliding-window layers S_r == window, so the buffer stores exactly
    # the live window at O(window) memory — this is what makes long_500k
    # caches fit (DESIGN.md).
    new_cache = None
    attend_from_cache = False
    if cache is not None:
        kc, vc = cache
        S_r = kc.shape[1]
        # [..., None] keeps the scalar case a plain [Tw] vector and makes
        # a [B] cache_pos broadcast to per-slot [B, Tw] write positions
        pw = cache_pos[..., None] + jnp.arange(min(T, S_r))
        if T > S_r:                     # only the last S_r tokens survive
            k_w, v_w = k[:, -S_r:], v[:, -S_r:]
            pw = cache_pos[..., None] + T - S_r + jnp.arange(S_r)
        else:
            k_w, v_w = k, v
        slots = jnp.mod(pw, S_r)
        if slots.ndim == 1:
            kc = kc.at[:, slots].set(k_w.astype(kc.dtype))
            vc = vc.at[:, slots].set(v_w.astype(vc.dtype))
        else:                           # per-slot offsets: row b at slots[b]
            bi = jnp.arange(B)[:, None]
            kc = kc.at[bi, slots].set(k_w.astype(kc.dtype))
            vc = vc.at[bi, slots].set(v_w.astype(vc.dtype))
        new_cache = (kc, vc)
        if T == 1:
            attend_from_cache = True    # decode: read the ring
        # prefill (T > 1): attend over the fresh k/v below (assumes the
        # prompt starts at cache_pos == 0, which all serving paths satisfy)

    qg = jnp.transpose(q.reshape(B, T, Hkv, G, dh), (0, 2, 3, 1, 4))
    scale = dh ** -0.5

    if attend_from_cache:
        kc, vc = new_cache
        S_r = kc.shape[1]
        qpos = cache_pos[..., None] + jnp.arange(T)     # [T] or [B, T]
        last = cache_pos + T - 1
        slot_i = jnp.arange(S_r)
        # most recent absolute position stored in slot i
        kpos = last[..., None] - jnp.mod(last[..., None] - slot_i, S_r)
        out = _decode_gqa(qg, kc, vc, causal=causal, window=window,
                          softcap=cfg.softcap, scale=scale, qpos=qpos,
                          kpos=kpos)
    else:
        s_len = k.shape[1]
        if cache is not None:
            # prefill always starts at position 0 (documented serving-path
            # invariant); a static offset keeps block pruning static
            q_offset = 0
        elif kv_override is not None:
            causal = False
            q_offset = 0
        else:
            q_offset = s_len - T
        big = (T * s_len) > (1024 * 2048)
        if big:
            # flash-style backward: recompute the blockwise attention in
            # the bwd pass instead of saving per-chunk softmax state —
            # without this, AD through the nested scans stores
            # O(T/cq * S/ck) running accumulators (measured 96-212 GB/dev
            # on the 32k cells; see EXPERIMENTS.md §Perf iteration 1).
            import functools as _ft
            chunked = jax.checkpoint(_ft.partial(
                _chunked_gqa, causal=causal, window=window,
                softcap=cfg.softcap, scale=scale, q_offset=q_offset,
                chunk_q=chunk_q, chunk_k=chunk_k))
            out = chunked(qg, k, v)
        else:
            out = _direct_gqa(qg, k, v, causal=causal, window=window,
                              softcap=cfg.softcap, scale=scale,
                              q_offset=q_offset)

    y = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, T, H * dh)
    return (y @ p["wo"]), new_cache


def _decode_gqa(q, k, v, *, causal, window, softcap, scale, qpos, kpos):
    """Cache read with explicit absolute position arrays (ring-aware).

    qpos: [T] (or per-slot [B, T]) absolute query positions; kpos: [S]
    (or [B, S]) absolute position stored in each cache slot
    (negative/stale slots masked by the causal+window conditions)."""
    b, hkv, g, t, dh = q.shape
    s = k.shape[1]
    sc = jnp.einsum("bhgqd,bshd->bhgqs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    shp = jnp.broadcast_shapes(qp.shape, kp.shape)   # [T,S] or [B,T,S]
    mask = (kp <= qp) if causal else jnp.ones(shp, bool)
    mask = mask & (kp >= 0)
    if window is not None:
        mask &= kp > qp - window
    if mask.ndim == 2:
        mask = mask[None]
    sc = jnp.where(mask[:, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bhgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
