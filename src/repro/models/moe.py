"""Mixture-of-Experts layer with two-phase, capacity-bounded dispatch.

The dispatch deliberately mirrors the paper's AER spike delivery (DESIGN.md
§Arch-applicability): routing produces a sparse, data-dependent
communication pattern; we exchange *counts* implicitly via a static-capacity
buffer per expert (the SPMD analogue of the spike-counter phase) and move
only payload tokens (gather), never one-hot matmuls — so dispatch costs
bytes, not FLOPs, and `cost_analysis` reflects the true active compute
(6·N_active·D).

Experts are sharded over the `model` ('expert-parallel') mesh axis; token
gather/scatter across shards lowers to all-to-all-like collectives under
GSPMD, again matching the paper's two-step MPI_Alltoallv structure.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from ..dist.sharding import axis_size, shard
from . import common


def init_moe(key, path: str, d_model: int, mcfg: MoEConfig, act: str, dtype):
    E, f = mcfg.n_experts, mcfg.d_ff_expert
    p = {
        "router": common.dense_init(key, path + "/router", (d_model, E),
                                    jnp.float32),
        "w_in": common.dense_init(key, path + "/w_in", (E, d_model, f),
                                  dtype),
        "w_out": common.dense_init(key, path + "/w_out", (E, f, d_model),
                                   dtype),
    }
    if act == "swiglu":
        p["w_gate"] = common.dense_init(key, path + "/w_gate",
                                        (E, d_model, f), dtype)
    if mcfg.shared_expert:
        p["s_in"] = common.dense_init(key, path + "/s_in", (d_model, f),
                                      dtype)
        p["s_out"] = common.dense_init(key, path + "/s_out", (f, d_model),
                                       dtype)
        if act == "swiglu":
            p["s_gate"] = common.dense_init(key, path + "/s_gate",
                                            (d_model, f), dtype)
    return p


def _expert_ffn(p, x_e, act: str):
    """x_e: [E, C, d] -> [E, C, d], per-expert weights."""
    h = jnp.einsum("ecd,edf->ecf", x_e, p["w_in"])
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", x_e, p["w_gate"])
        h = common.activate(h, g, "swiglu")
    else:
        h = common.activate(h, None, "gelu")
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def _row_dispatch(xr, idx, E: int, C: int, K: int):
    """Per-batch-row dispatch (runs under vmap; B rows stay data-local).

    xr [T, d]; idx [T, K].  Sort-by-expert = the paper's counter phase;
    capacity slots = the fixed AER buffer; overflow drops like AER
    saturation."""
    T, d = xr.shape
    flat_e = idx.reshape(-1)                                 # [T*K]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank = jnp.arange(T * K) - seg_start[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)
    token_of = (order // K).astype(jnp.int32)
    table = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        token_of, mode="drop")[:E * C]
    tok_valid = table < T
    x_e = jnp.take(xr, jnp.minimum(table, T - 1), axis=0)
    x_e = jnp.where(tok_valid[:, None], x_e, 0).reshape(E, C, d)
    return x_e, (order, sorted_e, rank, keep, token_of)


def _row_combine(y_e, gates, dispatch, T: int, C: int, dtype):
    order, sorted_e, rank, keep, token_of = dispatch
    gate_of = gates.reshape(-1)[order]
    gate_slot = jnp.where(keep, gate_of, 0.0)
    y_flat = y_e.reshape(-1, y_e.shape[-1])
    contrib = y_flat[jnp.where(keep, sorted_e * C + rank, 0)] \
        * gate_slot[:, None].astype(y_flat.dtype)
    return jnp.zeros((T, y_e.shape[-1]), dtype).at[token_of].add(
        contrib.astype(dtype), mode="drop")


def moe(p, x, mcfg: MoEConfig, act: str, *, router_key=None
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, d] -> (y, aux_loss).

    Routing/dispatch are vmapped PER BATCH ROW, so token gather/scatter
    never crosses the data shards (the global-dispatch formulation moved
    ~N*K*d bytes through per-layer all-gathers — 21 TB/device/step for
    granite; EXPERIMENTS.md §Perf).  Cross-shard movement happens only via
    the x_e sharding constraint: when E divides the 'experts' axis this is
    the canonical EP all-to-all; otherwise expert compute stays data-local
    (per-expert weights are small when E is odd-sized) with one psum after
    w_out.
    """
    B, T, d = x.shape
    E, K = mcfg.n_experts, mcfg.top_k
    N = B * T
    xf = x.reshape(N, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                     # [N, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ----- load-balancing auxiliary loss (Switch/GShard form) -----
    me = probs.mean(axis=0)                                  # [E]
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (N * K))
    aux = E * jnp.sum(me * ce)

    # per-row capacity (padded to a multiple of 8)
    C = int(mcfg.capacity_factor * T * K / E) or 1
    C = min(-(-C // 8) * 8, T * K)

    idx_r = idx.reshape(B, T, K)
    gates_r = gates.reshape(B, T, K).astype(x.dtype)
    x_e, dispatch = jax.vmap(
        lambda xr, ir: _row_dispatch(xr, ir, E, C, K)
    )(x, idx_r)                                              # [B, E, C, d]

    ep = E % max(axis_size("experts"), 1) == 0
    x_e = shard(x_e, None if ep else "batch", "experts", None, None)
    h = jnp.einsum("becd,edf->becf", x_e, p["w_in"])
    if "w_gate" in p:
        g = jnp.einsum("becd,edf->becf", x_e, p["w_gate"])
        h = common.activate(h, g, "swiglu")
    else:
        h = common.activate(h, None, "gelu")
    y_e = jnp.einsum("becf,efd->becd", h, p["w_out"])
    y_e = shard(y_e, None if ep else "batch", "experts", None, None)

    y = jax.vmap(
        lambda ye, gr, dp: _row_combine(ye, gr, dp, T, C, x.dtype)
    )(y_e, gates_r, dispatch)                                # [B, T, d]
    y = shard(y, "batch", None, None)

    if mcfg.shared_expert:
        h = xf @ p["s_in"]
        if "s_gate" in p:
            h = common.activate(h, xf @ p["s_gate"], "swiglu")
        else:
            h = common.activate(h, None, "gelu")
        y = y + (h @ p["s_out"]).reshape(B, T, d)

    return y, aux
