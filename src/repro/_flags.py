"""Stdlib-only env-flag helpers, importable before any jax import.

Kept outside `repro.dist` because that package imports jax at load time;
launch scripts must be able to mutate XLA_FLAGS first.
"""
from __future__ import annotations

import os


def force_host_device_count(n: int, current: str | None = None) -> str:
    """XLA_FLAGS value forcing `n` logical host devices.

    APPENDS to the existing flags: XLA parses duplicated flags last-wins,
    so the count requested here overrides any ambient CI-level forced
    device count."""
    cur = os.environ.get("XLA_FLAGS", "") if current is None else current
    return f"{cur} --xla_force_host_platform_device_count={n}".strip()


def subprocess_env(n_devices: int, src_path: str) -> dict:
    """Environment for a fresh-interpreter jax subprocess: `n_devices`
    forced host devices (overriding any ambient forced count) and
    `src_path` prepended to PYTHONPATH so `repro` imports uninstalled.

    Shared by tests/_mp_helpers.py, repro.bench.subproc and
    repro.cluster.local so their subprocess environments cannot drift
    apart."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = force_host_device_count(
        n_devices, env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = src_path + os.pathsep + env.get("PYTHONPATH", "")
    return env


# Coordinator wiring for multi-process (cluster) workers.  The names are
# repo-private so an ambient MPI/SLURM environment can never half-configure
# a worker; repro.cluster.runtime reads exactly these three.
ENV_COORD = "REPRO_CLUSTER_COORD"        # "host:port" of process 0
ENV_NUM_PROCS = "REPRO_CLUSTER_NPROCS"   # total process count
ENV_PROC_ID = "REPRO_CLUSTER_PROC_ID"    # this worker's rank


def cluster_env(n_devices: int, src_path: str, *, coordinator: str,
                num_processes: int, process_id: int) -> dict:
    """`subprocess_env` plus the coordinator variables a cluster worker
    needs to join a `jax.distributed` job, and gloo CPU collectives so
    cross-process `ppermute`/`all_gather` work on the host backend (the
    variable is ignored by jax versions without the option and by non-CPU
    backends)."""
    env = subprocess_env(n_devices, src_path)
    env[ENV_COORD] = coordinator
    env[ENV_NUM_PROCS] = str(num_processes)
    env[ENV_PROC_ID] = str(process_id)
    env.setdefault("JAX_CPU_COLLECTIVES_IMPLEMENTATION", "gloo")
    return env
