"""Stdlib-only env-flag helpers, importable before any jax import.

Kept outside `repro.dist` because that package imports jax at load time;
launch scripts must be able to mutate XLA_FLAGS first.
"""
from __future__ import annotations

import os


def force_host_device_count(n: int, current: str | None = None) -> str:
    """XLA_FLAGS value forcing `n` logical host devices.

    APPENDS to the existing flags: XLA parses duplicated flags last-wins,
    so the count requested here overrides any ambient CI-level forced
    device count."""
    cur = os.environ.get("XLA_FLAGS", "") if current is None else current
    return f"{cur} --xla_force_host_platform_device_count={n}".strip()


def subprocess_env(n_devices: int, src_path: str) -> dict:
    """Environment for a fresh-interpreter jax subprocess: `n_devices`
    forced host devices (overriding any ambient forced count) and
    `src_path` prepended to PYTHONPATH so `repro` imports uninstalled.

    Shared by tests/_mp_helpers.py and benchmarks/_util.py so their
    subprocess environments cannot drift apart."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = force_host_device_count(
        n_devices, env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = src_path + os.pathsep + env.get("PYTHONPATH", "")
    return env
