"""Stdlib-only env-flag helpers, importable before any jax import.

Kept outside `repro.dist` because that package imports jax at load time;
launch scripts must be able to mutate XLA_FLAGS first.
"""
from __future__ import annotations

import os


def force_host_device_count(n: int, current: str | None = None) -> str:
    """XLA_FLAGS value forcing `n` logical host devices.

    APPENDS to the existing flags: XLA parses duplicated flags last-wins,
    so the count requested here overrides any ambient CI-level forced
    device count."""
    cur = os.environ.get("XLA_FLAGS", "") if current is None else current
    return f"{cur} --xla_force_host_platform_device_count={n}".strip()


def subprocess_env(n_devices: int, src_path: str) -> dict:
    """Environment for a fresh-interpreter jax subprocess: `n_devices`
    forced host devices (overriding any ambient forced count) and
    `src_path` prepended to PYTHONPATH so `repro` imports uninstalled.

    Shared by tests/_mp_helpers.py, repro.bench.subproc and
    repro.cluster.local so their subprocess environments cannot drift
    apart."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = force_host_device_count(
        n_devices, env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = src_path + os.pathsep + env.get("PYTHONPATH", "")
    return env


# Coordinator wiring for multi-process (cluster) workers.  The names are
# repo-private so an ambient MPI/SLURM environment can never half-configure
# a worker; repro.cluster.runtime reads exactly these three.
ENV_COORD = "REPRO_CLUSTER_COORD"        # "host:port" of process 0
ENV_NUM_PROCS = "REPRO_CLUSTER_NPROCS"   # total process count
ENV_PROC_ID = "REPRO_CLUSTER_PROC_ID"    # this worker's rank


def cluster_env(n_devices: int, src_path: str, *, coordinator: str,
                num_processes: int, process_id: int,
                tuned: bool = False) -> dict:
    """`subprocess_env` plus the coordinator variables a cluster worker
    needs to join a `jax.distributed` job, and gloo CPU collectives so
    cross-process `ppermute`/`all_gather` work on the host backend (the
    variable is ignored by jax versions without the option and by non-CPU
    backends).  `tuned=True` overlays `tuned_host_env` (opt-in host-
    runtime tuning, A/B-comparable via the REPRO_TUNED_ENV marker)."""
    env = subprocess_env(n_devices, src_path)
    env[ENV_COORD] = coordinator
    env[ENV_NUM_PROCS] = str(num_processes)
    env[ENV_PROC_ID] = str(process_id)
    env.setdefault("JAX_CPU_COLLECTIVES_IMPLEMENTATION", "gloo")
    if tuned:
        env.update(tuned_host_env())
    return env


# Known install locations of gperftools' tcmalloc on the distros the
# benchmark targets (the classic JAX-on-CPU launch-script preset: malloc
# pressure from host-side plan construction and per-step dispatch is real,
# and tcmalloc's thread caches are measurably faster than glibc's arena
# malloc for it).
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib64/libtcmalloc.so.4",
    "/usr/lib64/libtcmalloc_minimal.so.4",
)

ENV_TUNED = "REPRO_TUNED_ENV"            # "1" when the preset is active


def find_tcmalloc() -> str | None:
    """First installed tcmalloc shared object, or None."""
    for p in TCMALLOC_CANDIDATES:
        if os.path.exists(p):
            return p
    return None


def tuned_host_env() -> dict:
    """Opt-in host-runtime tuning preset (cluster `--tuned-env`).

    LD_PRELOADs tcmalloc when installed (skipped silently otherwise — the
    preset must never break a launch), silences the large-alloc reporter
    and TF logging on the hot path.  Deliberately contains NO XLA flag
    that could alter compilation or numerics: the preset must keep the
    Table 1 invariant byte-exact, so it tunes only the host runtime
    around the compiled programs.  REPRO_TUNED_ENV=1 marks the worker so
    its result JSON records which A/B arm it ran in."""
    env = {
        "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
        "TF_CPP_MIN_LOG_LEVEL": "4",
        ENV_TUNED: "1",
    }
    tc = find_tcmalloc()
    if tc:
        prev = os.environ.get("LD_PRELOAD", "")
        env["LD_PRELOAD"] = f"{tc}:{prev}" if prev else tc
    return env
