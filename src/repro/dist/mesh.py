"""Mesh construction.

FUNCTIONS, not module-level constants: importing this module never touches
jax device state (the dry-run driver must set XLA_FLAGS before any jax
initialization)."""
from __future__ import annotations

from jax.sharding import Mesh

from .compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_snn_mesh(n_shards: int) -> Mesh:
    """The SNN engine is space-parallel only: one flat 'cells' axis."""
    return make_mesh((n_shards,), ("cells",))
