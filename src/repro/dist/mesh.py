"""Mesh construction.

FUNCTIONS, not module-level constants: importing this module never touches
jax device state (the dry-run driver must set XLA_FLAGS before any jax
initialization).

Meshes are built from `jax.devices()`, which is the *global* device list:
once `repro.cluster.runtime` has initialized `jax.distributed`, the same
constructors return process-spanning meshes and every collective routed
over them becomes genuine inter-process communication.  Sharding rules
(`repro.dist.sharding`) are unchanged by this — they only name logical
axes and never ask which process owns a device."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from .compat import make_mesh


def spans_processes(mesh: Mesh) -> bool:
    """True when `mesh` contains devices owned by another process (arrays
    sharded over it are only partially addressable here)."""
    here = jax.process_index()
    return any(d.process_index != here for d in mesh.devices.flat)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_snn_mesh(n_shards: int) -> Mesh:
    """The SNN engine is space-parallel only: one flat 'cells' axis.

    In a cluster job the `cells` axis runs across all processes' devices
    (process p contributes devices [p*H/P, (p+1)*H/P) of the axis)."""
    total = jax.device_count()
    if n_shards > total:
        raise ValueError(
            f"make_snn_mesh: {n_shards} shards > {total} global devices "
            f"(force more with XLA_FLAGS or launch more processes)")
    return make_mesh((n_shards,), ("cells",))
