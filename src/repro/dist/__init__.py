"""repro.dist — the one mesh-and-spec layer shared by every workload.

The SNN engine (space-parallel `cells` axis), the LM stack
(`data`/`model`/`pod` axes) and the dry-run driver all build meshes and
partition specs through this package:

  sharding — logical constraint application (`shard`), divisibility-aware
      rule fitting (`_fit`) and path+shape spec inference
      (`infer_param_spec` / `infer_cache_spec` / `infer_batch_spec`),
      plus the `use_mesh` context that binds a mesh to the former.
  mesh — mesh constructors (production 16x16 / 2x16x16, flat SNN `cells`).
  compat — `shard_map` across the jax versions we support (the keyword
      for replication checking moved between releases).
"""
from . import compat, mesh, sharding
from .compat import process_allgather, shard_map
from .mesh import make_production_mesh, make_snn_mesh, spans_processes
from .sharding import (NamedSharding, P, axis_size, global_put,
                       infer_batch_spec, infer_cache_spec, infer_param_spec,
                       replicated_put, shard, shard_put, tree_shardings,
                       use_mesh)

__all__ = [
    "compat", "mesh", "sharding", "process_allgather", "shard_map",
    "make_production_mesh", "make_snn_mesh", "spans_processes",
    "NamedSharding", "P", "axis_size", "global_put", "infer_batch_spec",
    "infer_cache_spec", "infer_param_spec", "replicated_put", "shard",
    "shard_put", "tree_shardings", "use_mesh",
]
