"""repro.dist — the one mesh-and-spec layer shared by every workload.

The SNN engine (space-parallel `cells` axis), the LM stack
(`data`/`model`/`pod` axes) and the dry-run driver all build meshes and
partition specs through this package:

  sharding — logical constraint application (`shard`), divisibility-aware
      rule fitting (`_fit`) and path+shape spec inference
      (`infer_param_spec` / `infer_cache_spec` / `infer_batch_spec`),
      plus the `use_mesh` context that binds a mesh to the former.
  mesh — mesh constructors (production 16x16 / 2x16x16, flat SNN `cells`).
  compat — `shard_map` across the jax versions we support (the keyword
      for replication checking moved between releases).

Public API (all re-exported here):

  use_mesh(mesh)               context manager binding `mesh` for `shard`
  shard(x, *axes)              logical per-dim layout constraint on `x`;
                               identity outside a bound mesh
  axis_size(logical)           bound-mesh size of a logical axis (1 if unbound)
  infer_param_spec(path, shape, mesh)   parameter PartitionSpec by path+shape
  infer_cache_spec(path, shape, mesh)   KV/recurrent-state placement
  infer_batch_spec(name, shape, mesh)   input-batch placement
  tree_shardings(tree, mesh, infer_fn)  map an infer_* over a whole tree
  shard_put / replicated_put / global_put   host tree -> device placement,
                               process-spanning-mesh aware
  make_snn_mesh(H)             flat `cells` mesh over the GLOBAL device list
  make_production_mesh()       16x16 (or 2x16x16) LM mesh
  spans_processes(mesh)        does `mesh` cross a process boundary?
  shard_map(f, mesh, in_specs, out_specs)   version-stable jax.shard_map
  process_allgather(tree)      host-local numpy copy of global arrays
"""
from . import compat, mesh, sharding
from .compat import process_allgather, shard_map
from .mesh import make_production_mesh, make_snn_mesh, spans_processes
from .sharding import (NamedSharding, P, axis_size, global_put,
                       infer_batch_spec, infer_cache_spec, infer_param_spec,
                       replicated_put, shard, shard_put, tree_shardings,
                       use_mesh)

__all__ = [
    "compat", "mesh", "sharding", "process_allgather", "shard_map",
    "make_production_mesh", "make_snn_mesh", "spans_processes",
    "NamedSharding", "P", "axis_size", "global_put", "infer_batch_spec",
    "infer_cache_spec", "infer_param_spec", "replicated_put", "shard",
    "shard_put", "tree_shardings", "use_mesh",
]
