"""Sharding rules: logical constraint application + path/shape spec
inference.

Two layers, one mesh:

1. **Logical axes** — model code names *logical* axes ("batch", "experts",
   "cells"); `shard(x, *axes)` translates them to whatever mesh axes are
   bound by `use_mesh` and applies a `with_sharding_constraint`.  Outside a
   bound mesh it is an identity, so the same model runs unsharded on one
   device, under GSPMD on a production mesh, and inside `shard_map` bodies
   (which bind no mesh) without branching.

2. **Spec inference** — whole trees (params, optimizer state, KV caches,
   token batches) are placed by path+shape rules: `infer_param_spec`,
   `infer_cache_spec`, `infer_batch_spec`, each built on `_fit`, which
   tries candidate rules in order and keeps the first whose every
   mesh-present axis divides its dimension (axes absent from the mesh are
   dropped silently — the same rules serve the 2x16x16 multi-pod mesh, the
   16x16 pod, and the tiny CI meshes).

The divisibility-or-fallback structure is what keeps one rule table
serving every architecture in `repro.configs`: a 151936-vocab embedding
vocab-shards cleanly over 16 chips while a 122753-vocab one falls back to
sharding d_model over both axes, with no per-model configuration.
"""
from __future__ import annotations

import contextlib
import math
from contextvars import ContextVar
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "NamedSharding", "P", "axis_size", "get_mesh", "infer_batch_spec",
    "infer_cache_spec", "infer_param_spec", "shard", "shard_put",
    "tree_shardings", "use_mesh", "LOGICAL_AXES",
]

# ---------------------------------------------------------------------------
# mesh binding
# ---------------------------------------------------------------------------

_ACTIVE_MESH: ContextVar[Optional[Mesh]] = ContextVar("repro_active_mesh",
                                                      default=None)

# logical name -> physical mesh axes, in sharding-priority order.  A
# logical axis maps onto whichever of its physical axes exist in the bound
# mesh (so "batch" spans pod+data on the multi-pod mesh and just data on a
# single pod).
LOGICAL_AXES = {
    "batch": ("pod", "data"),
    "data": ("data",),
    "model": ("model",),
    "experts": ("model",),   # expert-parallelism rides the model axis
    "seq": ("model",),       # sequence sharding (long-context caches)
    "cells": ("cells",),     # SNN space-parallel axis
}


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Bind `mesh` for `shard`/`axis_size` in this context.

    Bindings nest (a ContextVar, restored on exit) and are task-local
    under async execution.  Model code never takes a mesh argument: it
    names logical axes and the caller decides the physical layout by
    choosing what to bind here — bind nothing and every constraint is an
    identity."""
    token = _ACTIVE_MESH.set(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.reset(token)


def get_mesh() -> Optional[Mesh]:
    """The mesh bound by the innermost `use_mesh`, or None outside one."""
    return _ACTIVE_MESH.get()


def axis_size(logical: str) -> int:
    """Product of the bound-mesh sizes of `logical`'s physical axes (1 when
    no mesh is bound or none of its axes exist)."""
    mesh = get_mesh()
    if mesh is None:
        return 1
    names = LOGICAL_AXES.get(logical, (logical,))
    return math.prod(mesh.shape[a] for a in names if a in mesh.shape)


# ---------------------------------------------------------------------------
# logical constraint application
# ---------------------------------------------------------------------------


def _greedy_entry(dim: int, logical: Optional[str], mesh: Mesh):
    """Physical spec entry for one dimension: keep each mapped axis while
    the cumulative shard count still divides `dim` (best-effort — a
    constraint must never make a program uncompilable)."""
    if logical is None:
        return None
    kept, prod = [], 1
    for a in LOGICAL_AXES.get(logical, (logical,)):
        if a in mesh.shape and dim % (prod * mesh.shape[a]) == 0:
            kept.append(a)
            prod *= mesh.shape[a]
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def shard(x, *axes):
    """Constrain `x`'s layout along logical `axes` (one entry per dim,
    None = unconstrained).  Identity when no mesh is bound — single-device
    smoke runs and `shard_map` bodies skip it entirely."""
    mesh = get_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard: got {len(axes)} axes for rank-{x.ndim} "
                         f"array (shape {x.shape})")
    entries = [_greedy_entry(d, a, mesh) for d, a in zip(x.shape, axes)]
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


# ---------------------------------------------------------------------------
# rule fitting
# ---------------------------------------------------------------------------

Rule = Tuple[Any, ...]          # per-dim entries: None | axis | (axes...)

# physical building blocks for the rule tables
FSDP = ("pod", "data")          # fully-sharded-data-parallel axes
TP = "model"                    # tensor/expert-parallel axis
ALL = ("pod", "data", "model")  # "shard over everything" fallback


def _entry_axes(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def _apply_rule(shape: Sequence[int], rule: Rule, mesh: Mesh):
    """Rule -> spec, or None if any mesh-present axis fails divisibility.

    Axes the mesh doesn't have are dropped (not a failure): the candidate
    `(("pod","data"), "model")` degrades to `P(None, "model")` on a
    pod-less mesh.  Axes the mesh has must divide their dim or the whole
    rule is rejected so `_fit` can try the next candidate — partial
    application would silently produce a different layout than the rule
    author intended."""
    if len(rule) != len(shape):
        return None
    out = []
    for dim, entry in zip(shape, rule):
        names = [a for a in _entry_axes(entry) if a in mesh.shape]
        if names:
            if dim % math.prod(mesh.shape[a] for a in names) != 0:
                return None
            out.append(names[0] if len(names) == 1 else tuple(names))
        else:
            out.append(None)
    return P(*out)


def _fit(shape: Sequence[int], candidate_rules: Sequence[Rule],
         mesh: Mesh) -> P:
    """First candidate rule that fits `shape` on `mesh` (see
    `_apply_rule`); fully replicated when none fits."""
    for rule in candidate_rules:
        spec = _apply_rule(shape, rule, mesh)
        if spec is not None:
            return spec
    return P(*(None,) * len(shape))


# ---------------------------------------------------------------------------
# spec inference: params / caches / batches
# ---------------------------------------------------------------------------


def infer_param_spec(path: str, shape: Sequence[int], mesh: Mesh) -> P:
    """Parameter placement by path + shape.

    - embeddings: vocab on TP, d_model on FSDP; odd vocab falls back to
      d_model over every axis (the d-dim fallback).
    - stacked layer weights [L, d_in, d_out]: d_in on FSDP, d_out on TP.
    - expert weights [L, E, d, f]: experts on TP (expert-parallel), f on
      FSDP; odd expert counts fall back to data-local experts with f on TP.
    - vectors (norm scales, biases): replicated.
    """
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]
    if nd <= 1:
        return P(*(None,) * nd)
    if "embed" in leaf and nd == 2:
        if shape[0] >= shape[1]:                     # (vocab, d)
            rules = [(TP, FSDP), (None, ALL), (None, None)]
        else:                                        # (d, vocab)
            rules = [(FSDP, TP), (ALL, None), (None, None)]
        return _fit(shape, rules, mesh)
    if nd == 4:                                      # (L, E, d, f) experts
        return _fit(shape, [(None, TP, None, FSDP),
                            (None, None, None, TP),
                            (None, None, None, FSDP),
                            (None,) * 4], mesh)
    if nd == 3:                                      # (L, d_in, d_out)
        return _fit(shape, [(None, FSDP, TP),
                            (None, None, TP),
                            (None, None, ALL),
                            (None,) * 3], mesh)
    # plain 2-D dense (un-stacked: routers, shared experts, heads)
    return _fit(shape, [(FSDP, TP), (None, TP), (None, ALL),
                        (None, None)], mesh)


def infer_cache_spec(path: str, shape: Sequence[int], mesh: Mesh) -> P:
    """KV/recurrent-state placement: batch on FSDP, sequence on TP.

    Sequence sharding carries the long-context decode case: at batch=1
    nothing divides the FSDP axes, so batch falls back to replicated and
    the 512k-deep cache still spreads over the TP axis."""
    nd = len(shape)
    if nd == 5:                                      # (L, B, S, H, D)
        rules = [(None, FSDP, TP, None, None),
                 (None, None, TP, None, None),
                 (None,) * 5]
    elif nd == 4:                                    # (B, S, H, D)
        rules = [(FSDP, TP, None, None),
                 (None, TP, None, None),
                 (None,) * 4]
    elif nd == 3:                                    # (B, S, d) enc_out /
        rules = [(FSDP, None, None), (None,) * 3]    # recurrent state
    elif nd == 2:                                    # (B, d)
        rules = [(FSDP, None), (None, None)]
    else:
        rules = [(None,) * nd]
    return _fit(shape, rules, mesh)


def infer_batch_spec(name: str, shape: Sequence[int], mesh: Mesh) -> P:
    """Input batches: leading (batch) dim over FSDP, rest replicated."""
    nd = len(shape)
    if nd == 0:
        return P()
    return _fit(shape, [(FSDP,) + (None,) * (nd - 1), (None,) * nd], mesh)


# ---------------------------------------------------------------------------
# whole-tree placement
# ---------------------------------------------------------------------------


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        elif isinstance(k, jax.tree_util.FlattenedIndexKey):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/" + "/".join(parts)


def tree_shardings(tree, mesh: Mesh,
                   infer_fn: Callable[[str, Sequence[int], Mesh], P]):
    """Map `infer_fn(path, shape, mesh)` over a tree of arrays (or
    ShapeDtypeStructs), returning a matching tree of NamedShardings."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(mesh, infer_fn(_path_str(kp),
                                                      leaf.shape, mesh)),
        tree)


def global_put(mesh: Mesh, tree, pspec: P):
    """Place a host-addressable tree onto `mesh` with partition spec
    `pspec`, working on process-spanning meshes too.

    Every process must hold the full host value (true throughout this repo:
    construction is a pure function of the config, so all workers build
    identical plans/states) — each then contributes just its addressable
    shards via `make_array_from_callback`.  Local meshes keep the plain
    `device_put` fast path."""
    from .mesh import spans_processes
    sh = NamedSharding(mesh, pspec)
    if spans_processes(mesh):
        import numpy as np

        def put(x):
            host = np.asarray(x)
            return jax.make_array_from_callback(
                host.shape, sh, lambda idx: host[idx])
        return jax.tree.map(put, tree)
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def shard_put(mesh: Mesh, tree, axis: str = "cells"):
    """Place a stacked [H, ...] tree with each shard on its device of the
    `axis` mesh axis (the SNN engine's plan/state layout)."""
    return global_put(mesh, tree, P(axis))


def replicated_put(mesh: Mesh, tree):
    """Replicate a host-addressable tree across every device of `mesh`."""
    return global_put(mesh, tree, P())
