"""shard_map / make_mesh across jax versions.

jax moved `shard_map` from `jax.experimental.shard_map` (keyword
`check_rep`) to top-level `jax.shard_map` (keyword `check_vma`), and grew
`jax.make_mesh` only in the later 0.4.x releases.  Every caller in this
repo goes through `dist.shard_map(...)` / `dist.compat.make_mesh(...)` so
the version splits live in exactly one place (exercised by the CI jax
version matrix).
"""
from __future__ import annotations

import inspect

import jax
from jax.sharding import Mesh

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, mesh, in_specs, out_specs, *, check: bool = False):
    """Version-stable `shard_map`; `check` maps onto check_vma/check_rep."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: check})


def make_mesh(axis_shapes, axis_names) -> Mesh:
    """Version-stable `jax.make_mesh` (absent before jax 0.4.35)."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    from jax.experimental import mesh_utils
    return Mesh(mesh_utils.create_device_mesh(axis_shapes), axis_names)


def process_allgather(tree):
    """Host-local numpy copy of a tree of (possibly process-spanning)
    global arrays; a collective — every process must call it.  Lives here
    because `multihost_utils` is still under `jax.experimental` and may
    move like `shard_map` did."""
    from jax.experimental import multihost_utils
    return multihost_utils.process_allgather(tree, tiled=True)
