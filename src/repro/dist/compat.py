"""shard_map across jax versions.

jax moved `shard_map` from `jax.experimental.shard_map` (keyword
`check_rep`) to top-level `jax.shard_map` (keyword `check_vma`).  Every
caller in this repo goes through `dist.shard_map(f, mesh, in_specs,
out_specs, check=...)` so the version split lives in exactly one place.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, mesh, in_specs, out_specs, *, check: bool = False):
    """Version-stable `shard_map`; `check` maps onto check_vma/check_rep."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: check})
