from . import engine, sampling

__all__ = ["engine", "sampling"]
