"""Batched serving engine: continuous-batching-lite.

Requests (prompts) are packed into a fixed batch; finished slots are
refilled from a queue between steps (static shapes: one compiled prefill fn,
one compiled decode fn).  Prefill writes the prompt into the slot's cache
region; decode advances all live slots together."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import lm
from . import sampling


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # [T] int32
    max_new: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch: int, s_max: int,
                 greedy: bool = True, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.batch, self.s_max = batch, s_max
        self.cache = lm.init_cache(cfg, batch, s_max)
        # NOTE: per-slot position bookkeeping is host-side; the cache 'pos'
        # is uniform because slots prefill in lockstep (simplification:
        # a refill round re-prefills the whole batch).
        self.greedy = greedy
        self.key = jax.random.key(seed)

        def _prefill(params, cache, tokens):
            logits, cache = lm.decode_step(cfg, params, cache, tokens)
            return logits[:, -1], cache

        def _decode(params, cache, tok):
            logits, cache = lm.decode_step(cfg, params, cache, tok)
            return logits[:, 0], cache

        self.prefill = jax.jit(_prefill)
        self.decode = jax.jit(_decode)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve requests in rounds of `batch` (static-shape batching)."""
        done: List[Request] = []
        for i in range(0, len(requests), self.batch):
            round_reqs = requests[i:i + self.batch]
            done.extend(self._run_round(round_reqs))
        return done

    def _run_round(self, reqs: List[Request]) -> List[Request]:
        B = self.batch
        tmax = max(r.prompt.shape[0] for r in reqs)
        toks = np.zeros((B, tmax), np.int32)
        for s, r in enumerate(reqs):
            toks[s, -r.prompt.shape[0]:] = r.prompt   # left-pad
        self.cache = lm.init_cache(self.cfg, B, self.s_max)
        logits, self.cache = self.prefill(self.params, self.cache,
                                          jnp.asarray(toks))
        n_new = max(r.max_new for r in reqs)
        outs = []
        tok = self._sample(logits)
        for _ in range(n_new):
            outs.append(np.asarray(tok))
            logits, self.cache = self.decode(self.params, self.cache,
                                             tok[:, None])
            tok = self._sample(logits)
        gen = np.stack(outs, axis=1)                   # [B, n_new]
        for s, r in enumerate(reqs):
            r.out = gen[s, :r.max_new]
        return reqs

    def _sample(self, logits):
        if self.greedy:
            return sampling.greedy(logits)
        self.key, k = jax.random.split(self.key)
        return sampling.temperature(k, logits)
