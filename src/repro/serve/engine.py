"""Batched serving engine: continuous batching with per-slot refill.

Requests (prompts) are packed into a fixed batch of decode slots; a
finished slot is refilled from the queue immediately and INDIVIDUALLY:
the new prompt prefills through a fresh B=1 sub-cache that is written
back into just that slot's cache rows and position (static shapes: one
compiled slot-prefill fn + one compiled decode fn, both reused for every
refill).  The other slots' caches, positions and greedy sampling are
untouched, so a live request's output is bitwise independent of refill
traffic — asserted by tests.  Per-slot positions live in cache['pos']
([B] int32, see `lm.init_cache(per_slot=True)`); request/output
bookkeeping stays host-side."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import lm
from . import sampling


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # [T] int32
    max_new: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch: int, s_max: int,
                 greedy: bool = True, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.batch, self.s_max = batch, s_max
        self.cache = lm.init_cache(cfg, batch, s_max, per_slot=True)
        self.greedy = greedy
        self.key = jax.random.key(seed)

        def _prefill_slot(params, cache, tokens, b):
            # fresh B=1 sub-cache (scalar pos 0: the documented
            # prefill-from-zero path), written back into slot b only
            sub = lm.init_cache(cfg, 1, s_max)
            logits, sub = lm.decode_step(cfg, params, sub, tokens)
            # units caches are [n_units, B, ...], rem caches [B, ...]
            wr = lambda axis: (lambda full, one:
                               jax.lax.dynamic_update_slice_in_dim(
                                   full, one.astype(full.dtype), b, axis))
            layers = {
                "units": jax.tree.map(wr(1), cache["layers"]["units"],
                                      sub["layers"]["units"]),
                "rem": jax.tree.map(wr(0), cache["layers"]["rem"],
                                    sub["layers"]["rem"]),
            }
            pos = cache["pos"].at[b].set(sub["pos"])
            return logits[0, -1], {"layers": layers,
                                   "enc_out": cache.get("enc_out"),
                                   "pos": pos}

        def _decode(params, cache, tok):
            logits, cache = lm.decode_step(cfg, params, cache, tok)
            return logits[:, 0], cache

        self.prefill_slot = jax.jit(_prefill_slot)
        self.decode = jax.jit(_decode)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests, refilling finished slots one at a time."""
        if not requests:
            return []
        B = self.batch
        queue = list(requests)
        t_pad = max(r.prompt.shape[0] for r in requests)
        self.cache = lm.init_cache(self.cfg, B, self.s_max, per_slot=True)
        live: List[Optional[Request]] = [None] * B
        gen: List[List[int]] = [[] for _ in range(B)]
        cur = np.zeros((B, 1), np.int32)
        while True:
            changed = True
            while changed:               # admit + retire until stable
                changed = False
                for b in range(B):
                    if live[b] is None and queue:
                        live[b] = queue.pop(0)
                        gen[b] = [self._admit(b, live[b], t_pad)]
                        cur[b, 0] = gen[b][0]
                        changed = True
                    r = live[b]
                    if r is not None and len(gen[b]) >= r.max_new:
                        r.out = np.asarray(gen[b][:r.max_new], np.int32)
                        live[b] = None
                        changed = True
            if not any(r is not None for r in live):
                break
            logits, self.cache = self.decode(self.params, self.cache,
                                             jnp.asarray(cur))
            tok = np.asarray(self._sample(logits))
            for b in range(B):
                if live[b] is not None:
                    gen[b].append(int(tok[b]))
                    cur[b, 0] = int(tok[b])
        return requests

    def _admit(self, b: int, req: Request, t_pad: int) -> int:
        """Prefill ONLY slot b with the request's (left-padded) prompt;
        returns the first sampled token."""
        toks = np.zeros((1, t_pad), np.int32)
        toks[0, -req.prompt.shape[0]:] = req.prompt
        logit, self.cache = self.prefill_slot(
            self.params, self.cache, jnp.asarray(toks), jnp.int32(b))
        return int(self._sample(np.asarray(logit)[None])[0])

    def _sample(self, logits):
        if self.greedy:
            return sampling.greedy(logits)
        self.key, k = jax.random.split(self.key)
        return sampling.temperature(k, logits)
